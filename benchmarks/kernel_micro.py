"""Kernel microbenchmark: the Pallas quantization kernels' VMEM tiling and
roofline position on the TPU v5e target, plus CPU-side timing of the jnp
reference (the only wall-clock available in this container).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / iters


def run(print_fn=print):
    print_fn("\n== quantization kernels: arithmetic intensity & v5e roofline "
             "position ==")
    print_fn("kernel         bytes/elem(moved)  flops/elem  intensity  "
             "v5e-bound")
    rows = [
        ("quant_int8", 2 + 1 + 4 / 512., 3, None),
        ("dequant_int8", 1 + 2 + 4 / 512., 1, None),
        ("quant_int4", 2 + 0.5 + 4 / 512., 4, None),
        ("dequant_int4", 0.5 + 2 + 4 / 512., 2, None),
    ]
    ridge = PEAK_FLOPS / HBM_BW
    for name, bpe, fpe, _ in rows:
        inten = fpe / bpe
        bound = "memory" if inten < ridge else "compute"
        print_fn(f"{name:14s} {bpe:17.2f} {fpe:11d} {inten:10.2f}  {bound}"
                 f"  (ridge {ridge:.0f})")
    print_fn("-> all four kernels are deeply memory-bound on TPU: fusing the "
             "dequant into the consumer matmul (kernels/dequant_matmul.py) "
             "removes the extra HBM round-trip entirely.")

    print_fn("\n== CPU wall-times of the jnp reference path (container "
             "sanity only) ==")
    for n in (1 << 16, 1 << 20, 1 << 22):
        x = jax.random.normal(jax.random.key(0), (n,))
        q8 = jax.jit(lambda v: ops.quantize_int8(v, 512))
        t = _time(q8, x)
        print_fn(f"  quant_int8 n={n:>8d}: {t * 1e3:7.2f} ms "
                 f"({n / t / 1e9:.2f} Gelem/s)")
    return True


if __name__ == "__main__":
    run()
