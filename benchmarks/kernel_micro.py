"""Kernel microbenchmark: the Pallas quantization kernels' VMEM tiling and
roofline position on the TPU v5e target, the fused-vs-unfused dequant
pipeline comparison, CPU-side timing of the jnp reference (the only
wall-clock available in this container), the per-layer gather/compute
overlap probe, and the kernel-impl HLO census (impl="jnp" vs
impl="pallas_interpret" must emit the identical collective inventory —
fusion changes compute, never communication).

Emits ``BENCH_kernels.json`` (cwd, or $REPRO_BENCH_DIR); CI diffs the
stable fields against ``benchmarks/baselines/BENCH_kernels.json`` via
``benchmarks.check_baseline`` so the census/roofline trajectory can never
silently regress. Wall-clock fields are recorded but not gated.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    # warm up with a single call (compile) and block on *every* leaf of the
    # result before starting the clock — the old version called fn twice and
    # never blocked on non-tuple results, so first-call compile time leaked
    # into the measurement
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / iters


def bench_out_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_kernels.json"


def roofline_rows() -> dict:
    """Bytes/elem moved, flops/elem and arithmetic intensity per kernel.

    The fused rows are the point of the exercise: unfused dequant->matmul
    round-trips the dequantized bf16 weight through HBM (write + re-read =
    4 B/param on top of the 1 B/param INT8 read); the fused kernel scales
    tiles in VMEM so HBM traffic stays at the wire format. Same for the
    a2a dequant-reduce (d chunks summed in one pass vs d f32 copies)."""
    rows = {
        "quant_int8": dict(bytes_per_elem=2 + 1 + 4 / 512., flops_per_elem=3),
        "dequant_int8": dict(bytes_per_elem=1 + 2 + 4 / 512., flops_per_elem=1),
        "quant_int4": dict(bytes_per_elem=2 + 0.5 + 4 / 512., flops_per_elem=4),
        "dequant_int4": dict(bytes_per_elem=0.5 + 2 + 4 / 512., flops_per_elem=2),
        # weight consumed by a matmul of M=2048 rows: per weight element the
        # unfused pipeline moves int8(1) + bf16 write(2) + bf16 read(2);
        # fused moves only the int8 (+ scales, amortized)
        "dequant_matmul_unfused": dict(bytes_per_elem=1 + 2 + 2 + 4 / 512.,
                                       flops_per_elem=1 + 2 * 2048),
        "dequant_matmul_fused": dict(bytes_per_elem=1 + 4 / 512.,
                                     flops_per_elem=1 + 2 * 2048),
        # a2a receive side, d=8 chunks: unfused writes+reads the f32 dequant
        # of every chunk before reducing; fused streams them once
        "dequant_int4_sum_unfused": dict(bytes_per_elem=0.5 + 4 + 4 + 4 / 8.,
                                         flops_per_elem=3),
        "dequant_int4_sum_fused": dict(bytes_per_elem=0.5 + 4 / 8. + 4 / 512.,
                                       flops_per_elem=3),
        # attention, per score element (Sq x Sk per head; S=2048, D=64,
        # bf16 activations): materialized writes+reads the logits for the
        # softmax and the probs for the PV matmul (4 x 2 B); flash keeps
        # both in VMEM so HBM sees only q/k/v in + o out, amortized over
        # the S scores each row participates in (~ 8*D/S bytes/score)
        "attention_materialized": dict(bytes_per_elem=2 + 2 + 2 + 2.,
                                       flops_per_elem=4 * 64 + 5),
        "attention_flash": dict(bytes_per_elem=8 * 64 / 2048.,
                                flops_per_elem=4 * 64 + 5),
        # selective scan, per (s, d, n) state element (N=16, D=512, f32):
        # the materialized form writes dA = exp(dt*A) and dB*x to HBM,
        # re-reads them for the scan, and round-trips h per step; the
        # kernel holds h in VMEM and HBM sees only dt/x in + y out
        # (amortized over N) and B/C in (amortized over D)
        "selective_scan_materialized": dict(
            bytes_per_elem=4 + 4 + 4 + 4 + 4 + 4, flops_per_elem=6),
        "selective_scan_fused": dict(
            bytes_per_elem=(4 + 4 + 4) / 16. + (4 + 4) / 512.,
            flops_per_elem=6),
        # weight-grad wire epilogue (matmul_quant), per dW element with an
        # M=2048 contraction: unfused writes the dense f32 dW (4 B) and
        # re-reads it to quantize (4 B) before emitting the INT8 wire
        # (1 B + scales/block); fused quantizes in the matmul epilogue so
        # only the wire format ever reaches HBM
        "matmul_quant_unfused": dict(bytes_per_elem=4 + 4 + 1 + 4 / 64.,
                                     flops_per_elem=2 * 2048 + 4),
        "matmul_quant_fused": dict(bytes_per_elem=1 + 4 / 64.,
                                   flops_per_elem=2 * 2048 + 4),
    }
    ridge = PEAK_FLOPS / HBM_BW
    for name, r in rows.items():
        r["intensity"] = r["flops_per_elem"] / r["bytes_per_elem"]
        r["v5e_bound"] = "memory" if r["intensity"] < ridge else "compute"
    return dict(ridge=ridge, rows=rows)


def cpu_wall_section(print_fn) -> dict:
    """CPU wall-times of the jnp reference path (container sanity only)."""
    out = {}
    print_fn("\n== CPU wall-times of the jnp reference path (container "
             "sanity only; not baseline-gated) ==")
    for n in (1 << 16, 1 << 20, 1 << 22):
        x = jax.random.normal(jax.random.key(0), (n,))
        q8 = jax.jit(lambda v: ops.quantize_int8(v, 512))
        t = _time(q8, x)
        out[f"quant_int8_n{n}"] = dict(ms=t * 1e3, gelem_s=n / t / 1e9)
        print_fn(f"  quant_int8 n={n:>8d}: {t * 1e3:7.2f} ms "
                 f"({n / t / 1e9:.2f} Gelem/s)")

    # fused vs unfused dequant-matmul on the jnp oracle path: on CPU the
    # win is XLA fusing the scale-multiply into the dot's operand stream;
    # the structural win (no HBM round-trip) is the roofline section above
    print_fn("\n== fused vs unfused dequant->matmul (jnp oracle, CPU) ==")
    m, block = 256, 512
    for k, n in ((512, 2048), (2048, 2048)):
        w = jax.random.normal(jax.random.key(1), (k * n,))
        q, s = ops.quantize_int8(w, block)
        x = jax.random.normal(jax.random.key(2), (m, k))

        def unfused(x, q, s):
            wd = ops.dequantize_int8(q, s, block, jnp.float32).reshape(k, n)
            return x @ wd

        def fused(x, q, s):
            return ops.dequant_matmul(x, q, s, (k, n), block,
                                      dtype=jnp.float32, impl="jnp")

        tu = _time(jax.jit(unfused), x, q, s)
        tf = _time(jax.jit(fused), x, q, s)
        out[f"dequant_matmul_{k}x{n}"] = dict(
            unfused_ms=tu * 1e3, fused_ms=tf * 1e3, speedup=tu / tf)
        print_fn(f"  K={k:5d} N={n:5d}: unfused {tu * 1e3:7.2f} ms  "
                 f"fused {tf * 1e3:7.2f} ms  ({tu / tf:.2f}x)")

    # hot-path kernels under the ops dispatch (DESIGN.md §5): flash
    # attention vs the dense materialized softmax, the blocked selective
    # scan vs the materialized associative scan, and the epilogue-fused
    # matmul_quant vs matmul-then-quantize. CPU numbers are sanity only
    # (the structural HBM win is the roofline rows above) — never gated.
    print_fn("\n== hot-path kernels: fused vs materialized (jnp oracle, "
             "CPU, not baseline-gated) ==")
    bh, s, d = 4, 512, 64
    ks = jax.random.split(jax.random.key(3), 3)
    q_, k_, v_ = (jax.random.normal(kk_, (bh, s, d)) for kk_ in ks)

    def attn_unfused(q, k, v):
        sc = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        p = jax.nn.softmax(jnp.where(mask, sc, -1e30), axis=-1)
        return jnp.einsum("bqk,bkd->bqd", p, v)

    ta_u = _time(jax.jit(attn_unfused), q_, k_, v_)
    ta_f = _time(jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, impl="jnp")), q_, k_, v_)
    out[f"attention_bh{bh}_s{s}"] = dict(
        unfused_ms=ta_u * 1e3, fused_ms=ta_f * 1e3, speedup=ta_u / ta_f)
    print_fn(f"  attention      BH={bh} S={s} D={d}: materialized "
             f"{ta_u * 1e3:7.2f} ms  flash {ta_f * 1e3:7.2f} ms  "
             f"({ta_u / ta_f:.2f}x)")

    b, ss, dd, nn = 2, 256, 256, 16
    kss = jax.random.split(jax.random.key(4), 6)
    dt_ = jax.random.uniform(kss[0], (b, ss, dd), minval=0.01, maxval=0.2)
    x_ = jax.random.normal(kss[1], (b, ss, dd))
    bm_ = jax.random.normal(kss[2], (b, ss, nn)) * 0.3
    cm_ = jax.random.normal(kss[3], (b, ss, nn)) * 0.3
    a_ = -jnp.exp(jax.random.normal(kss[4], (dd, nn)) * 0.3)
    h0_ = jax.random.normal(kss[5], (b, dd, nn)) * 0.1

    def scan_unfused(dt, x, bm, cm, a, h0):
        da = jnp.exp(dt[..., None] * a)                   # (B,S,D,N) in HBM
        dbx = (dt * x)[..., None] * bm[:, :, None, :]     # (B,S,D,N) in HBM
        def op(l, r):
            return l[0] * r[0], r[1] + r[0] * l[1]
        aa, hh = jax.lax.associative_scan(op, (da, dbx), axis=1)
        h = aa * h0[:, None] + hh
        return jnp.sum(h * cm[:, :, None, :], axis=-1), h[:, -1]

    ts_u = _time(jax.jit(scan_unfused), dt_, x_, bm_, cm_, a_, h0_)
    ts_f = _time(jax.jit(lambda *a2: ops.selective_scan(*a2, impl="jnp")),
                 dt_, x_, bm_, cm_, a_, h0_)
    out[f"selective_scan_s{ss}_d{dd}"] = dict(
        unfused_ms=ts_u * 1e3, fused_ms=ts_f * 1e3, speedup=ts_u / ts_f)
    print_fn(f"  selective_scan B={b} S={ss} D={dd} N={nn}: materialized "
             f"{ts_u * 1e3:7.2f} ms  blocked {ts_f * 1e3:7.2f} ms  "
             f"({ts_u / ts_f:.2f}x)")

    mq_m, mq_k, mq_n = 1024, 256, 2048
    x2 = jax.random.normal(jax.random.key(5), (mq_m, mq_k))
    g2 = jax.random.normal(jax.random.key(6), (mq_m, mq_n))

    def mq_unfused(x2, g2):
        return ops.quantize_int8((x2.T @ g2).reshape(-1), 64)

    tq_u = _time(jax.jit(mq_unfused), x2, g2)
    tq_f = _time(jax.jit(lambda x2, g2: ops.matmul_quant(
        x2, g2, 64, impl="jnp")), x2, g2)
    out[f"matmul_quant_{mq_m}x{mq_k}x{mq_n}"] = dict(
        unfused_ms=tq_u * 1e3, fused_ms=tq_f * 1e3, speedup=tq_u / tq_f)
    print_fn(f"  matmul_quant   M={mq_m} K={mq_k} N={mq_n}: "
             f"matmul+quantize {tq_u * 1e3:7.2f} ms  epilogue "
             f"{tq_f * 1e3:7.2f} ms  ({tq_u / tq_f:.2f}x)")
    return out


def run(print_fn=print):
    rec = {}
    print_fn("\n== quantization kernels: arithmetic intensity & v5e roofline "
             "position ==")
    rl = roofline_rows()
    rec["roofline"] = rl
    print_fn(f"{'kernel':24s} {'bytes/elem':>11s} {'flops/elem':>11s} "
             f"{'intensity':>10s}  v5e-bound")
    for name, r in rl["rows"].items():
        print_fn(f"{name:24s} {r['bytes_per_elem']:11.2f} "
                 f"{r['flops_per_elem']:11.0f} {r['intensity']:10.2f}  "
                 f"{r['v5e_bound']}  (ridge {rl['ridge']:.0f})")
    print_fn("-> the quant/dequant kernels are deeply memory-bound: fusing "
             "the dequant into the consumer (dequant_matmul.py, the *_sum "
             "a2a kernels) removes the extra HBM round-trip entirely, which "
             "is where the per-GCD TFLOPS live.")

    rec["cpu_wall"] = cpu_wall_section(print_fn)
    rec["overlap_probe"] = overlap_probe(print_fn)
    rec["impl_census"] = impl_census_probe(print_fn)
    rec["grad_rs_census"] = grad_rs_census_probe(print_fn)

    out = bench_out_path()
    out.write_text(json.dumps(rec, indent=1))
    print_fn(f"\nwrote {out}")
    return True


# ---------------------------------------------------------------------------
# Per-layer gather/compute overlap probe (DESIGN.md §3)
# ---------------------------------------------------------------------------

N_LAYERS = 4


def _probe_subprocess(flag: str, print_fn):
    """Run a child probe on 8 fake CPU devices (XLA_FLAGS must be set before
    the child's first jax call)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    # invoke by file path, not -m: the benchmarks dir isn't an installed
    # package and -m would silently depend on the parent's cwd
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), flag],
        capture_output=True, text=True, timeout=900, env=env)
    if r.returncode != 0:
        print_fn("probe failed:\n" + (r.stdout + r.stderr)[-2000:])
        raise RuntimeError(f"probe subprocess {flag} failed")
    return json.loads(r.stdout.strip().splitlines()[-1])


def overlap_probe(print_fn=print) -> dict:
    """Compile + time the engine forward with overlap off/on on 8 fake CPU
    devices and census the compiled HLO."""
    print_fn("\n== per-layer gather/compute overlap "
             "(zero_topo, qwen2-0.5b reduced, 8 fake CPU devices) ==")
    rec = _probe_subprocess("--overlap-probe", print_fn)
    for key in ("overlap=False", "overlap=True"):
        m = rec[key]
        print_fn(f"  {key:14s} fwd step {m['step_ms']:7.2f} ms  "
                 f"per-layer {m['per_layer_ms']:6.2f} ms  "
                 f"all-gathers {m['all_gather_count']:3d}  "
                 f"gather wire {m['all_gather_wire_mb']:.3f} MB  "
                 f"loss {m['loss']:.6f}")
    off, on = rec["overlap=False"], rec["overlap=True"]
    same_comm = (off["all_gather_count"] == on["all_gather_count"]
                 and abs(off["all_gather_wire_mb"]
                         - on["all_gather_wire_mb"]) < 1e-9)
    print_fn(f"  -> comm volume identical: {same_comm}; losses bitwise equal: "
             f"{off['loss'] == on['loss']}. Overlap changes only the "
             "schedule (gather issued one layer ahead); CPU fake devices "
             "serialize collectives, so the wall-clock win appears on real "
             "accelerators with async collectives.")
    # informational only — when this is False the assert below fails the
    # benchmark run itself (no JSON is emitted), which is what fails CI;
    # the baseline gate compares the census numbers, not this flag
    rec["comm_identical"] = same_comm
    assert same_comm and off["loss"] == on["loss"]
    return rec


def _overlap_probe_main():
    """Child half of overlap_probe: runs with 8 fake devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch import hlo
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.registry import build_model, get_arch

    jax.config.update("jax_default_matmul_precision", "float32")
    ax = ("data", "node", "gcd")
    mesh = make_test_mesh()
    arch = get_arch("qwen2-0.5b").reduced(n_layers=N_LAYERS, d_model=128,
                                          vocab=256)
    model = build_model(arch)
    rng = np.random.default_rng(0)
    batch_np = rng.integers(0, arch.vocab, (8, 33), dtype=np.int32)
    out = {}
    for overlap in (False, True):
        cfg = scheme_config("zero_topo", mesh, quant_block=64,
                            overlap=overlap, compute_dtype="float32")
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
        ev = eng.make_eval_step(model.loss_fn(), {"tokens": P(ax)})
        state = eng.init_state(jax.random.key(0))
        batch = {"tokens": jax.device_put(jnp.asarray(batch_np),
                                          NamedSharding(mesh, P(ax)))}
        loss = float(ev(state, batch))
        dt = _time(ev, state, batch, iters=3)
        census = hlo.analyze(
            ev.lower(state, batch).compile().as_text()).summary()
        out[f"overlap={overlap}"] = dict(
            loss=loss, step_ms=dt * 1e3, per_layer_ms=dt * 1e3 / N_LAYERS,
            all_gather_count=int(
                census["collective_counts"].get("all-gather", 0)),
            all_gather_wire_mb=census["wire_bytes"].get("all-gather", 0.0)
            / 1e6)
    print(json.dumps(out))


# ---------------------------------------------------------------------------
# Kernel-impl census probe (DESIGN.md §5)
# ---------------------------------------------------------------------------

def impl_census_probe(print_fn=print) -> dict:
    """Compile fwd+bwd with impl="jnp" vs impl="pallas_interpret" and census
    the collective inventory of both compiled modules: fusing the dequant
    into the matmul (and the a2a reduce into its dequant) must leave the
    collective count and wire bytes exactly unchanged."""
    print_fn("\n== kernel impl dispatch: collective census, jnp vs "
             "pallas_interpret (fwd+bwd, 8 fake CPU devices) ==")
    rec = _probe_subprocess("--impl-probe", print_fn)
    for impl in ("jnp", "pallas_interpret"):
        m = rec[impl]
        print_fn(f"  impl={impl:17s} collectives {m['collective_counts']}  "
                 f"wire {m['total_wire_mb']:.3f} MB  loss {m['loss']:.6f}")
    same = (rec["jnp"]["collective_counts"]
            == rec["pallas_interpret"]["collective_counts"]
            and rec["jnp"]["wire_bytes"] == rec["pallas_interpret"]["wire_bytes"])
    bitwise = rec["jnp"]["loss"] == rec["pallas_interpret"]["loss"]
    print_fn(f"  -> collective count/wire bytes identical: {same}; losses "
             f"bitwise equal: {bitwise} (fusion changes compute, never "
             "communication)")
    rec["census_identical"] = same   # informational; the assert is the gate
    assert same and bitwise, rec
    return rec


def _impl_probe_main():
    """Child half of impl_census_probe (8 fake devices): fwd+bwd so the
    INT4 a2a gradient reduce-scatter and the secondary re-gather are in the
    compiled module, not just the forward gathers."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.engine import ParamView, TrainHparams, ZeroEngine
    from repro.launch import hlo
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.registry import build_model, get_arch

    jax.config.update("jax_default_matmul_precision", "float32")
    ax = ("data", "node", "gcd")
    mesh = make_test_mesh()
    arch = get_arch("qwen2-0.5b").reduced(n_layers=N_LAYERS, d_model=128,
                                          vocab=256)
    model = build_model(arch)
    loss_fn = model.loss_fn()
    rng = np.random.default_rng(0)
    batch_np = rng.integers(0, arch.vocab, (8, 33), dtype=np.int32)
    out = {}
    for impl in ("jnp", "pallas_interpret"):
        cfg = scheme_config("zero_topo", mesh, quant_block=64,
                            compute_dtype="float32", impl=impl)
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
        state = eng.init_state(jax.random.key(0))
        specs = eng.state_in_specs()["primaries"]

        def local(primaries, b, eng=eng):
            def loss(p):
                v = ParamView(eng.fns, p, overlap=eng.cfg.overlap)
                l, t = loss_fn(v, b)
                return l / t
            return jax.value_and_grad(loss)(primaries)

        sm = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(specs, {"tokens": P(ax)}),
                               out_specs=(P(), specs), check_vma=False))
        batch = {"tokens": jax.device_put(jnp.asarray(batch_np),
                                          NamedSharding(mesh, P(ax)))}
        loss, _ = sm(state["primaries"], batch)
        census = hlo.analyze(
            sm.lower(state["primaries"], batch).compile().as_text()).summary()
        out[impl] = dict(
            loss=float(loss),
            collective_counts=census["collective_counts"],
            wire_bytes=census["wire_bytes"],
            total_wire_mb=census["total_wire_bytes"] / 1e6)
    print(json.dumps(out))


# ---------------------------------------------------------------------------
# Grad-RS census probe (DESIGN.md §8)
# ---------------------------------------------------------------------------

def grad_rs_census_probe(print_fn=print) -> dict:
    """Compile the full train step with the seed and the streaming grad
    paths and census the gradient collectives of both modules.

    The streaming path moves the stage-2 reduce-scatter + cross-replica
    sync from one batched post-backward collective per leaf into the
    reverse scan body (one per layer), so the *counts* differ by design
    (scan trip count multiplies ops) — but the total gradient wire bytes
    per step must be IDENTICAL at n_microbatch=1: same data, different
    schedule. Both censuses are pinned in the baseline so neither path's
    collective inventory can silently drift."""
    print_fn("\n== streaming grad path: train-step collective census, seed "
             "vs stream (zero_topo, 8 fake CPU devices) ==")
    rec = _probe_subprocess("--grad-rs-probe", print_fn)
    for key in ("stream=False", "stream=True"):
        m = rec[key]
        print_fn(f"  {key:13s} collectives {m['collective_counts']}  "
                 f"wire {m['total_wire_mb']:.3f} MB  loss {m['loss']:.6f}  "
                 f"grad-RS wire {m['grad_rs_wire_mb']:.3f} MB")
    off, on = rec["stream=False"], rec["stream=True"]
    same_wire = abs(off["grad_rs_wire_mb"] - on["grad_rs_wire_mb"]) < 1e-9
    bitwise = off["loss"] == on["loss"]
    print_fn(f"  -> grad-RS wire bytes identical: {same_wire}; losses "
             f"bitwise equal: {bitwise} (streaming changes the schedule and "
             "the accumulation layout, never the gradient bytes on the "
             "wire)")
    rec["grad_rs_wire_identical"] = same_wire   # informational; assert gates
    assert same_wire and bitwise, rec
    return rec


def _grad_rs_probe_main():
    """Child half of grad_rs_census_probe (8 fake devices): one full train
    step per grad regime — the stage-2 RS + cross-replica + update gather
    are only in the compiled module for a *train* step."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch import hlo
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.registry import build_model, get_arch

    jax.config.update("jax_default_matmul_precision", "float32")
    ax = ("data", "node", "gcd")
    mesh = make_test_mesh()
    arch = get_arch("qwen2-0.5b").reduced(n_layers=N_LAYERS, d_model=128,
                                          vocab=256)
    model = build_model(arch)
    rng = np.random.default_rng(0)
    batch_np = rng.integers(0, arch.vocab, (8, 33), dtype=np.int32)
    out = {}
    for stream in (False, True):
        cfg = scheme_config("zero_topo", mesh, quant_block=64,
                            compute_dtype="float32", stream_grads=stream)
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                         TrainHparams(lr=1e-3, total_steps=8, warmup_steps=0))
        state = eng.init_state(jax.random.key(0))
        step = eng.make_train_step(model.loss_fn(), {"tokens": P(ax)})
        batch = {"tokens": jax.device_put(jnp.asarray(batch_np),
                                          NamedSharding(mesh, P(ax)))}
        census = hlo.analyze(
            step.lower(state, batch).compile().as_text()).summary()
        state, m = step(state, batch)
        # gradient wire = the a2a-based quantized RS (stage 1 + stage 2)
        # plus the cross-replica all-reduce; the all-gathers are the
        # (unchanged) weight/update paths
        grs = census["wire_bytes"].get("all-to-all", 0.0) \
            + census["wire_bytes"].get("all-reduce", 0.0) \
            + census["wire_bytes"].get("reduce-scatter", 0.0)
        out[f"stream={stream}"] = dict(
            loss=float(m["loss"]),
            collective_counts=census["collective_counts"],
            wire_bytes=census["wire_bytes"],
            total_wire_mb=census["total_wire_bytes"] / 1e6,
            grad_rs_wire_mb=grs / 1e6)
    print(json.dumps(out))


if __name__ == "__main__":
    if "--overlap-probe" in sys.argv:
        _overlap_probe_main()
    elif "--impl-probe" in sys.argv:
        _impl_probe_main()
    elif "--grad-rs-probe" in sys.argv:
        _grad_rs_probe_main()
    else:
        run()
