"""Kernel microbenchmark: the Pallas quantization kernels' VMEM tiling and
roofline position on the TPU v5e target, plus CPU-side timing of the jnp
reference (the only wall-clock available in this container), plus the
per-layer gather/compute overlap probe (ZeroConfig.overlap on/off on the
8-fake-device test mesh, run in a subprocess so this process keeps its
single-device view).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / iters


def run(print_fn=print):
    print_fn("\n== quantization kernels: arithmetic intensity & v5e roofline "
             "position ==")
    print_fn("kernel         bytes/elem(moved)  flops/elem  intensity  "
             "v5e-bound")
    rows = [
        ("quant_int8", 2 + 1 + 4 / 512., 3, None),
        ("dequant_int8", 1 + 2 + 4 / 512., 1, None),
        ("quant_int4", 2 + 0.5 + 4 / 512., 4, None),
        ("dequant_int4", 0.5 + 2 + 4 / 512., 2, None),
    ]
    ridge = PEAK_FLOPS / HBM_BW
    for name, bpe, fpe, _ in rows:
        inten = fpe / bpe
        bound = "memory" if inten < ridge else "compute"
        print_fn(f"{name:14s} {bpe:17.2f} {fpe:11d} {inten:10.2f}  {bound}"
                 f"  (ridge {ridge:.0f})")
    print_fn("-> all four kernels are deeply memory-bound on TPU: fusing the "
             "dequant into the consumer matmul (kernels/dequant_matmul.py) "
             "removes the extra HBM round-trip entirely.")

    print_fn("\n== CPU wall-times of the jnp reference path (container "
             "sanity only) ==")
    for n in (1 << 16, 1 << 20, 1 << 22):
        x = jax.random.normal(jax.random.key(0), (n,))
        q8 = jax.jit(lambda v: ops.quantize_int8(v, 512))
        t = _time(q8, x)
        print_fn(f"  quant_int8 n={n:>8d}: {t * 1e3:7.2f} ms "
                 f"({n / t / 1e9:.2f} Gelem/s)")

    overlap_probe(print_fn)
    return True


# ---------------------------------------------------------------------------
# Per-layer gather/compute overlap probe (DESIGN.md §3)
# ---------------------------------------------------------------------------

N_LAYERS = 4


def overlap_probe(print_fn=print):
    """Compile + time the engine forward with overlap off/on on 8 fake CPU
    devices and census the compiled HLO.  Spawned as a subprocess because
    XLA_FLAGS must be set before the child's first jax call."""
    print_fn("\n== per-layer gather/compute overlap "
             "(zero_topo, qwen2-0.5b reduced, 8 fake CPU devices) ==")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    # invoke by file path, not -m: the benchmarks dir isn't an installed
    # package and -m would silently depend on the parent's cwd
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--overlap-probe"],
        capture_output=True, text=True, timeout=900, env=env)
    if r.returncode != 0:
        print_fn("probe failed:\n" + (r.stdout + r.stderr)[-2000:])
        raise RuntimeError("overlap probe subprocess failed")
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    for key in ("overlap=False", "overlap=True"):
        m = rec[key]
        print_fn(f"  {key:14s} fwd step {m['step_ms']:7.2f} ms  "
                 f"per-layer {m['per_layer_ms']:6.2f} ms  "
                 f"all-gathers {m['all_gather_count']:3d}  "
                 f"gather wire {m['all_gather_wire_mb']:.3f} MB  "
                 f"loss {m['loss']:.6f}")
    off, on = rec["overlap=False"], rec["overlap=True"]
    same_comm = (off["all_gather_count"] == on["all_gather_count"]
                 and abs(off["all_gather_wire_mb"]
                         - on["all_gather_wire_mb"]) < 1e-9)
    print_fn(f"  -> comm volume identical: {same_comm}; losses bitwise equal: "
             f"{off['loss'] == on['loss']}. Overlap changes only the "
             "schedule (gather issued one layer ahead); CPU fake devices "
             "serialize collectives, so the wall-clock win appears on real "
             "accelerators with async collectives.")
    assert same_comm and off["loss"] == on["loss"]


def _overlap_probe_main():
    """Child half of overlap_probe: runs with 8 fake devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch import hlo
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.registry import build_model, get_arch

    jax.config.update("jax_default_matmul_precision", "float32")
    ax = ("data", "node", "gcd")
    mesh = make_test_mesh()
    arch = get_arch("qwen2-0.5b").reduced(n_layers=N_LAYERS, d_model=128,
                                          vocab=256)
    model = build_model(arch)
    rng = np.random.default_rng(0)
    batch_np = rng.integers(0, arch.vocab, (8, 33), dtype=np.int32)
    out = {}
    for overlap in (False, True):
        cfg = scheme_config("zero_topo", mesh, quant_block=64,
                            overlap=overlap, compute_dtype="float32")
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
        ev = eng.make_eval_step(model.loss_fn(), {"tokens": P(ax)})
        state = eng.init_state(jax.random.key(0))
        batch = {"tokens": jax.device_put(jnp.asarray(batch_np),
                                          NamedSharding(mesh, P(ax)))}
        loss = float(ev(state, batch))
        dt = _time(ev, state, batch, iters=3)
        census = hlo.analyze(
            ev.lower(state, batch).compile().as_text()).summary()
        out[f"overlap={overlap}"] = dict(
            loss=loss, step_ms=dt * 1e3, per_layer_ms=dt * 1e3 / N_LAYERS,
            all_gather_count=int(
                census["collective_counts"].get("all-gather", 0)),
            all_gather_wire_mb=census["wire_bytes"].get("all-gather", 0.0)
            / 1e6)
    print(json.dumps(out))


if __name__ == "__main__":
    if "--overlap-probe" in sys.argv:
        _overlap_probe_main()
    else:
        run()
