"""Paper Figs 9 & 10: loss curves with all quantization enabled (ZeRO-topo)
vs standard ZeRO-3 — real training on CPU at reduced scale, same data/init.

Pass criterion mirrors the paper's claim: final evaluation loss within ~1-2%.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import TrainHparams, ZeroEngine
from repro.data.pipeline import BatchSpec, SyntheticTokens
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.registry import build_model, get_arch

STEPS = 120


def train_curve(scheme: str, quant: bool, steps: int = STEPS,
                arch_name: str = "gpt-neox-20b") -> list[float]:
    mesh = make_test_mesh(shape=(1, 1, 1), axes=("data", "node", "gcd"))
    arch = get_arch(arch_name).reduced(n_layers=2, d_model=128, vocab=512)
    model = build_model(arch)
    cfg = scheme_config(scheme, mesh, quant_block=64, compute_dtype="float32")
    cfg = dataclasses.replace(cfg, quantize_weights=quant,
                              quantize_grads=quant)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=steps,
                                  warmup_steps=10))
    state = eng.init_state(jax.random.key(0))
    step = eng.make_train_step(model.loss_fn(), {"tokens": P()})
    data = SyntheticTokens(BatchSpec(4, 64, arch.vocab), seed=0)
    losses = []
    for i in range(steps):
        b = data.batch(i)
        state, m = step(state, {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    return losses


def run(print_fn=print, steps: int = STEPS):
    exact = train_curve("zero3", quant=False, steps=steps)
    topo = train_curve("zero_topo", quant=True, steps=steps)
    print_fn(f"\n== Figs 9/10 analogue: ZeRO-topo (INT8 W / INT4 g) vs "
             f"ZeRO-3 exact, {steps} steps ==")
    for i in range(0, steps, max(steps // 8, 1)):
        print_fn(f"  step {i:4d}  zero3 {exact[i]:.4f}  topo-quant "
                 f"{topo[i]:.4f}  rel {abs(exact[i]-topo[i])/exact[i]*100:5.2f}%")
    final_rel = abs(exact[-1] - topo[-1]) / exact[-1]
    print_fn(f"final: zero3 {exact[-1]:.4f} vs topo {topo[-1]:.4f} "
             f"({final_rel * 100:.2f}% apart; paper claims ~1%)")
    assert exact[-1] < exact[0] * 0.8, "reference run failed to learn"
    out = Path("experiments")
    out.mkdir(exist_ok=True)
    (out / "convergence.json").write_text(json.dumps(
        dict(zero3=exact, zero_topo_quant=topo)))
    return final_rel < 0.05


if __name__ == "__main__":
    run()
