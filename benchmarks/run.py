"""Benchmark harness entry (deliverable (d)): one benchmark per paper
table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only comm_volume,memory_table
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ["memory_table", "comm_volume", "scaling_model", "plan_table",
           "quant_error", "kernel_micro", "convergence", "serve_load"]
PAPER_ARTIFACT = dict(
    memory_table="Tables V/VI + §II max-model-size",
    comm_volume="Tables VII/VIII",
    scaling_model="Figs 7/8 (TFLOPS per GPU, scaling efficiency)",
    plan_table="Tables IV/V generalized: planner choice vs presets",
    quant_error="§III-C block-based quantization",
    kernel_micro="kernel-level roofline",
    convergence="Figs 9/10 (loss curves, quantized vs exact)",
    serve_load="wire-format serving: INT8-resident decode vs fp gather "
               "under an SLO request storm (DESIGN.md §12)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--skip", default="")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    names = [n for n in names if n not in args.skip.split(",")]

    import importlib
    failures = []
    for name in names:
        print(f"\n{'=' * 72}\nBENCH {name}  [{PAPER_ARTIFACT[name]}]\n{'=' * 72}",
              flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            ok = mod.run()
            print(f"[{name}] {'PASS' if ok else 'CHECK'} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAIL ({time.time() - t0:.1f}s)", flush=True)
    if failures:
        print(f"\nFAILED: {failures}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
