"""Paper Tables IV/V-style scheme comparison for ANY topology, plus the
planner's automatic choice (the "targeted strategy" generalized).

For each hand-written preset and the planner's top-ranked scheme, prints the
sharding-degree row (Table IV), the per-device memory row (Table V/VI
formulas) and the predicted per-phase communication seconds / step time /
TFLOPS from the shared cost model (``repro.topo.cost``), then asserts the
planner's choice is never slower than any preset (it searches a superset).

    PYTHONPATH=src python -m benchmarks.plan_table                 # frontier
    PYTHONPATH=src python -m benchmarks.plan_table --topology my.json
    PYTHONPATH=src python -m benchmarks.plan_table --quick         # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

from repro.core.partition import sharding_factor_table
from repro.topo.cost import PHASES, Workload, step_cost
from repro.topo.model import load_topology
from repro.topo.planner import Plan, model_workload, plan, preset_on_topology

PRESETS = ("zero3", "zeropp", "zero_topo")
GB = 1e9


def _bench_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_plan.json"


def _plan_record(topo, wl, rows, ranked) -> dict:
    """The baseline-gated record: the planner's chosen scheme (axes,
    degrees, quant switches) and every row's predicted step seconds — all
    deterministic cost-model arithmetic, no wall clock anywhere."""
    auto = rows["auto (planner)"]
    t = sharding_factor_table(auto.cfg)
    return dict(
        topology=topo.name,
        workload=dict(psi=wl.psi, n_layers=wl.n_layers),
        n_schemes_searched=len(ranked),
        choice=dict(
            label=auto.label,
            weights=t["weights"], grads=t["grads"],
            optimizer=t["optimizer"], secondary=t["secondary"],
            int8_weights=bool(auto.cfg.quantize_weights),
            int4_grads=bool(auto.cfg.quantize_grads),
            step_s=auto.step_s,
        ),
        presets={name: dict(step_s=rows[name].step_s,
                            fits=bool(rows[name].cost.fits))
                 for name in PRESETS},
    )


def build_rows(topo, wl: Workload, budget: float | None):
    rows: dict[str, Plan] = {}
    for scheme in PRESETS:
        cfg = preset_on_topology(scheme, topo)
        c = step_cost(cfg, topo, wl, memory_budget=budget)
        rows[scheme] = Plan(cfg, c, c.step_s(wl.hidden_fraction))
    ranked = plan(topo, wl, memory_budget=budget)
    rows["auto (planner)"] = ranked[0]
    return rows, ranked


def print_tables(topo, wl, rows, print_fn=print):
    print_fn(f"topology: {topo.name}  [" + ", ".join(
        f"{l.name}({l.size}) {l.bandwidth / 1e9:.0f}GB/s/{l.latency * 1e6:.0f}us"
        for l in topo.links) + f"]  {topo.n_devices} devices, "
        f"psi={wl.psi / 1e9:.1f}B")

    print_fn("\n-- Table IV: sharding degrees --")
    print_fn(f"{'scheme':16s} {'weights':>8s} {'grads':>8s} {'optim':>8s} "
             f"{'sec':>8s}")
    for name, p in rows.items():
        t = sharding_factor_table(p.cfg)
        print_fn(f"{name:16s} {t['weights']:8d} {t['grads']:8d} "
                 f"{t['optimizer']:8d} {t['secondary']:8d}")

    print_fn("\n-- Tables V/VI: per-device state memory --")
    print_fn(f"{'scheme':16s} {'weights':>9s} {'grads':>9s} {'optim':>9s} "
             f"{'total':>9s} {'fits':>5s}")
    for name, p in rows.items():
        m = p.cost.memory
        print_fn(f"{name:16s} {m['weights'] / GB:8.2f}G {m['grads'] / GB:8.2f}G "
                 f"{m['optimizer'] / GB:8.2f}G {m['total'] / GB:8.2f}G "
                 f"{'y' if p.cost.fits else 'NO':>5s}")

    print_fn("\n-- predicted communication seconds per step (cost model) --")
    print_fn(f"{'scheme':16s}" + "".join(f" {ph[:9]:>9s}" for ph in PHASES)
             + f" {'comm':>8s} {'step':>8s} {'TFLOPS':>7s}")
    for name, p in rows.items():
        tokens = wl.n_microbatch * wl.tokens_per_device_mb
        tf = 6.0 * wl.psi * tokens / p.step_s / 1e12
        print_fn(f"{name:16s}" + "".join(
            f" {p.cost.comm_s[ph]:9.3f}" for ph in PHASES)
            + f" {p.cost.comm_total_s:8.3f} {p.step_s:8.3f} {tf:7.1f}")


def run(print_fn=print, topology: str = "frontier",
        model: str = "gpt-neox-20b", quick: bool = False,
        budget_gb: float = 0.0, stream_grads: bool = False,
        gcds: int = 0):
    import dataclasses
    if gcds:
        # scale the frontier preset to any GCD count (8 per node) — the
        # scaling_model sweep's 64..1536 range, one table per scale
        from repro.topo.model import frontier
        if topology != "frontier":
            raise SystemExit("--gcds only rescales the frontier preset")
        if gcds % 8:
            raise SystemExit(f"--gcds {gcds} not divisible by 8 GCDs/node")
        topo = frontier(gcds // 8)
    else:
        topo = load_topology(topology)
    wl = model_workload(model) if not quick else Workload(psi=20e9)
    if stream_grads:
        # streaming grad regime (DESIGN.md §8): per-layer grad RS inside
        # the backward, grad memory at os layout. Not used by --quick: the
        # CI gate pins the seed-regime record.
        wl = dataclasses.replace(wl, stream_grads=True)
    budget = budget_gb * GB if budget_gb else None
    rows, ranked = build_rows(topo, wl, budget)
    print_tables(topo, wl, rows, print_fn)

    auto = rows["auto (planner)"]
    print_fn(f"\nplanner searched {len(ranked)} schemes; choice: {auto.label}")
    for name in PRESETS:
        # feasibility first: a preset outside the memory budget may be
        # "faster" on paper but the planner rightly ranks fitting plans ahead
        assert (not auto.cost.fits, auto.step_s) <= \
            (not rows[name].cost.fits, rows[name].step_s), \
            f"planner choice ranks below preset {name}: " \
            f"{auto.step_s} > {rows[name].step_s}"
        note = "" if rows[name].cost.fits else "  (preset over budget)"
        print_fn(f"  vs {name:10s}: {rows[name].step_s / auto.step_s:5.2f}x "
                 f"predicted speedup{note}")
    if not quick:
        # paper Table V sweep: secondary degree column on the frontier preset
        print_fn("\n-- planner top-5 (the searched space, ranked) --")
        for r, p in enumerate(ranked[:5], 1):
            print_fn(f"  {r}. step {p.step_s:.3f}s  mem "
                     f"{p.cost.memory_total / GB:.1f}G  {p.label}")
    if quick:
        # the CI bench-gate diffs this record against the committed
        # baseline: a planner/cost-model change that silently flips the
        # chosen scheme fails check_baseline until the baseline is updated
        # in the same PR. Only the --quick (fixed 20B) workload is gated —
        # a full run would record a different psi and spuriously trip the
        # gate against the committed --quick baseline.
        rec = _plan_record(topo, wl, rows, ranked)
        _bench_path().write_text(json.dumps(rec, indent=1))
        print_fn(f"\nwrote {_bench_path()}")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="frontier",
                    help="preset name (frontier/gpu_pod/tpu) or JSON path")
    ap.add_argument("--model", default="gpt-neox-20b")
    ap.add_argument("--budget-gb", type=float, default=0.0,
                    help="per-device memory budget; 0 = topology HBM")
    ap.add_argument("--quick", action="store_true",
                    help="skip model construction (fixed 20B workload) — "
                         "the CI gate")
    ap.add_argument("--stream-grads", action="store_true",
                    help="price the streaming grad regime (DESIGN.md §8)")
    ap.add_argument("--gcds", type=int, default=0,
                    help="rescale the frontier topology to this GCD count "
                         "(8/node; the scaling sweep's 64..1536 range)")
    args = ap.parse_args()
    run(topology=args.topology, model=args.model, quick=args.quick,
        budget_gb=args.budget_gb, stream_grads=args.stream_grads,
        gcds=args.gcds)


if __name__ == "__main__":
    main()
