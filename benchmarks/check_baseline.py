"""Benchmark regression gate: diff the freshly-emitted BENCH_*.json against
the committed baselines in ``benchmarks/baselines/``.

    PYTHONPATH=src python -m benchmarks.check_baseline \
        [--emitted .] [--baselines benchmarks/baselines]

Only *invariant* fields are gated — collective counts, wire bytes, analytic
comm volumes, the fused/unfused roofline arithmetic, and the planner's
chosen scheme + predicted step seconds on the CI reference workload
(BENCH_plan.json, pure cost-model arithmetic). Wall-clock fields are
recorded in the JSONs for trend inspection but never compared (CI machines
are noisy).

Exit code != 0 lists every regressed field. To intentionally move a
baseline (e.g. a scheme change that legitimately alters the gather count),
re-run the benchmarks and copy the emitted files over
``benchmarks/baselines/`` in the same PR that changes the behavior.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

RTOL = 1e-6

# dotted paths into each BENCH file that must not drift. A trailing ".*"
# compares the whole subtree (dict/list/scalar) with float tolerance.
GATED = {
    # (the probes' *_identical flags are deliberately not gated: when they
    # are False the benchmark run itself asserts before emitting the JSON,
    # so only the raw census numbers carry baseline signal)
    "BENCH_kernels.json": [
        "roofline.*",
        "overlap_probe.overlap=False.all_gather_count",
        "overlap_probe.overlap=False.all_gather_wire_mb",
        "overlap_probe.overlap=True.all_gather_count",
        "overlap_probe.overlap=True.all_gather_wire_mb",
        "impl_census.jnp.collective_counts.*",
        "impl_census.jnp.wire_bytes.*",
        "impl_census.pallas_interpret.collective_counts.*",
        "impl_census.pallas_interpret.wire_bytes.*",
        # streaming grad path (DESIGN.md §8): both regimes' full train-step
        # collective inventory, pinned — the probe itself asserts the
        # grad-RS wire bytes are identical across regimes before emitting
        "grad_rs_census.stream=False.collective_counts.*",
        "grad_rs_census.stream=False.wire_bytes.*",
        "grad_rs_census.stream=True.collective_counts.*",
        "grad_rs_census.stream=True.wire_bytes.*",
    ],
    "BENCH_comm_volume.json": [
        "zero3.*", "zeropp.*", "zero_topo.*", "invariants.*",
        "cost_model_crosscheck", "overlap_volume_invariant",
    ],
    # the planner's chosen scheme on the CI reference workload
    # (plan_table --quick): identity + predicted step seconds are pure
    # cost-model arithmetic, so ANY drift is a planner/cost change that
    # must ship with an updated baseline
    "BENCH_plan.json": [
        "topology", "workload.*", "n_schemes_searched",
        "choice.*", "presets.*",
    ],
    # predicted scaling curve 64->1536 GCDs (benchmarks/scaling_model.py
    # --quick): TFLOPS/GCD and efficiency-vs-64 per scheme, pure cost-model
    # arithmetic pinned against the paper's 0.94 at 384 GCDs (the emitter
    # asserts the tolerance before writing, so the gate pins exact values)
    "BENCH_scaling.json": [
        "workload.*", "scales_gcds", "tflops_per_gpu.*",
        "efficiency_vs_64.*", "efficiency_at_384.*", "ratios_at_384.*",
        "paper.*",
    ],
    # per-device memory accounting (benchmarks/memory_table.py): pure byte
    # arithmetic from partition.py's shared formulas — any drift is a
    # memory-model change (engine memory_report uses the same functions,
    # cross-checked by tests/test_stream_grads.py)
    "BENCH_memory.json": [
        "paper_table.*", "engine.*", "max_model_2nodes.*", "max_model_tpu.*",
    ],
    # comm-contract verifier census (repro.analysis.check --grid): the
    # schedule-tag counts and the per-tier/per-dtype collective inventory of
    # the compiled train step across the overlap x stream-grads matrix —
    # any drift is a schedule or wire-format change that must ship with an
    # updated baseline (emitted by the `analysis` CI leg, not bench-gate)
    "BENCH_contracts.json": [
        "model", "scheme", "n_microbatch", "census.*",
    ],
    # observability structure (repro.obs.calibrate --quick, the `obs` CI
    # leg): the schedule-site span census, the phased step's segment and
    # phase inventories, the probe leaf lists and the metrics JSONL schema.
    # All deterministic structure — wall-clock never appears in this file,
    # so any drift is a schedule/obs contract change, not machine noise
    "BENCH_obs.json": [
        "model", "scheme", "span_census.*", "segments.*", "phases.*",
        "probe_inventory.*", "jsonl_schema.*",
    ],
    # serving load generator (benchmarks/serve_load.py --quick, the `serve`
    # CI leg): residency layout, paged-pool geometry, the SLO storm's
    # admission/rejection/preemption census (pure step-count arithmetic),
    # the serve JSONL schema and the fused-dispatch proof. The throughput.*
    # subtree is wall-clock and deliberately NOT listed here — the emitter
    # itself asserts resident >= gathered before writing
    "BENCH_serve.json": [
        "model", "scheme", "n_slots", "prompt_len", "max_len",
        "residency.*", "pool.*", "slo.*", "storm.*", "dispatch.*",
        "jsonl_schema.*",
    ],
}


def _lookup(tree, path: str):
    cur = tree
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def _diff(base, new, path: str, out: list[str]):
    if isinstance(base, dict):
        if not isinstance(new, dict):
            out.append(f"{path}: dict -> {type(new).__name__}")
            return
        for k in base:
            if k not in new:
                out.append(f"{path}.{k}: missing in emitted")
            else:
                _diff(base[k], new[k], f"{path}.{k}", out)
        for k in new:
            if k not in base:
                out.append(f"{path}.{k}: new field (update the baseline)")
    elif isinstance(base, list):
        if not isinstance(new, list) or len(base) != len(new):
            out.append(f"{path}: list shape changed {base!r} -> {new!r}")
            return
        for i, (b, n) in enumerate(zip(base, new)):
            _diff(b, n, f"{path}[{i}]", out)
    elif isinstance(base, (int, float)) and isinstance(new, (int, float)) \
            and not isinstance(base, bool) and not isinstance(new, bool):
        if abs(float(base) - float(new)) > RTOL * max(abs(float(base)), 1.0):
            out.append(f"{path}: {base!r} -> {new!r}")
    elif base != new:
        out.append(f"{path}: {base!r} -> {new!r}")


def check_file(baseline: Path, emitted: Path) -> list[str]:
    problems: list[str] = []
    if not emitted.exists():
        return [f"{emitted}: not emitted (benchmark did not run?)"]
    base = json.loads(baseline.read_text())
    new = json.loads(emitted.read_text())
    for spec in GATED[baseline.name]:
        path = spec[:-2] if spec.endswith(".*") else spec
        try:
            b = _lookup(base, path)
        except KeyError:
            problems.append(f"{baseline.name}:{path}: missing in baseline "
                            "(re-seed benchmarks/baselines/)")
            continue
        try:
            n = _lookup(new, path)
        except KeyError:
            problems.append(f"{baseline.name}:{path}: missing in emitted")
            continue
        local: list[str] = []
        _diff(b, n, f"{baseline.name}:{path}", local)
        problems.extend(local)
    return problems


# legs emit disjoint file sets (bench-gate: kernels/comm/plan/memory/
# scaling; analysis: contracts), so each passes --files for what it ran
_BENCH_GATE_FILES = ("BENCH_kernels.json", "BENCH_comm_volume.json",
                     "BENCH_plan.json", "BENCH_memory.json",
                     "BENCH_scaling.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--emitted", default=".",
                    help="directory holding the freshly-written BENCH_*.json")
    ap.add_argument("--baselines", default="benchmarks/baselines")
    ap.add_argument("--files", default=",".join(_BENCH_GATE_FILES),
                    help="comma-separated BENCH file names to gate "
                         "(default: the bench-gate leg's four)")
    args = ap.parse_args()
    emitted = Path(args.emitted)
    baselines = Path(args.baselines)
    names = [n for n in args.files.split(",") if n]
    unknown = [n for n in names if n not in GATED]
    if unknown:
        sys.exit(f"no gate spec for {', '.join(unknown)} "
                 f"(known: {', '.join(sorted(GATED))})")

    problems: list[str] = []
    for name in names:
        b = baselines / name
        if not b.exists():
            problems.append(f"{b}: baseline missing (seed it from an "
                            "emitted run)")
            continue
        problems.extend(check_file(b, emitted / name))

    if problems:
        print("BENCHMARK REGRESSIONS vs committed baseline:")
        for p in problems:
            print(f"  {p}")
        sys.exit(1)
    print(f"benchmark baselines OK ({', '.join(sorted(names))})")


if __name__ == "__main__":
    main()
