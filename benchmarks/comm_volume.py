"""Paper Tables VII & VIII: analytic per-step communication volume per scheme,
validated against the wire-byte census of the compiled dry-run when
experiments/dryrun JSONs are present.

The formulas here are deliberately written scheme-by-scheme and kept
*independent* of the general cost model in ``repro.topo.cost`` — ``run()``
(and tests/test_topo.py) cross-checks the two implementations phase by
phase, so a regression in either one is caught by the other.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.partition import preset

GB = 1e9
PHASE_KEYS = ("fwd_allgather", "bwd_allgather", "grad_rs", "cross_replica",
              "update_gather", "total")

# bandwidth tiers (B/s): paper's Frontier numbers and the TPU adaptation
FRONTIER = dict(l0=200e9, intra=50e9, inter=25e9)
TPU = dict(l0=50e9, intra=50e9, inter=50e9 / 4)    # ICI hops vs DCI-ish


def analytic_volumes(scheme: str, psi: int, n_nodes: int,
                     gcds_per_node: int = 8, overlap: bool = False) -> dict:
    """Bytes per device per step for each phase (paper Tables VII/VIII).

    ``overlap`` selects the double-buffered gather schedule (DESIGN.md §3).
    It is schedule-only: the overlapped layer loop issues exactly one gather
    per leaf per layer (prologue + per-step issue + epilogue consume), so
    every volume below is identical for both settings — the returned dict
    just records which schedule was asked for. kernel_micro's census probe
    validates this on compiled HLO.
    """
    sizes = {"data": n_nodes, "node": gcds_per_node // 2, "gcd": 2}
    cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                 l0_axes=("gcd",), axis_sizes=sizes, overlap=overlap)
    w_bytes = psi / cfg.w_degree * (1 if cfg.quantize_weights else 2)
    dw = cfg.w_degree
    ds = cfg.sec_degree or dw
    # forward all-gather of the primary (volume per device ~ shard * (d-1))
    fwd = w_bytes * (dw - 1)
    # backward gather: secondary (INT8) over sec group, else primary again
    if cfg.axes.secondary is not None:
        bwd = psi / ds * (ds - 1)
    else:
        bwd = fwd
    # gradient reduce-scatter over grad group (INT4 if quantized, else fp16)
    dg = cfg.g_degree
    g_bytes = psi * (0.5 if cfg.quantize_grads else 2)
    grs = g_bytes * (dg - 1) / dg
    # cross-replica allreduce of grad shards over R
    dr = cfg.size(cfg.axes.replica)
    crs = 2 * (2 * psi / dg) * (dr - 1) / dr if dr > 1 else 0.0
    # update all-gather over E+R (bf16)
    dos = cfg.os_degree
    upd = (2 * psi / cfg.w_degree) * (1 - cfg.w_degree / dos)
    return dict(fwd_allgather=fwd, bwd_allgather=bwd, grad_rs=grs,
                cross_replica=crs, update_gather=upd,
                total=fwd + bwd + grs + crs + upd,
                schedule="double-buffered" if overlap else "serial",
                degrees=dict(w=dw, sec=ds, g=dg, os=dos))


def run(print_fn=print):
    rec = {}
    psi = 20e9
    n_nodes = 48
    print_fn("\n== Paper Tables VII/VIII: per-device comm volume per step "
             "(psi=20B, 48 nodes x 8 GCDs) ==")
    print_fn(f"{'scheme':10s} {'fwd AG':>9s} {'bwd AG':>9s} {'grad RS':>9s} "
             f"{'x-replica':>9s} {'update':>9s} {'total':>9s}")
    for scheme in ("zero3", "zeropp", "zero_topo"):
        v = analytic_volumes(scheme, psi, n_nodes)
        rec[scheme] = {k: v[k] for k in PHASE_KEYS}
        rec[scheme]["degrees"] = v["degrees"]
        print_fn(f"{scheme:10s} " + " ".join(
            f"{v[k] / GB:8.1f}G" for k in PHASE_KEYS))
    print_fn("\nkey paper claims encoded here:")
    v3 = analytic_volumes("zero3", psi, n_nodes)
    vp = analytic_volumes("zeropp", psi, n_nodes)
    vt = analytic_volumes("zero_topo", psi, n_nodes)
    rec["invariants"] = dict(
        zeropp_fwd_over_zero3=vp["fwd_allgather"] / v3["fwd_allgather"],
        topo_grad_rs_over_zero3=vt["grad_rs"] / v3["grad_rs"],
        topo_fwd_degree=vt["degrees"]["w"])
    print_fn(f"  zero++ fwd AG is 0.5x of zero3 (INT8): "
             f"{vp['fwd_allgather'] / v3['fwd_allgather']:.3f}")
    print_fn(f"  topo fwd AG devices = 2 (constant in scale): degrees "
             f"{vt['degrees']}")
    print_fn(f"  topo grad RS volume = 0.25x zero3 (INT4): "
             f"{vt['grad_rs'] / v3['grad_rs']:.3f}")

    print_fn("\n== cross-check vs the planner's cost model (repro.topo.cost) ==")
    from repro.topo.cost import phase_volumes
    for scheme in ("zero3", "zeropp", "zero_topo"):
        sizes = {"data": n_nodes, "node": 4, "gcd": 2}
        cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                     l0_axes=("gcd",), axis_sizes=sizes)
        mine = analytic_volumes(scheme, psi, n_nodes)
        theirs = phase_volumes(cfg, psi)
        # cost.py splits the grad RS into its two real stages (W per
        # backward, E per step); the byte counts telescope to one figure
        pairs = {k: theirs[k] for k in ("fwd_allgather", "bwd_allgather",
                                        "cross_replica", "update_gather",
                                        "total")}
        pairs["grad_rs"] = theirs["grad_rs_w"] + theirs["grad_rs_e"]
        for k, v in pairs.items():
            assert abs(mine[k] - v) <= 1e-6 * max(mine[k], 1.0), \
                (scheme, k, mine[k], v)
        print_fn(f"  {scheme:10s} all five phases + total agree "
                 f"(total {theirs['total'] / GB:.1f}G)")
    rec["cost_model_crosscheck"] = True

    print_fn("\n== overlap schedule (DESIGN.md \u00a73): volume-invariance ==")
    for scheme in ("zero3", "zeropp", "zero_topo"):
        off = analytic_volumes(scheme, psi, n_nodes, overlap=False)
        on = analytic_volumes(scheme, psi, n_nodes, overlap=True)
        assert all(off[k] == on[k] for k in
                   ("fwd_allgather", "bwd_allgather", "grad_rs",
                    "cross_replica", "update_gather", "total")), (off, on)
        print_fn(f"  {scheme:10s} total {off['total'] / GB:6.1f}G "
                 f"({off['schedule']}) == {on['total'] / GB:6.1f}G "
                 f"({on['schedule']})  -> identical; overlap moves the "
                 "per-layer gather off the critical path, it sends no "
                 "extra bytes")
    rec["overlap_volume_invariant"] = True

    # cross-check against compiled dry-run census when available
    d = Path("experiments/dryrun")
    files = sorted(d.glob("*__train_4k__prod__*.json")) if d.exists() else []
    if files:
        print_fn("\n== measured (compiled-HLO census) vs analytic, prod mesh ==")
        for f in files[:12]:
            dr = json.loads(f.read_text())
            wire = dr["census"]["total_wire_bytes"]
            print_fn(f"  {dr['arch']:24s} {dr['scheme']:10s} "
                     f"wire {wire / GB:7.2f} GB/device/step  "
                     f"counts {dr['census']['collective_counts']}")

    out = Path(os.environ.get("REPRO_BENCH_DIR", ".")) \
        / "BENCH_comm_volume.json"
    out.write_text(json.dumps(rec, indent=1))
    print_fn(f"\nwrote {out}")
    return True


if __name__ == "__main__":
    run()
