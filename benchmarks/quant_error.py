"""Block-based quantization error vs block size (§III-C, Dettmers et al.):
smaller blocks isolate outliers -> lower error; INT4 vs INT8 gap."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops


def run(print_fn=print):
    n = 1 << 16
    rng = jax.random.key(0)
    # heavy-tailed weights (realistic): normal + 1% outliers x10
    x = jax.random.normal(rng, (n,))
    mask = jax.random.uniform(jax.random.key(1), (n,)) < 0.01
    x = jnp.where(mask, x * 10.0, x)

    print_fn("\n== quantization RMSE vs block size (Dettmers block-based) ==")
    print_fn(f"{'block':>8s} {'INT8 rmse':>12s} {'INT4 rmse':>12s} "
             f"{'scales overhead':>16s}")
    for block in (64, 256, 1024, 4096, 16384):
        q8, s8 = ops.quantize_int8(x, block)
        d8 = ops.dequantize_int8(q8, s8, block)
        q4, s4 = ops.quantize_int4(x, block)
        d4 = ops.dequantize_int4(q4, s4, block)
        r8 = float(jnp.sqrt(jnp.mean((d8 - x) ** 2)))
        r4 = float(jnp.sqrt(jnp.mean((d4 - x) ** 2)))
        overhead = 4.0 / block          # f32 scale per block, per element
        print_fn(f"{block:8d} {r8:12.5f} {r4:12.5f} {overhead * 100:15.2f}%")
    # smaller blocks must not be worse
    q8a, s8a = ops.quantize_int8(x, 64)
    q8b, s8b = ops.quantize_int8(x, 16384)
    ra = float(jnp.sqrt(jnp.mean((ops.dequantize_int8(q8a, s8a, 64) - x) ** 2)))
    rb = float(jnp.sqrt(jnp.mean(
        (ops.dequantize_int8(q8b, s8b, 16384) - x) ** 2)))
    assert ra < rb, "block-quantization error should shrink with block size"
    return True


if __name__ == "__main__":
    run()
