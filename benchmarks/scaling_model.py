"""Paper Figs 7 & 8: modeled TFLOPS-per-GPU and scaling efficiency across
scales for ZeRO-3 / ZeRO++ / ZeRO-topo on the Frontier bandwidth tiers.

This benchmark is now a thin consumer of the shared analytic cost model
(``repro.topo.cost`` on the ``repro.topo.model.frontier`` topology) — the
same model the partition planner searches with, so every number printed here
is a number the planner ranks by (one cost model, two consumers).  The
structure is the paper's argument:

  * per-microbatch collectives (fwd/bwd weight all-gather, gradient RS) pay
    volume/tier-bandwidth + n_layers x (group-1) x per-hop ring latency —
    ZeRO-topo pins the group sizes (2 / 8) so this term is CONSTANT in
    cluster size, while ZeRO-3/ZeRO++ groups grow with scale;
  * once-per-step collectives (cross-replica grad sync, update all-gather)
    amortize over gradient accumulation.

Reported: the scheme ratios the paper measures — ZeRO++/ZeRO-3 (+40.5%),
topo/ZeRO++ (+70.7%), topo/ZeRO-3 (+139.8%) at 384 GCDs — and scaling
efficiency (paper: 0.94 for topo 64->384), extended past the paper's
largest measured point to 1536 GCDs (the elastic-restore regime: the same
run can actually move between these scales, DESIGN.md §11).

Emits BENCH_scaling.json (gated by check_baseline.py): the full predicted
TFLOPS/GCD and efficiency curves, pure cost-model arithmetic — and asserts
the predicted zero_topo efficiency at 384 GCDs is within tolerance of the
paper's 0.94 before emitting.

    PYTHONPATH=src python -m benchmarks.scaling_model [--quick]

``--quick`` emits the gated record without the Fig 7/8 tables (CI).
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.topo.cost import Workload, step_cost, tflops_per_device
from repro.topo.model import frontier
from repro.topo.planner import preset_on_topology

MICRO_BATCHES = 4
TOKENS_PER_GCD_MB = 2048   # per-microbatch tokens per GCD
N_LAYERS = 44

SCHEMES = ("zero3", "zeropp", "zero_topo")
# paper Figs 7/8 measure 64..384; the sweep extends to 1536 (192 nodes)
# where the constant-group-size argument is starkest
SWEEP_GCDS = (64, 128, 192, 256, 384, 512, 768, 1024, 1536)
PAPER_EFFICIENCY_384 = 0.94
EFFICIENCY_TOL = 0.05      # |predicted - paper| at 384 GCDs, zero_topo


def _workload(psi: float, n_layers: int = N_LAYERS) -> Workload:
    return Workload(psi=psi, n_layers=n_layers,
                    tokens_per_device_mb=TOKENS_PER_GCD_MB,
                    n_microbatch=MICRO_BATCHES)


def step_time(scheme: str, psi: float, n_nodes: int,
              n_layers: int = N_LAYERS) -> tuple[float, float]:
    """(compute seconds, communication seconds) for one step."""
    topo = frontier(n_nodes)
    cfg = preset_on_topology(scheme, topo)
    c = step_cost(cfg, topo, _workload(psi, n_layers))
    return c.compute_s, c.comm_total_s


def tflops_per_gpu(scheme: str, psi: float, n_nodes: int) -> float:
    topo = frontier(n_nodes)
    cfg = preset_on_topology(scheme, topo)
    # DeepSpeed prefetches all-gathers: 60% of comm hidden under compute
    # (Workload.hidden_fraction default; the repo's own overlap schedule §3)
    return tflops_per_device(cfg, topo, _workload(psi))


def scaling_record(psi: float = 20e9) -> dict:
    """The gated scaling-curve record: TFLOPS/GCD and efficiency-vs-64 for
    every scheme over SWEEP_GCDS, pinned against the paper's 0.94 at 384.
    Pure cost-model arithmetic — any drift is a cost/planner change that
    must ship with an updated baseline."""
    tflops = {s: [tflops_per_gpu(s, psi, g // 8) for g in SWEEP_GCDS]
              for s in SCHEMES}
    i384 = SWEEP_GCDS.index(384)
    eff = {s: [v / tflops[s][0] for v in tflops[s]] for s in SCHEMES}
    eff384 = {s: eff[s][i384] for s in SCHEMES}

    # the paper's headline number: 0.94 scaling efficiency for zero_topo
    # at 384 GCDs (64 -> 384). The analytic model must land within
    # tolerance or the record is not emitted.
    assert abs(eff384["zero_topo"] - PAPER_EFFICIENCY_384) <= EFFICIENCY_TOL, \
        (eff384["zero_topo"], PAPER_EFFICIENCY_384, EFFICIENCY_TOL)
    # paper trend at every swept scale, not just the measured endpoint
    for i, g in enumerate(SWEEP_GCDS):
        assert tflops["zero_topo"][i] > tflops["zeropp"][i] \
            > tflops["zero3"][i], (g, {s: tflops[s][i] for s in SCHEMES})
    # the constant-group-size argument: zero_topo must scale better than
    # both baselines out to the far end of the sweep
    assert eff["zero_topo"][-1] > max(eff["zeropp"][-1], eff["zero3"][-1])

    z3, zpp, topo = (tflops[s][i384] for s in SCHEMES)
    return dict(
        workload=dict(psi=psi, n_layers=N_LAYERS,
                      n_microbatch=MICRO_BATCHES,
                      tokens_per_device_mb=TOKENS_PER_GCD_MB,
                      topology="frontier"),
        scales_gcds=list(SWEEP_GCDS),
        tflops_per_gpu=tflops,
        efficiency_vs_64=eff,
        efficiency_at_384=eff384,
        ratios_at_384=dict(zeropp_over_zero3=zpp / z3,
                           topo_over_zeropp=topo / zpp,
                           topo_over_zero3=topo / z3),
        paper=dict(efficiency_at_384_zero_topo=PAPER_EFFICIENCY_384,
                   tolerance=EFFICIENCY_TOL,
                   ratios_at_384=dict(zeropp_over_zero3=1.41,
                                      topo_over_zeropp=1.71,
                                      topo_over_zero3=2.40)),
    )


def _bench_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_scaling.json"


def emit_record(print_fn=print) -> dict:
    rec = scaling_record()
    _bench_path().write_text(json.dumps(rec, indent=1))
    eff = rec["efficiency_vs_64"]["zero_topo"]
    print_fn(f"\n== scaling sweep {SWEEP_GCDS[0]}->{SWEEP_GCDS[-1]} GCDs "
             f"(20B, zero_topo) -> {_bench_path()} ==")
    print_fn("  " + "  ".join(f"{g}:{e:.3f}"
                              for g, e in zip(SWEEP_GCDS, eff)))
    print_fn(f"  efficiency at 384 GCDs: "
             f"{rec['efficiency_at_384']['zero_topo']:.4f} "
             f"(paper {PAPER_EFFICIENCY_384}, tol {EFFICIENCY_TOL})")
    return rec


def run(print_fn=print, quick: bool = False):
    if quick:
        emit_record(print_fn)
        return True
    for psi, label in ((20e9, "GPT-NeoX-20B (Fig 7)"),
                       (10e9, "GPT-NeoX-10B (Fig 8)")):
        print_fn(f"\n== modeled TFLOPS/GPU across scales — {label} ==")
        print_fn(f"{'GCDs':>6s}" + "".join(f" {s:>10s}" for s in
                                           ("zero3", "zeropp", "zero_topo")))
        scales = [64, 128, 192, 256, 384]
        base = {}
        for gcds in scales:
            row = [tflops_per_gpu(s, psi, gcds // 8)
                   for s in ("zero3", "zeropp", "zero_topo")]
            base[gcds] = row
            print_fn(f"{gcds:6d}" + "".join(f" {r:10.1f}" for r in row))
        z3, zpp, topo = base[384]
        print_fn(f"at 384 GCDs: zero++/zero3 = {zpp / z3:.2f}x "
                 f"(paper 1.41x), topo/zero++ = {topo / zpp:.2f}x "
                 f"(paper 1.71x), topo/zero3 = {topo / z3:.2f}x "
                 f"(paper 2.40x)")
        eff = {s: base[384][i] / base[64][i]
               for i, s in enumerate(("zero3", "zeropp", "zero_topo"))}
        print_fn("scaling efficiency 64->384 GCDs: " +
                 ", ".join(f"{k} {v:.2f}" for k, v in eff.items()) +
                 "  (paper: topo 0.94)")
        assert topo > zpp > z3, "paper trend must hold: topo > zero++ > zero3"
    emit_record(print_fn)
    return True


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="emit the gated BENCH_scaling.json only "
                         "(skip the Fig 7/8 tables)")
    args = ap.parse_args(argv)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
