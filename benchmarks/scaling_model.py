"""Paper Figs 7 & 8: modeled TFLOPS-per-GPU and scaling efficiency across
scales for ZeRO-3 / ZeRO++ / ZeRO-topo on the Frontier bandwidth tiers.

This benchmark is now a thin consumer of the shared analytic cost model
(``repro.topo.cost`` on the ``repro.topo.model.frontier`` topology) — the
same model the partition planner searches with, so every number printed here
is a number the planner ranks by (one cost model, two consumers).  The
structure is the paper's argument:

  * per-microbatch collectives (fwd/bwd weight all-gather, gradient RS) pay
    volume/tier-bandwidth + n_layers x (group-1) x per-hop ring latency —
    ZeRO-topo pins the group sizes (2 / 8) so this term is CONSTANT in
    cluster size, while ZeRO-3/ZeRO++ groups grow with scale;
  * once-per-step collectives (cross-replica grad sync, update all-gather)
    amortize over gradient accumulation.

Reported: the scheme ratios the paper measures — ZeRO++/ZeRO-3 (+40.5%),
topo/ZeRO++ (+70.7%), topo/ZeRO-3 (+139.8%) at 384 GCDs — and scaling
efficiency (paper: 0.94 for topo 64->384).
"""
from __future__ import annotations

from repro.topo.cost import Workload, step_cost, tflops_per_device
from repro.topo.model import frontier
from repro.topo.planner import preset_on_topology

MICRO_BATCHES = 4
TOKENS_PER_GCD_MB = 2048   # per-microbatch tokens per GCD
N_LAYERS = 44


def _workload(psi: float, n_layers: int = N_LAYERS) -> Workload:
    return Workload(psi=psi, n_layers=n_layers,
                    tokens_per_device_mb=TOKENS_PER_GCD_MB,
                    n_microbatch=MICRO_BATCHES)


def step_time(scheme: str, psi: float, n_nodes: int,
              n_layers: int = N_LAYERS) -> tuple[float, float]:
    """(compute seconds, communication seconds) for one step."""
    topo = frontier(n_nodes)
    cfg = preset_on_topology(scheme, topo)
    c = step_cost(cfg, topo, _workload(psi, n_layers))
    return c.compute_s, c.comm_total_s


def tflops_per_gpu(scheme: str, psi: float, n_nodes: int) -> float:
    topo = frontier(n_nodes)
    cfg = preset_on_topology(scheme, topo)
    # DeepSpeed prefetches all-gathers: 60% of comm hidden under compute
    # (Workload.hidden_fraction default; the repo's own overlap schedule §3)
    return tflops_per_device(cfg, topo, _workload(psi))


def run(print_fn=print):
    for psi, label in ((20e9, "GPT-NeoX-20B (Fig 7)"),
                       (10e9, "GPT-NeoX-10B (Fig 8)")):
        print_fn(f"\n== modeled TFLOPS/GPU across scales — {label} ==")
        print_fn(f"{'GCDs':>6s}" + "".join(f" {s:>10s}" for s in
                                           ("zero3", "zeropp", "zero_topo")))
        scales = [64, 128, 192, 256, 384]
        base = {}
        for gcds in scales:
            row = [tflops_per_gpu(s, psi, gcds // 8)
                   for s in ("zero3", "zeropp", "zero_topo")]
            base[gcds] = row
            print_fn(f"{gcds:6d}" + "".join(f" {r:10.1f}" for r in row))
        z3, zpp, topo = base[384]
        print_fn(f"at 384 GCDs: zero++/zero3 = {zpp / z3:.2f}x "
                 f"(paper 1.41x), topo/zero++ = {topo / zpp:.2f}x "
                 f"(paper 1.71x), topo/zero3 = {topo / z3:.2f}x "
                 f"(paper 2.40x)")
        eff = {s: base[384][i] / base[64][i]
               for i, s in enumerate(("zero3", "zeropp", "zero_topo"))}
        print_fn("scaling efficiency 64->384 GCDs: " +
                 ", ".join(f"{k} {v:.2f}" for k, v in eff.items()) +
                 "  (paper: topo 0.94)")
        assert topo > zpp > z3, "paper trend must hold: topo > zero++ > zero3"
    return True


if __name__ == "__main__":
    run()
