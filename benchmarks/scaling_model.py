"""Paper Figs 7 & 8: modeled TFLOPS-per-GPU and scaling efficiency across
scales for ZeRO-3 / ZeRO++ / ZeRO-topo on the Frontier bandwidth tiers.

CPU containers cannot measure wall-time TFLOPS, so this benchmark evaluates
an analytic latency model with the same structure the paper argues from:

  * per-microbatch collectives (fwd/bwd weight all-gather, gradient RS) pay
    volume/tier-bandwidth + (group-1) x per-hop ring latency — the paper's
    central point is that ZeRO-topo pins the group size (2 / 8) so this term
    is CONSTANT in cluster size, while ZeRO-3/ZeRO++ groups grow with scale;
  * once-per-step collectives (cross-replica grad sync, update all-gather)
    amortize over gradient accumulation.

Reported: the scheme ratios the paper measures — ZeRO++/ZeRO-3 (+40.5%),
topo/ZeRO++ (+70.7%), topo/ZeRO-3 (+139.8%) at 384 GCDs — and scaling
efficiency (paper: 0.94 for topo 64->384).
"""
from __future__ import annotations

from benchmarks.comm_volume import analytic_volumes

# Frontier per-GCD capabilities
PEAK = 135e12              # achievable matmul FLOP/s per GCD (70% of 191.5)
BW = dict(l0=200e9,        # GCD-GCD inside one MI250X
          intra=40e9,      # effective per-GCD intra-node
          inter=100e9 / 8)  # 4x Slingshot (100 GB/s) shared by 8 GCDs
HOP_LAT = dict(l0=2e-6, intra=4e-6, inter=15e-6)   # ring per-hop latency

MICRO_BATCHES = 4
TOKENS_PER_GCD_MB = 2048   # per-microbatch tokens per GCD


def _tier(scheme: str, phase: str) -> str:
    table = {
        "zero3": dict(fwd_allgather="inter", bwd_allgather="inter",
                      grad_rs="inter", cross_replica="inter",
                      update_gather="inter"),
        "zeropp": dict(fwd_allgather="inter", bwd_allgather="intra",
                       grad_rs="inter", cross_replica="inter",
                       update_gather="inter"),
        "zero_topo": dict(fwd_allgather="l0", bwd_allgather="intra",
                          grad_rs="intra", cross_replica="inter",
                          update_gather="inter"),
    }
    return table[scheme][phase]


def _group(scheme: str, phase: str, v: dict, n_nodes: int) -> int:
    d = v["degrees"]
    table = {
        "zero3": dict(fwd_allgather=d["w"], bwd_allgather=d["w"],
                      grad_rs=d["g"], cross_replica=1,
                      update_gather=1),
        "zeropp": dict(fwd_allgather=d["w"], bwd_allgather=d["sec"],
                       grad_rs=d["g"], cross_replica=1,
                       update_gather=1),
        "zero_topo": dict(fwd_allgather=d["w"], bwd_allgather=d["sec"],
                          grad_rs=d["g"], cross_replica=n_nodes,
                          update_gather=d["os"] // d["w"]),
    }
    return table[scheme][phase]


def step_time(scheme: str, psi: float, n_nodes: int,
              n_layers: int = 44) -> tuple[float, float]:
    v = analytic_volumes(scheme, psi, n_nodes)
    per_mb = 0.0
    for phase in ("fwd_allgather", "bwd_allgather", "grad_rs"):
        tier = _tier(scheme, phase)
        grp = _group(scheme, phase, v, n_nodes)
        per_mb += v[phase] / BW[tier] \
            + n_layers * max(grp - 1, 0) * HOP_LAT[tier]
    per_step = 0.0
    for phase in ("cross_replica", "update_gather"):
        tier = _tier(scheme, phase)
        grp = _group(scheme, phase, v, n_nodes)
        per_step += v[phase] / BW[tier] + max(grp - 1, 0) * HOP_LAT[tier]
    t_comm = MICRO_BATCHES * per_mb + per_step
    gcds = n_nodes * 8
    tokens = MICRO_BATCHES * TOKENS_PER_GCD_MB * gcds
    t_comp = 6.0 * psi * tokens / gcds / PEAK
    return t_comp, t_comm


def tflops_per_gpu(scheme: str, psi: float, n_nodes: int) -> float:
    t_comp, t_comm = step_time(scheme, psi, n_nodes)
    gcds = n_nodes * 8
    tokens = MICRO_BATCHES * TOKENS_PER_GCD_MB * gcds
    # DeepSpeed prefetches all-gathers: model 60% of comm hidden under compute
    t = max(t_comp, t_comm) + 0.4 * min(t_comp, t_comm)
    return 6.0 * psi * tokens / gcds / t / 1e12


def run(print_fn=print):
    for psi, label in ((20e9, "GPT-NeoX-20B (Fig 7)"),
                       (10e9, "GPT-NeoX-10B (Fig 8)")):
        print_fn(f"\n== modeled TFLOPS/GPU across scales — {label} ==")
        print_fn(f"{'GCDs':>6s}" + "".join(f" {s:>10s}" for s in
                                           ("zero3", "zeropp", "zero_topo")))
        scales = [64, 128, 192, 256, 384]
        base = {}
        for gcds in scales:
            row = [tflops_per_gpu(s, psi, gcds // 8)
                   for s in ("zero3", "zeropp", "zero_topo")]
            base[gcds] = row
            print_fn(f"{gcds:6d}" + "".join(f" {r:10.1f}" for r in row))
        z3, zpp, topo = base[384]
        print_fn(f"at 384 GCDs: zero++/zero3 = {zpp / z3:.2f}x "
                 f"(paper 1.41x), topo/zero++ = {topo / zpp:.2f}x "
                 f"(paper 1.71x), topo/zero3 = {topo / z3:.2f}x "
                 f"(paper 2.40x)")
        eff = {s: base[384][i] / base[64][i]
               for i, s in enumerate(("zero3", "zeropp", "zero_topo"))}
        print_fn("scaling efficiency 64->384 GCDs: " +
                 ", ".join(f"{k} {v:.2f}" for k, v in eff.items()) +
                 "  (paper: topo 0.94)")
    return True


if __name__ == "__main__":
    run()
