"""Serving load generator: the INT8-resident decode vs the seed
fp-materialized gather, under a request storm with SLO admission.

Two phases on the same reduced model over 8 fake devices:

* **throughput** — the same request stream through two continuous batchers:
  ``gathered`` over an unquantized engine (per-token compute-dtype weight
  all-gather + dense matmul — the seed fp-materialized serving path) and
  ``resident`` over the INT8 wire residency (per-token INT8 re-gather into
  the fused ``dequant_matmul``, built once from the training engine's
  shards). Decode-rate wall-clock is *recorded* for trend inspection and the
  run asserts resident >= gathered before emitting, but never baseline-gated.

* **storm** — >= 1000 queued requests against a few slots under a
  step-count SLO (``ServeSLO.max_queue_steps``) with an oversubscribed page
  pool. Admission / rejection / preemption / retirement counts depend only
  on deterministic step arithmetic, so they ARE gated, alongside the pool
  geometry, the serve JSONL schema, and the fused-dispatch proof
  (``ops.dispatch_counters`` shows the resident decode traced
  ``dequant_matmul``). p50/p99 latency is reported, not gated.

    PYTHONPATH=src python -m benchmarks.serve_load          # full storm
    PYTHONPATH=src python -m benchmarks.serve_load --quick  # CI leg
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.engine import TrainHparams, ZeroEngine  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.launch.mesh import make_test_mesh, scheme_config  # noqa: E402
from repro.models.registry import build_model, get_arch  # noqa: E402
from repro.obs.metrics import (SERVE_REQUIRED_FIELDS, MetricsWriter,  # noqa: E402
                               read_jsonl, serve_aggregates)
from repro.serve.resident import build_resident  # noqa: E402
from repro.serve.scheduler import ContinuousBatcher, Request, ServeSLO  # noqa: E402

AX = ("data", "node", "gcd")
N_SLOTS = 4
PROMPT_LEN = 8
MAX_LEN = 32
PAGE = 8
MAX_NEW = 6


def _bench_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_serve.json"


def _setup(mesh):
    arch = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=128, vocab=256)
    model = build_model(arch)
    cfg_q = scheme_config("zero_topo", mesh, quant_block=64)
    cfg_fp = dataclasses.replace(
        cfg_q, quantize_weights=False, quantize_grads=False,
        axes=dataclasses.replace(cfg_q.axes, secondary=None))
    cfg_fp.validate_dependency_rule()
    return arch, model, cfg_q, cfg_fp


def _requests(arch, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, arch.vocab,
                                        PROMPT_LEN).astype(np.int32),
                    max_new=MAX_NEW) for i in range(n)]


def _run_backend(model, eng, mesh, params, *, backend, res_axes, arch,
                 n_requests, metrics_path, slo=None, n_pages=0,
                 seed=0) -> dict:
    mw = MetricsWriter(metrics_path, fields=SERVE_REQUIRED_FIELDS)
    cb = ContinuousBatcher(model, eng, mesh, n_slots=N_SLOTS,
                           max_len=MAX_LEN, prompt_len=PROMPT_LEN,
                           page_size=PAGE, n_pages=n_pages, slo=slo,
                           backend=backend, res_axes=res_axes, metrics=mw)
    cb.run(params, _requests(arch, n_requests, seed), max_steps=5000)
    mw.close()
    agg = serve_aggregates(read_jsonl(metrics_path))
    agg["counters"] = dict(cb.counters)
    agg["steps"] = cb.step_count
    agg["pool"] = dict(page_size=cb.paged.page_size,
                       n_pages=cb.paged.n_pages,
                       blocks_per_slot=cb.paged.blocks_per_slot)
    agg.update(cb.latency_percentiles())
    return agg


def run(print_fn=print, quick: bool = False) -> bool:
    mesh = make_test_mesh(shape=(2, 2, 2), axes=AX)
    arch, model, cfg_q, cfg_fp = _setup(mesh)
    # the storm census is baseline-gated, so its size is FIXED across
    # quick/full modes; --quick only shrinks the (ungated) throughput phase
    n_storm = 1000
    tmp = Path(tempfile.mkdtemp(prefix="serve_load_"))

    # seed fp-materialized path: unquantized engine, per-token fp gathers
    eng_fp = ZeroEngine(model.leaf_specs(), cfg_fp, mesh, TrainHparams())
    state_fp = eng_fp.init_state(jax.random.key(0))
    # INT8 wire residency from the quantized training engine's shards
    eng_q = ZeroEngine(model.leaf_specs(), cfg_q, mesh, TrainHparams())
    state_q = eng_q.init_state(jax.random.key(0))
    layout, residency = build_resident(eng_q, state_q, mesh)
    print_fn(f"residency: axes={layout.res_axes} degree={layout.res_degree} "
             f"wire={layout.memory_report()['wire_bytes']}B/device")

    # -- throughput: same stream, both backends (wall-clock, never gated) --
    # best-of-2 per backend: the first pass of each pays its jit compiles
    # and OS noise, so a single sample is ratio-flaky at this reduced size
    n_tp = 24 if quick else 48

    def _best_of(eng, params, *, backend, res_axes, tag):
        runs = [_run_backend(model, eng, mesh, params, backend=backend,
                             res_axes=res_axes, arch=arch, n_requests=n_tp,
                             metrics_path=tmp / f"{tag}{rep}.jsonl")
                for rep in range(2)]
        return max(runs, key=lambda a: a["tokens_per_s"])

    tp_fp = _best_of(eng_fp, state_fp["primaries"],
                     backend="gathered", res_axes=None, tag="fp")
    before = dict(ops.dispatch_counters())
    tp_res = _best_of(eng_q, residency,
                      backend="resident", res_axes=layout.res_axes,
                      tag="res")
    fused = {k: v - before.get(k, 0) for k, v in
             ops.dispatch_counters().items()
             if k.startswith("dequant_matmul/") and v > before.get(k, 0)}
    print_fn(f"throughput ({n_tp} reqs, {N_SLOTS} slots): "
             f"gathered-fp {tp_fp['tokens_per_s']:.1f} tok/s, "
             f"resident-int8 {tp_res['tokens_per_s']:.1f} tok/s "
             f"({tp_res['tokens_per_s'] / max(tp_fp['tokens_per_s'], 1e-9):.2f}x)"
             )
    print_fn(f"resident fused dispatch: {fused}")
    assert fused, "resident decode never traced ops.dequant_matmul"
    assert tp_res["tokens_per_s"] >= tp_fp["tokens_per_s"], \
        (tp_res["tokens_per_s"], tp_fp["tokens_per_s"],
         "INT8-resident decode must beat the fp-materialized gather")
    assert tp_fp["retired"] == n_tp and tp_res["retired"] == n_tp

    # -- storm: SLO admission under >= 1000 queued requests (gated census) --
    storm = _run_backend(
        model, eng_q, mesh, residency, backend="resident",
        res_axes=layout.res_axes, arch=arch, n_requests=n_storm,
        metrics_path=tmp / "storm.jsonl",
        slo=ServeSLO(max_queue_steps=6, reserve_pages=1),
        # 4 slots x 1 prompt page admit fine, but each slot needs a 2nd
        # page mid-decode: 6 pages can't hold 4x2, forcing preemption
        n_pages=6, seed=1)
    c = storm["counters"]
    print_fn(f"storm ({n_storm} queued): admitted {c['admitted']}, "
             f"rejected {c['rejected']}, preempted {c['preempted']}, "
             f"retired {c['retired']} in {storm['steps']} steps; "
             f"p50 {storm['p50_ms']:.1f}ms p99 {storm['p99_ms']:.1f}ms")
    assert c["rejected"] > 0, "storm must exercise SLO rejection"
    assert c["preempted"] > 0, "storm must exercise page preemption"
    # every request ends exactly once; every admission ends exactly once
    assert c["rejected"] + c["retired"] == n_storm, c
    assert c["admitted"] == c["retired"] + c["preempted"], c

    rec = dict(
        model=arch.name, scheme="zero_topo",
        n_slots=N_SLOTS, prompt_len=PROMPT_LEN, max_len=MAX_LEN,
        residency=dict(axes=list(layout.res_axes),
                       degree=layout.res_degree,
                       wire_bytes=layout.memory_report()["wire_bytes"]),
        pool=storm["pool"],
        slo=dict(max_queue_steps=6, reserve_pages=1),
        storm=dict(n_requests=n_storm, steps=storm["steps"], **c),
        dispatch=dict(resident_dequant_matmul=bool(fused)),
        jsonl_schema=dict(serve_fields=list(SERVE_REQUIRED_FIELDS)),
        # wall-clock trend fields (recorded, never gated)
        throughput=dict(
            gathered_fp_tokens_per_s=tp_fp["tokens_per_s"],
            resident_tokens_per_s=tp_res["tokens_per_s"],
            speedup=tp_res["tokens_per_s"] / max(tp_fp["tokens_per_s"],
                                                 1e-9),
            storm_p50_ms=storm["p50_ms"], storm_p99_ms=storm["p99_ms"]),
    )
    _bench_path().write_text(json.dumps(rec, indent=1))
    print_fn(f"wrote {_bench_path()}")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized storm (1000 queued requests)")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
