"""Paper Tables V & VI + §II max-model-size motivation, reconciled with the
engine's real allocation.

Three accountings of the per-device gradient buffer, all from the SAME
formulas in ``repro.core.partition`` (so this table, ``ZeroEngine.
memory_report`` and the planner's ``topo.cost`` can never drift —
tests/test_stream_grads.py cross-checks all three):

* **paper table** — fp16 grads at the grad-shard degree (``grad_memory_
  bytes(grad_bytes=2)``): what Tables V/VI print.
* **engine (seed)** — fp32 grads in *primary layout*
  (``grad_buffer_bytes(streaming=False)`` = 4*psi/w_degree): what the seed
  step actually accumulates across microbatches, strictly more than the
  paper figure whenever E is non-trivial.
* **engine (streaming)** — fp32 grads in *optimizer-shard layout*
  (``grad_buffer_bytes(streaming=True)`` = 4*psi/os_degree): the streaming
  grad path (DESIGN.md §8), which reduces each layer's cotangent inside the
  backward.

Emits ``BENCH_memory.json`` (cwd, or $REPRO_BENCH_DIR); CI's bench-gate
diffs it against ``benchmarks/baselines/BENCH_memory.json`` via
``benchmarks.check_baseline`` — pure byte arithmetic, so ANY drift is a
memory-model change that must ship with an updated baseline.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.partition import (grad_buffer_bytes, grad_memory_bytes,
                                  optimizer_memory_bytes, preset,
                                  weight_memory_bytes)

GB = 1 << 30
SCHEMES = ("zero1", "zero2", "zero3", "zeropp", "zero_topo")


def _cfg(scheme: str, n_nodes: int, gcds_per_node: int = 8):
    sizes = {"data": n_nodes, "node": gcds_per_node // 2, "gcd": 2}
    return preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                  l0_axes=("gcd",), axis_sizes=sizes)


def scheme_bytes(scheme: str, psi: int, n_nodes: int, gcds_per_node: int = 8,
                 *, grad_bytes: int = 2, streaming: bool | None = None):
    """Per-device training-state bytes for one scheme.

    ``streaming=None`` reproduces the paper's Table V/VI accounting (grads
    at the grad-shard degree, fp16 by default); a bool selects the engine's
    real buffer (``grad_buffer_bytes``) in the seed (False) or streaming
    (True) regime, fp32.
    """
    cfg = _cfg(scheme, n_nodes, gcds_per_node)
    w = weight_memory_bytes(cfg, psi)
    if streaming is None:
        g = grad_memory_bytes(cfg, psi, grad_bytes=grad_bytes)
    else:
        g = grad_buffer_bytes(cfg, psi, streaming=streaming,
                              grad_bytes=grad_bytes)
    os_ = optimizer_memory_bytes(cfg, psi)
    return dict(weights=w, grads=g, optimizer=os_, total=w + g + os_)


def max_model_size(scheme: str, n_nodes: int, mem_per_gcd: float,
                   gcds_per_node: int = 8, *, grad_bytes: int = 2,
                   streaming: bool | None = None) -> float:
    """Largest psi (params) whose training state fits (bisective search)."""
    lo, hi = 1e6, 1e13
    for _ in range(80):
        mid = (lo + hi) / 2
        b = scheme_bytes(scheme, int(mid), n_nodes, gcds_per_node,
                         grad_bytes=grad_bytes, streaming=streaming)
        if b["total"] <= mem_per_gcd:
            lo = mid
        else:
            hi = mid
    return lo


def bench_out_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_memory.json"


def run(print_fn=print):
    rec: dict = {}
    psi = 20_000_000_000
    print_fn("\n== Paper Tables V/VI: per-GCD training-state bytes "
             "(psi=20B params, 48 Frontier nodes; fp16 grads, Table VI "
             "accounting) ==")
    hdr = f"{'scheme':10s} {'weights':>10s} {'grads':>10s} {'optimizer':>10s} {'total':>10s}"
    print_fn(hdr)
    rec["paper_table"] = {}
    for scheme in SCHEMES:
        b = scheme_bytes(scheme, psi, 48)
        rec["paper_table"][scheme] = b
        print_fn(f"{scheme:10s} " + " ".join(
            f"{b[k] / GB:9.2f}G" for k in ("weights", "grads", "optimizer",
                                           "total")))

    print_fn("\n== engine accounting: the fp32 grad buffer the step really "
             "allocates (same formulas as ZeroEngine.memory_report) ==")
    print_fn(f"{'scheme':10s} {'paper(fp16)':>12s} {'seed(fp32)':>12s} "
             f"{'streaming':>12s}   seed = primary layout 4psi/w; "
             "streaming = os layout 4psi/os (DESIGN.md §8)")
    rec["engine"] = {}
    for scheme in SCHEMES:
        paper = scheme_bytes(scheme, psi, 48)["grads"]
        seed = scheme_bytes(scheme, psi, 48, grad_bytes=4,
                            streaming=False)["grads"]
        strm = scheme_bytes(scheme, psi, 48, grad_bytes=4,
                            streaming=True)["grads"]
        rec["engine"][scheme] = dict(paper_fp16=paper, seed_fp32=seed,
                                     streaming_fp32=strm)
        print_fn(f"{scheme:10s} {paper / GB:11.2f}G {seed / GB:11.2f}G "
                 f"{strm / GB:11.2f}G")
        assert strm <= seed, (scheme, strm, seed)
    print_fn("-> the seed path's primary-layout accumulation costs up to "
             "w_degree/os_degree MORE than the paper table assumes; the "
             "streaming path brings it BELOW the table (fp32 at os degree).")

    print_fn("\n== §II motivation: max model size, 2 Frontier nodes "
             "(16 GCDs x 64 GB) ==")
    rec["max_model_2nodes"] = {}
    for scheme in ("zero3", "zeropp", "zero_topo"):
        m = max_model_size(scheme, 2, 64 * GB)
        ms = max_model_size(scheme, 2, 64 * GB, grad_bytes=4, streaming=True)
        rec["max_model_2nodes"][scheme] = dict(paper=m, streaming=ms)
        print_fn(f"{scheme:10s} ~{m / 1e9:5.1f}B params "
                 f"(streaming grads, fp32: ~{ms / 1e9:5.1f}B)")
    print_fn("(paper reports ~68B for ZeRO-3 vs ~55B for ZeRO++ — same "
             "ordering and ~20% gap; zero_topo trades further memory for "
             "constant-latency gathers and is the 36B-class row, Table V)")

    print_fn("\n== TPU v5e adaptation: max model size, 16 GB/chip, 256 chips ==")
    rec["max_model_tpu"] = {}
    for scheme in ("zero3", "zeropp", "zero_topo"):
        m = max_model_size(scheme, 16, 16 * GB, gcds_per_node=16)
        rec["max_model_tpu"][scheme] = m
        print_fn(f"{scheme:10s} ~{m / 1e9:5.1f}B params "
                 f"(weight-degree {_cfg(scheme, 16, 16).w_degree})")

    out = bench_out_path()
    out.write_text(json.dumps(rec, indent=1))
    print_fn(f"\nwrote {out}")
    return True


if __name__ == "__main__":
    run()
