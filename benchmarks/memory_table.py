"""Paper Tables V & VI + §II max-model-size motivation.

Per-device weight/gradient/optimizer bytes for each scheme, on the paper's
Frontier geometry (64 GB/GCD, 8 GCD/node) and on the TPU v5e target
(16 GB/chip), plus the maximum trainable model size per scheme — reproducing
the ZeRO++ 55B vs ZeRO-3 68B observation on 2 nodes (16 GCDs).
"""
from __future__ import annotations

from repro.core.partition import (grad_memory_bytes, optimizer_memory_bytes,
                                  preset, weight_memory_bytes)

GB = 1 << 30


def scheme_bytes(scheme: str, psi: int, n_nodes: int, gcds_per_node: int = 8):
    sizes = {"data": n_nodes, "node": gcds_per_node // 2, "gcd": 2}
    cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                 l0_axes=("gcd",), axis_sizes=sizes)
    w = weight_memory_bytes(cfg, psi)
    g = grad_memory_bytes(cfg, psi) // 2        # paper counts fp16 grads
    os_ = optimizer_memory_bytes(cfg, psi)
    return dict(weights=w, grads=g, optimizer=os_, total=w + g + os_)


def max_model_size(scheme: str, n_nodes: int, mem_per_gcd: float,
                   gcds_per_node: int = 8) -> float:
    """Largest psi (params) whose training state fits (bisective search)."""
    lo, hi = 1e6, 1e13
    for _ in range(80):
        mid = (lo + hi) / 2
        if scheme_bytes(scheme, int(mid), n_nodes, gcds_per_node)["total"] \
                <= mem_per_gcd:
            lo = mid
        else:
            hi = mid
    return lo


def run(print_fn=print):
    print_fn("\n== Paper Tables V/VI: per-GCD training-state bytes "
             "(psi=20B params, 48 Frontier nodes) ==")
    psi = 20_000_000_000
    hdr = f"{'scheme':10s} {'weights':>10s} {'grads':>10s} {'optimizer':>10s} {'total':>10s}"
    print_fn(hdr)
    for scheme in ("zero1", "zero2", "zero3", "zeropp", "zero_topo"):
        b = scheme_bytes(scheme, psi, 48)
        print_fn(f"{scheme:10s} " + " ".join(
            f"{b[k] / GB:9.2f}G" for k in ("weights", "grads", "optimizer",
                                           "total")))

    print_fn("\n== §II motivation: max model size, 2 Frontier nodes "
             "(16 GCDs x 64 GB) ==")
    for scheme in ("zero3", "zeropp", "zero_topo"):
        m = max_model_size(scheme, 2, 64 * GB)
        print_fn(f"{scheme:10s} ~{m / 1e9:5.1f}B params")
    print_fn("(paper reports ~68B for ZeRO-3 vs ~55B for ZeRO++ — same "
             "ordering and ~20% gap; zero_topo trades further memory for "
             "constant-latency gathers and is the 36B-class row, Table V)")

    print_fn("\n== TPU v5e adaptation: max model size, 16 GB/chip, 256 chips ==")
    for scheme in ("zero3", "zeropp", "zero_topo"):
        sizes = {"data": 16, "node": 8, "gcd": 2}   # 256 chips
        cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                     l0_axes=("gcd",), axis_sizes=sizes)
        lo, hi = 1e6, 1e13
        for _ in range(80):
            mid = (lo + hi) / 2
            w = weight_memory_bytes(cfg, int(mid))
            g = grad_memory_bytes(cfg, int(mid)) // 2
            o = optimizer_memory_bytes(cfg, int(mid))
            if w + g + o <= 16 * GB:
                lo = mid
            else:
                hi = mid
        print_fn(f"{scheme:10s} ~{lo / 1e9:5.1f}B params "
                 f"(weight-degree {cfg.w_degree})")
    return True


if __name__ == "__main__":
    run()
