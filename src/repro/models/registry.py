"""Architecture registry: ArchConfig -> ModelDef (specs + step functions +
abstract input/cache specs for the dry-run).

``input_specs`` follows the shannon/kernels pattern: weak-type-correct
``ShapeDtypeStruct`` stand-ins for every model input, shardable, no device
allocation. The frontend carve-out lives here: VLM patch embeddings and audio
frame embeddings are *inputs* of the right shape, produced by a stub pipeline
instead of a ViT / conv codec.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.partition import LeafSpec
from .config import SHAPES, ArchConfig, ShapeConfig, shape_supported
from .transformer import LM, kind_meta

ARCHS: dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]):
    cfg = fn()
    ARCHS[cfg.name] = fn
    return fn


def get_arch(name: str) -> ArchConfig:
    if not ARCHS:
        load_all_configs()
    return ARCHS[name]()


def list_archs() -> list[str]:
    if not ARCHS:
        load_all_configs()
    return sorted(ARCHS)


def load_all_configs():
    """Import every repro.configs.<arch> module (they self-register)."""
    import importlib
    import pkgutil

    from .. import configs as pkg
    for m in pkgutil.iter_modules(pkg.__path__):
        importlib.import_module(f"repro.configs.{m.name}")


# ---------------------------------------------------------------------------
# Batch partitioning helpers
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, global_batch: int,
               candidates: tuple[str, ...] | None = None) -> tuple[str, ...]:
    """Largest major->minor prefix of mesh axes whose product divides batch."""
    axes = candidates if candidates is not None else tuple(mesh.axis_names)
    out: list[str] = []
    prod = 1
    for a in axes:
        n = mesh.shape[a]
        if global_batch % (prod * n) == 0:
            out.append(a)
            prod *= n
        else:
            break
    return tuple(out)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes used for batch sharding of serve shapes (everything but model tiers)."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "node", "gcd"))


def model_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("model", "node", "gcd"))


# ---------------------------------------------------------------------------
# ModelDef
# ---------------------------------------------------------------------------

@dataclass
class ModelDef:
    arch: ArchConfig
    lm: LM

    def leaf_specs(self) -> dict[str, LeafSpec]:
        return self.lm.leaf_specs()

    def param_count(self) -> int:
        """Logical (unpadded) parameter count — matches ZeroEngine.param_count."""
        return sum(s.logical_size * (s.stack or 1)
                   for s in self.leaf_specs().values())

    # ---- step functions (run inside shard_map; device-local views) ----

    def loss_fn(self):
        return lambda view, batch: self.lm.loss(view, batch)

    def prefill_fn(self, seq_axes, axis_sizes, seq_parallel: bool = False):
        return lambda view, batch: self.lm.prefill(
            view, batch, seq_axes=seq_axes, axis_sizes=axis_sizes,
            seq_parallel=seq_parallel)

    def decode_fn(self, seq_axes, axis_sizes):
        return lambda view, caches, batch: self.lm.decode(
            view, caches, batch, seq_axes=seq_axes, axis_sizes=axis_sizes)

    # ---- abstract inputs -------------------------------------------------

    def _extra_inputs(self, b: int, s_text_hint: int) -> dict[str, tuple]:
        cfg = self.arch
        out = {}
        if cfg.n_patches:
            out["patches"] = ((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.enc_layers:
            out["frames"] = ((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        return out

    def train_batch_shapes(self, shape: ShapeConfig) -> dict[str, tuple]:
        cfg = self.arch
        b, s = shape.global_batch, shape.seq_len
        s_text = s - cfg.n_patches if cfg.n_patches else s
        out = {"tokens": ((b, s_text + 1), jnp.int32)}
        out.update(self._extra_inputs(b, s_text))
        return out

    def prefill_batch_shapes(self, shape: ShapeConfig) -> dict[str, tuple]:
        cfg = self.arch
        b, s = shape.global_batch, shape.seq_len
        s_text = s - cfg.n_patches if cfg.n_patches else s
        out = {"tokens": ((b, s_text), jnp.int32)}
        out.update(self._extra_inputs(b, s_text))
        return out

    def decode_batch_shapes(self, shape: ShapeConfig) -> dict[str, tuple]:
        return {"token": ((shape.global_batch,), jnp.int32)}

    def batch_pspecs(self, shapes: dict[str, tuple], baxes: tuple[str, ...]):
        ba = baxes if baxes else None
        return {k: P(ba, *([None] * (len(sh) - 1)))
                for k, (sh, _) in shapes.items()}

    def batch_sds(self, shapes: dict[str, tuple], mesh: Mesh,
                  baxes: tuple[str, ...]):
        specs = self.batch_pspecs(shapes, baxes)
        return {k: jax.ShapeDtypeStruct(sh, dt,
                                        sharding=NamedSharding(mesh, specs[k]))
                for k, (sh, dt) in shapes.items()}

    # ---- cache specs ------------------------------------------------------

    def cache_shapes(self, shape: ShapeConfig) -> dict[str, Any]:
        """Global cache shapes+dtypes+seq-shardable flags per kind."""
        cfg = self.arch
        b, s = shape.global_batch, shape.seq_len
        h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hdim
        out: dict[str, Any] = {}
        for kind, count in cfg.kind_counts().items():
            m = kind_meta(kind, cfg)
            entry: dict[str, Any] = {}
            if m.mixer == "attn":
                if m.window:
                    w = m.window   # ring size is always the window (slot = pos % W)
                    entry["k"] = ((count, b, w, kv, hd), jnp.bfloat16, False)
                    entry["v"] = ((count, b, w, kv, hd), jnp.bfloat16, False)
                else:
                    entry["k"] = ((count, b, s, kv, hd), jnp.bfloat16, True)
                    entry["v"] = ((count, b, s, kv, hd), jnp.bfloat16, True)
            elif m.mixer == "mla":
                ml = cfg.mla
                entry["lat"] = ((count, b, s, ml.kv_lora + ml.qk_rope),
                                jnp.bfloat16, True)
            else:  # mamba
                c = cfg.ssm
                entry["h"] = ((count, b, cfg.d_inner, c.d_state),
                              jnp.float32, False)
                entry["conv"] = ((count, b, c.d_conv - 1, cfg.d_inner),
                                 jnp.float32, False)
            if m.cross:
                entry["kx"] = ((count, b, cfg.n_frames, h, hd), jnp.bfloat16,
                               False)
                entry["vx"] = ((count, b, cfg.n_frames, h, hd), jnp.bfloat16,
                               False)
            out[kind] = entry
        return out

    def cache_pspecs(self, shape: ShapeConfig, baxes, seq_axes):
        shapes = self.cache_shapes(shape)
        out = {}
        for kind, entry in shapes.items():
            out[kind] = {}
            for name, (sh, dt, seq_shard) in entry.items():
                spec = [None, baxes if baxes else None] + [None] * (len(sh) - 2)
                if seq_shard and seq_axes:
                    spec[2] = seq_axes
                out[kind][name] = P(*spec)
        out["pos"] = P()
        return out

    def cache_sds(self, shape: ShapeConfig, mesh: Mesh, baxes, seq_axes):
        shapes = self.cache_shapes(shape)
        specs = self.cache_pspecs(shape, baxes, seq_axes)
        out: dict[str, Any] = {}
        for kind, entry in shapes.items():
            out[kind] = {
                name: jax.ShapeDtypeStruct(
                    sh, dt, sharding=NamedSharding(mesh, specs[kind][name]))
                for name, (sh, dt, _) in entry.items()}
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P()))
        return out


def build_model(arch: ArchConfig) -> ModelDef:
    return ModelDef(arch, LM(arch))


def supported_shapes(arch: ArchConfig) -> list[str]:
    return [s for s in SHAPES if shape_supported(arch, SHAPES[s])]
