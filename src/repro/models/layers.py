"""Shared model layers: norms, RoPE, memory-bounded attention, chunked CE.

Training/prefill attention routes through the first-class ``kernels/ops``
dispatch (``jnp | pallas | pallas_interpret``, inherited from
``ops.set_default_impl`` / ``--kernel-impl`` / ``REPRO_KERNEL_IMPL``):
``ops.attention_fusable`` decides whether a call shape can use the Pallas
kernel path, and rejected shapes (MLA value dims, traced decode offsets,
unaligned seqs) fall back to the chunked jnp scan below — with a one-time
structured warning and a dispatch-counter record, never silently.

``flash_decode`` is the sequence-sharded single-token decode attention used
for 32k/500k KV caches: each device computes a partial softmax over its local
KV slice and the partials are combined exactly with a global max/denominator
reduction over the sharding axes (one pmax + two psums).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ops

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(positions, dim: int, theta: float):
    """positions (...,) -> (cos, sin) of shape (..., dim//2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x32_1 * c - x32_2 * s, x32_2 * c + x32_1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (training / prefill)
# ---------------------------------------------------------------------------

def _best_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (repeated halving fails badly
    for non-power-of-two lengths, e.g. whisper's 1500 frames -> chunk 4)."""
    target = min(target, s)
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return 1


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    q_offset: int = 0, softmax_scale: float | None = None):
    """q (B,Sq,H,D); k,v (B,Sk,Hkv,D). Returns (B,Sq,H,D).

    ``window`` > 0: sliding-window causal attention (each query attends to the
    previous ``window`` positions, inclusive of itself).
    ``q_offset``: global position of q[0] relative to k[0] (prefill=0;
    cross-attention uses causal=False).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]                      # MLA: value dim may differ from qk dim
    fusable, reason = ops.attention_fusable(
        sq, sk, d, dv, softmax_scale=softmax_scale, q_offset=q_offset)
    if fusable:
        kf = _repeat_kv(k, h // hkv)
        vf = _repeat_kv(v, h // hkv)
        qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
        kt = kf.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
        vt = vf.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
        o = ops.flash_attention(qt, kt, vt, causal=causal, window=window,
                                q_offset=q_offset)
        return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    ops.record_fallback("attention", reason)
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    q_chunk = _best_chunk(sq, q_chunk)
    kv_chunk = _best_chunk(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk

    qc = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,C,D)
    kc = k.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, h, dv).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(sk).reshape(nk, kv_chunk)

    def q_body(_, qi):
        qb, qp = qi  # (B,H,C,D), (C,)

        def kv_body(carry, ki):
            acc, m, denom = carry
            kb, vb, kp = ki
            s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((b, h, q_chunk, dv), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        (acc, m, denom), _ = lax.scan(kv_body, (acc0, m0, d0), (kc, vc, k_pos))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, out = lax.scan(jax.checkpoint(q_body, prevent_cse=False), None,
                      (qc, q_pos))
    # (nq, B, H, C, Dv) -> (B, S, H, Dv)
    return out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

def _row_positions(pos, b):
    """Broadcast a scalar or (B,) position to (B,) int32."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(p.reshape(-1), (b,)) if p.ndim <= 1 \
        else p.reshape(b)


def flash_decode(q, k_loc, v_loc, pos, *, seq_axes: tuple[str, ...] = (),
                 seq_offset=0, softmax_scale: float | None = None):
    """Single-token decode over a (possibly sequence-sharded) KV cache.

    q: (B, H, D); k_loc/v_loc: (B, S_loc, Hkv, D) — this device's slice of the
    cache; valid entries are global positions <= pos (scalar or per-row (B,),
    for continuous batching). ``seq_offset``: global position of k_loc[0]
    (devices differ). Partial softmax combined exactly over ``seq_axes``.
    """
    b, h, d = q.shape
    _, s_loc, hkv, _ = k_loc.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    n_rep = h // hkv
    kpos = seq_offset + jnp.arange(s_loc)
    pos_b = _row_positions(pos, b)
    valid = kpos[None, :] <= pos_b[:, None]               # (B, S_loc)

    qg = q.reshape(b, hkv, n_rep, d).astype(jnp.float32)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg,
                   k_loc.astype(jnp.float32)) * scale     # (B,Hkv,rep,S_loc)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)
    if seq_axes:
        m = lax.pmax(m_loc, seq_axes)
    else:
        m = m_loc
    p = jnp.exp(s - m[..., None])
    num = jnp.einsum("bgrs,bsgd->bgrd", p, v_loc.astype(jnp.float32))
    den = jnp.sum(p, axis=-1)
    if seq_axes:
        # contract: allow[raw-psum] -- seq-parallel softmax partials over the
        # intra-tier seq axes; fp32 throughout, single-process decode path
        num = lax.psum(num, seq_axes)
        den = lax.psum(den, seq_axes)  # contract: allow[raw-psum]
    out = num / jnp.maximum(den[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


def ring_decode(q, k_ring, v_ring, pos, window: int,
                softmax_scale: float | None = None):
    """Decode over a sliding-window ring cache (B, W, Hkv, D), write-pos =
    pos % W. ``pos`` may be per-row (B,)."""
    b, h, d = q.shape
    _, w, hkv, _ = k_ring.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    n_rep = h // hkv
    # ring slot i holds global position: the largest p <= pos with p % W == i
    slot = jnp.arange(w)
    pos_b = _row_positions(pos, b)[:, None]
    gpos = pos_b - (pos_b - slot[None, :]) % w
    valid = (gpos >= 0) & (gpos > pos_b - window)         # (B, W)
    qg = q.reshape(b, hkv, n_rep, d).astype(jnp.float32)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg, k_ring.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p, v_ring.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def sharded_cache_write(cache_loc, new, pos, *, seq_axes: tuple[str, ...],
                        axis_sizes: dict[str, int]):
    """Write ``new`` (B, 1, Hkv, D) at global seq position ``pos``.

    cache_loc: (B, S_loc, Hkv, D), this device's contiguous slice of the
    global (B, S, ...) cache (major->minor over seq_axes). Only the owner
    updates; others keep their slice via a where-mask. ``pos`` may be
    per-row (B,) (continuous batching): a masked one-hot write is used.
    """
    b = cache_loc.shape[0]
    s_loc = cache_loc.shape[1]
    p = jnp.asarray(pos, jnp.int32)
    if seq_axes:
        idx = _linear_index(seq_axes, axis_sizes)
        local = p - idx * s_loc
    else:
        local = p
    if p.ndim == 0:
        inb = (local >= 0) & (local < s_loc)
        upd = lax.dynamic_update_slice_in_dim(
            cache_loc, new.astype(cache_loc.dtype),
            jnp.clip(local, 0, s_loc - 1), axis=1)
        return jnp.where(inb, upd, cache_loc)
    # per-row positions: one-hot masked write
    oh = jnp.arange(s_loc)[None, :] == local.reshape(b)[:, None]   # (B,S_loc)
    return jnp.where(oh[:, :, None, None], new.astype(cache_loc.dtype),
                     cache_loc)


def _linear_index(axes: tuple[str, ...], axis_sizes: dict[str, int]):
    """Row-major device index over `axes` (major -> minor)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * axis_sizes[a] + lax.axis_index(a)
    return idx


def seq_offset(axes: tuple[str, ...], axis_sizes: dict[str, int], s_loc: int):
    return _linear_index(axes, axis_sizes) * s_loc


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_cross_entropy(x, w_vocab, labels, mask, *, chunk: int = 512,
                          logit_softcap: float = 0.0):
    """Next-token CE without materializing (B, S, V).

    x: (B, S, d) final hidden states; w_vocab: (V, d) dense lm-head (gathered
    once — its AD cotangent is reduced over chunks by scan); labels (B, S)
    int32; mask (B, S) {0,1}. Returns (loss_sum, token_count).
    """
    b, s, d = x.shape
    chunk = _best_chunk(s, chunk)
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        xi, li, mi = inp
        logits = jnp.einsum("bcd,vd->bcv", xi.astype(jnp.float32),
                            w_vocab.astype(jnp.float32))
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return carry + jnp.sum(nll), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, mc))
    return total, jnp.sum(mask)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]
