"""Architecture + input-shape configuration records.

One ``ArchConfig`` per assigned architecture (see ``repro.configs``); the
fields cover every family in the pool (dense / MoE / SSM / hybrid / VLM /
audio).  ``block_pattern`` names the per-layer block kind — uniform models
scan over a single stacked leaf group, patterned models (gemma3's 5:1
local:global, jamba's mamba/attention interleave) group layers by kind and
loop.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_ff: int = 0                   # per-expert FFN width
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    token_chunk: int = 4096         # dispatch chunking (bounds (T,E,C) tensors)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)
    chunk: int = 256                # associative-scan chunk length


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 768
    kv_lora: int = 256
    qk_nope: int = 64
    qk_rope: int = 32
    v_head: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0             # 0 -> n_heads (MHA)
    head_dim: int = 0               # 0 -> d_model // n_heads
    block_pattern: tuple[str, ...] = ()   # () -> uniform default kind
    rope_theta: float = 10_000.0
    rope_theta_global: float = 1_000_000.0
    sliding_window: int = 0         # 0 -> full attention
    qkv_bias: bool = False
    norm: str = "rms"               # rms | ln
    act: str = "silu_glu"           # silu_glu | gelu | gelu_glu
    parallel_residual: bool = False  # GPT-NeoX style
    tie_embeddings: bool = False
    embed_scale: bool = False        # multiply embeddings by sqrt(d) (gemma)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    mla: MLAConfig | None = None
    # -- modality frontends (stubs: precomputed embeddings are inputs) --
    n_patches: int = 0              # vlm: patch embeddings prepended to text
    n_frames: int = 0               # audio: encoder input frames
    enc_layers: int = 0             # enc-dec: encoder depth (decoder = n_layers)
    source: str = ""                # citation

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank(self) -> int:
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        default = {"dense": "attn", "moe": "moe", "ssm": "mamba",
                   "vlm": "attn", "audio": "dec"}[self.family] \
            if self.family != "hybrid" else "attn"
        return (default,) * self.n_layers

    @property
    def uniform(self) -> bool:
        return len(set(self.pattern)) == 1

    def kind_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for k in self.pattern:
            out[k] = out.get(k, 0) + 1
        return out

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                n_experts: int = 4, vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant: same family/block kinds, tiny dims."""
        d_model = min(d_model, self.d_model)
        heads = min(self.n_heads, max(2, d_model // 64))
        kvh = max(1, min(self.kv_heads, heads))
        while heads % kvh:
            kvh -= 1
        kinds = list(dict.fromkeys(self.pattern))  # preserve order, unique
        pat = tuple((kinds * n_layers)[:max(n_layers, len(kinds))])
        moe = self.moe
        if moe.n_experts:
            moe = dataclasses.replace(
                moe, n_experts=min(n_experts, moe.n_experts),
                d_ff=min(max(2 * d_model, 64), moe.d_ff), token_chunk=256)
        mla = self.mla
        if mla is not None:
            mla = MLAConfig(q_lora=d_model // 2, kv_lora=d_model // 4,
                            qk_nope=32, qk_rope=16, v_head=32)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=len(pat),
            d_model=d_model, n_heads=heads, n_kv_heads=kvh, head_dim=0,
            d_ff=min(max(2 * d_model, 64), self.d_ff) if self.d_ff else 0,
            vocab=min(vocab, self.vocab), block_pattern=pat, moe=moe, mla=mla,
            ssm=dataclasses.replace(self.ssm, chunk=64),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            n_frames=min(self.n_frames, 32) if self.n_frames else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic support for long_500k (system DESIGN §Arch-applicability):
# SSM/hybrid run natively; gemma3 (SWA local + seq-sharded global flash-decode)
# and mixtral (SWA 4k) run; pure full-attention archs are skipped.
LONG_CONTEXT_OK = {"falcon-mamba-7b", "jamba-v0.1-52b", "gemma3-1b",
                   "mixtral-8x7b"}


def shape_supported(arch: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return arch.name in LONG_CONTEXT_OK
    return True
