"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Baseline (paper-faithful ZeRO): expert weights are ordinary flat ZeRO shards —
the dense (E, d, ff) tensors are materialized by the hierarchical quantized
all-gather like any other parameter, and every device computes the dispatch /
expert-FFN / combine einsums for its own tokens. This is exactly how
DeepSpeed-ZeRO trains MoE when expert parallelism is off, and it is where the
paper's intra-tier bandwidth matters most (the expert tensors dominate the
gather volume).

Expert parallelism (beyond-paper option, see EXPERIMENTS.md §Perf) shards the
expert dimension over a mesh axis and exchanges token slots with a single
all-to-all each way — the same 1-hop a2a machinery the paper uses for the
quantized gradient reduce-scatter.

Dispatch uses the standard capacity-factor formulation (Mesh-TF / GSPMD):
tokens are processed in chunks so the (T, E, C) one-hot dispatch tensor stays
bounded at 32k+ sequence lengths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig


def _dispatch_combine(gates, top_k: int, capacity: int):
    """gates (T, E) softmax probs -> dispatch (T,E,C) bf16, combine (T,E,C) f32,
    aux load-balance loss terms (f_e, P_e)."""
    t, e = gates.shape
    vals, idx = lax.top_k(gates, top_k)                  # (T, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((t, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((t, e, capacity), jnp.float32)
    fill = jnp.zeros((e,), jnp.float32)
    for j in range(top_k):
        oh = jax.nn.one_hot(idx[:, j], e, dtype=jnp.float32)       # (T, E)
        pos = jnp.cumsum(oh, axis=0) - oh + fill                    # (T, E)
        fill = fill + oh.sum(axis=0)
        pos_t = (pos * oh).sum(-1)                                  # (T,)
        in_cap = (pos_t < capacity)
        slot = jax.nn.one_hot(pos_t, capacity, dtype=jnp.float32)   # (T, C)
        d_j = (oh[:, :, None] * slot[:, None, :]) * in_cap[:, None, None]
        dispatch = dispatch + d_j.astype(jnp.bfloat16)
        combine = combine + d_j * vals[:, j][:, None, None]

    # Switch-style load balance: E * sum_e f_e * P_e (f from top-1 choices)
    top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    f_e = top1.mean(axis=0)
    p_e = gates.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return dispatch, combine, aux


def moe_ffn(view, prefix: str, cfg: ArchConfig, x):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Leaves: f"{prefix}router" (d, E); f"{prefix}w_gate"/"w_up" (E, d, ff);
    f"{prefix}w_down" (E, ff, d) — dense-materialized via the ZeRO gather.
    """
    m = cfg.moe
    b, s, d = x.shape
    router = view.get(prefix + "router")                  # (d, E)

    from .layers import _best_chunk
    xt = x.reshape(b * s, d)
    t_total = b * s
    chunk = _best_chunk(t_total, m.token_chunk)
    n_chunks = t_total // chunk
    capacity = max(int(m.capacity_factor * m.top_k * chunk / m.n_experts), 4)

    def body(carry, xc):
        gates = jax.nn.softmax(
            (xc.astype(jnp.float32) @ router.astype(jnp.float32)), axis=-1)
        disp, comb, aux = _dispatch_combine(gates, m.top_k, capacity)
        e_in = jnp.einsum("tec,td->ecd", disp.astype(jnp.bfloat16),
                          xc.astype(jnp.bfloat16))
        e_out = view.expert_ffn(prefix, e_in)
        yc = jnp.einsum("tec,ecd->td", comb.astype(jnp.float32),
                        e_out.astype(jnp.float32))
        return carry + aux, yc.astype(x.dtype)

    if n_chunks == 1:
        aux, y = body(jnp.zeros((), jnp.float32), xt)
    else:
        body_ck = jax.checkpoint(body, prevent_cse=False)
        aux, y = lax.scan(body_ck, jnp.zeros((), jnp.float32),
                          xt.reshape(n_chunks, chunk, d))
        y = y.reshape(t_total, d)
    return y.reshape(b, s, d), aux * m.aux_coef / n_chunks
