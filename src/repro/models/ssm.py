"""Mamba-1 selective-state-space mixer (falcon-mamba, jamba).

The selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is a linear
recurrence evaluated through the first-class ``kernels/ops`` dispatch
(``ops.selective_scan``): the jnp oracle (kernels/ref.selective_scan_ref)
runs a rematerialized time-blocked ``lax.scan``, and the Pallas kernel keeps
the (B, d_inner, d_state) carry in VMEM across sequence blocks. The two are
bitwise-identical through fwd+bwd (the shared custom_vjp differentiates the
oracle), so ``--kernel-impl`` swaps never perturb training numerics.

Decode is O(1): one state update per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ArchConfig


def _ssm_params(view, prefix, cfg: ArchConfig):
    a_log = view.get(prefix + "A_log").astype(jnp.float32)     # (din, n)
    d_skip = view.get(prefix + "D").astype(jnp.float32)        # (din,)
    dt_bias = view.get(prefix + "dt_bias").astype(jnp.float32)  # (din,)
    return a_log, d_skip, dt_bias


def _conv_train(x, w, b, d_conv: int):
    """Causal depthwise conv: x (B,S,din), w (din,K), b (din,)."""
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(d_conv):
        shift = d_conv - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs.astype(jnp.float32) * w[:, k].astype(jnp.float32)
    return out + b.astype(jnp.float32)


def mamba_mixer(view, prefix: str, cfg: ArchConfig, x):
    """Full-sequence mixer. x (B,S,d) -> (y (B,S,d), (h_last, conv_tail))."""
    s = cfg.ssm
    din, n, dtr = cfg.d_inner, s.d_state, cfg.dt_rank
    b, seq, _ = x.shape

    xz = view.mm(prefix + "w_in", x)                           # (B,S,2*din)
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv_w = view.get(prefix + "conv_w")                        # (din, K)
    conv_b = view.get(prefix + "conv_b")
    x_c = jax.nn.silu(_conv_train(x_in, conv_w, conv_b, s.d_conv))
    x_c = x_c.astype(x.dtype)

    xdb = view.mm(prefix + "w_xproj", x_c)                      # (B,S,dtr+2n)
    dt_r = xdb[..., :dtr]
    b_ssm = xdb[..., dtr:dtr + n].astype(jnp.float32)           # (B,S,n)
    c_ssm = xdb[..., dtr + n:].astype(jnp.float32)
    dt_full = view.mm(prefix + "w_dt", dt_r)                    # (B,S,din)
    a_log, d_skip, dt_bias = _ssm_params(view, prefix, cfg)
    dt = jax.nn.softplus(dt_full.astype(jnp.float32) + dt_bias)  # (B,S,din)
    a = -jnp.exp(a_log)                                          # (din,n)

    h0 = jnp.zeros((b, din, n), jnp.float32)
    y, h_last = ops.selective_scan(dt, x_c.astype(jnp.float32), b_ssm, c_ssm,
                                   a, h0)
    y = y + d_skip * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = view.mm(prefix + "w_out", y)
    conv_tail = x_in[:, -(s.d_conv - 1):].astype(jnp.float32) if seq >= s.d_conv - 1 \
        else jnp.pad(x_in.astype(jnp.float32),
                     ((0, 0), (s.d_conv - 1 - seq, 0), (0, 0)))
    return out, (h_last, conv_tail)


def mamba_decode(view, prefix: str, cfg: ArchConfig, x_tok, state):
    """Single-token step. x_tok (B,1,d); state = (h (B,din,n) f32,
    conv_tail (B, K-1, din) f32). Returns (y (B,1,d), new state)."""
    s = cfg.ssm
    din, n, dtr = cfg.d_inner, s.d_state, cfg.dt_rank
    h, conv_tail = state
    b = x_tok.shape[0]

    xz = view.mm(prefix + "w_in", x_tok)                        # (B,1,2din)
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv_w = view.get(prefix + "conv_w").astype(jnp.float32)    # (din,K)
    conv_b = view.get(prefix + "conv_b").astype(jnp.float32)
    window = jnp.concatenate([conv_tail, x_in.astype(jnp.float32)], axis=1)
    x_c = jax.nn.silu(jnp.einsum("bkd,dk->bd", window, conv_w) + conv_b)
    new_tail = window[:, 1:]

    xdb = view.mm(prefix + "w_xproj", x_c[:, None].astype(x_tok.dtype))
    dt_r = xdb[..., :dtr]
    b_ssm = xdb[0:, 0, dtr:dtr + n].astype(jnp.float32)          # (B,n)
    c_ssm = xdb[0:, 0, dtr + n:].astype(jnp.float32)
    dt_full = view.mm(prefix + "w_dt", dt_r)[:, 0]               # (B,din)
    a_log, d_skip, dt_bias = _ssm_params(view, prefix, cfg)
    dt = jax.nn.softplus(dt_full.astype(jnp.float32) + dt_bias)
    a = -jnp.exp(a_log)
    da = jnp.exp(dt[..., None] * a)                              # (B,din,n)
    dbx = (dt * x_c)[..., None] * b_ssm[:, None, :]
    h_new = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h_new, c_ssm) + d_skip * x_c
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = view.mm(prefix + "w_out", y[:, None].astype(x_tok.dtype))
    return out, (h_new, new_tail)


def mamba_state_spec(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    return (
        jax.ShapeDtypeStruct((batch, cfg.d_inner, s.d_state), jnp.float32),
        jax.ShapeDtypeStruct((batch, s.d_conv - 1, cfg.d_inner), jnp.float32),
    )
