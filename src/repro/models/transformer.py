"""Composable decoder (+ optional encoder) language models.

A model is a sequence of *blocks*; each block kind = a mixer (attention /
MLA / Mamba) plus an FFN (dense MLP / MoE / none). Layers of the same kind
are stored as one stacked leaf group and executed with ``lax.scan`` (uniform
models) or a Python loop over the pattern (gemma3's 5:1 local:global, jamba's
mamba/attention interleave). Every weight access goes through the ZeRO
``ParamView`` — the per-layer quantized all-gather therefore happens inside
the scan body, reproducing ZeRO-3's per-module communication schedule, and
the layer loops route through ``view.scan_layers``/``loop_layers`` (the
comm-schedule layer, core/schedule.py) so the engine can rotate its gather
prefetch buffers and thread the streaming-gradient sinks (DESIGN.md §3/§8)
through them without the model code knowing either machine exists.

Caches: full-attention KV and MLA latent caches are *sequence-sharded* over
the mesh's model axes with exact distributed flash-decode; sliding-window
layers use replicated ring buffers; SSM layers carry O(1) state.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..core.partition import GATHER_Q, MATMUL, PLAIN, LeafSpec
from . import layers as L
from .config import ArchConfig, ShapeConfig
from .moe import moe_ffn
from .ssm import mamba_decode, mamba_mixer


# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KindMeta:
    mixer: str                    # attn | mla | mamba
    ffn: str                      # mlp | moe | none
    window: int = 0               # sliding-window size (0 = full)
    theta: float = 10_000.0
    rope: bool = True
    causal: bool = True
    cross: bool = False           # + cross-attention (whisper decoder)
    parallel: bool = False        # parallel residual (GPT-NeoX)


def kind_meta(kind: str, cfg: ArchConfig) -> KindMeta:
    t, tg = cfg.rope_theta, cfg.rope_theta_global
    table = {
        "attn": KindMeta("attn", "mlp", window=cfg.sliding_window, theta=t),
        "attn_local": KindMeta("attn", "mlp", window=cfg.sliding_window, theta=t),
        "attn_global": KindMeta("attn", "mlp", window=0, theta=tg),
        "moe": KindMeta("attn", "moe", window=cfg.sliding_window, theta=t),
        "mla": KindMeta("mla", "mlp", theta=t),
        "neox": KindMeta("attn", "mlp", theta=t, parallel=True),
        "mamba": KindMeta("mamba", "none"),
        "mamba_mlp": KindMeta("mamba", "mlp"),
        "mamba_moe": KindMeta("mamba", "moe"),
        "attn_mlp": KindMeta("attn", "mlp", rope=False),
        "attn_moe": KindMeta("attn", "moe", rope=False),
        "enc": KindMeta("attn", "mlp", rope=False, causal=False),
        "dec": KindMeta("attn", "mlp", rope=False, cross=True),
    }
    return table[kind]


def _norm_specs(name: str, d: int, cfg: ArchConfig) -> dict[str, LeafSpec]:
    out = {name: LeafSpec(name, (d,), PLAIN, init="ones")}
    if cfg.norm == "ln":
        out[name + "_b"] = LeafSpec(name + "_b", (d,), PLAIN, init="zeros")
    return out


def block_specs(kind: str, cfg: ArchConfig) -> dict[str, LeafSpec]:
    """Per-layer leaf specs for one block kind (stack applied by the model)."""
    m = kind_meta(kind, cfg)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hdim
    s: dict[str, LeafSpec] = {}

    def mat(name, shape):
        s[name] = LeafSpec(name, shape, MATMUL)

    if m.mixer == "attn":
        s.update(_norm_specs("ln1", d, cfg))
        mat("wq", (d, h * hd))
        mat("wk", (d, kv * hd))
        mat("wv", (d, kv * hd))
        mat("wo", (h * hd, d))
        if cfg.qkv_bias:
            for b, width in (("bq", h * hd), ("bk", kv * hd), ("bv", kv * hd)):
                s[b] = LeafSpec(b, (width,), PLAIN, init="zeros")
    elif m.mixer == "mla":
        ml = cfg.mla
        s.update(_norm_specs("ln1", d, cfg))
        mat("w_dq", (d, ml.q_lora))
        s["q_norm"] = LeafSpec("q_norm", (ml.q_lora,), PLAIN, init="ones")
        mat("w_uq", (ml.q_lora, h * (ml.qk_nope + ml.qk_rope)))
        mat("w_dkv", (d, ml.kv_lora + ml.qk_rope))
        s["kv_norm"] = LeafSpec("kv_norm", (ml.kv_lora,), PLAIN, init="ones")
        mat("w_ukv", (ml.kv_lora, h * (ml.qk_nope + ml.v_head)))
        mat("wo", (h * ml.v_head, d))
    elif m.mixer == "mamba":
        c = cfg.ssm
        din, dtr = cfg.d_inner, cfg.dt_rank
        s.update(_norm_specs("ln1", d, cfg))
        mat("w_in", (d, 2 * din))
        s["conv_w"] = LeafSpec("conv_w", (din, c.d_conv), PLAIN, init_scale=0.5)
        s["conv_b"] = LeafSpec("conv_b", (din,), PLAIN, init="zeros")
        mat("w_xproj", (din, dtr + 2 * c.d_state))
        mat("w_dt", (dtr, din))
        s["dt_bias"] = LeafSpec("dt_bias", (din,), PLAIN, init="dt_bias")
        s["A_log"] = LeafSpec("A_log", (din, c.d_state), PLAIN, init="ssm_a")
        s["D"] = LeafSpec("D", (din,), PLAIN, init="ones")
        mat("w_out", (din, d))

    if m.cross:
        s.update(_norm_specs("ln_x", d, cfg))
        mat("wq_x", (d, h * hd))
        mat("wk_x", (d, h * hd))
        mat("wv_x", (d, h * hd))
        mat("wo_x", (h * hd, d))

    if m.ffn == "mlp":
        s.update(_norm_specs("ln2", d, cfg))
        ff = cfg.d_ff
        if cfg.act.endswith("_glu"):
            mat("w_gate", (d, ff))
            mat("w_up", (d, ff))
            mat("w_down", (ff, d))
        else:
            mat("w_in", (d, ff))
            mat("w_out_ff", (ff, d))
            if cfg.norm == "ln":
                s["b_in"] = LeafSpec("b_in", (ff,), PLAIN, init="zeros")
                s["b_out"] = LeafSpec("b_out", (d,), PLAIN, init="zeros")
    elif m.ffn == "moe":
        s.update(_norm_specs("ln2", d, cfg))
        e, ff = cfg.moe.n_experts, cfg.moe.d_ff
        s["router"] = LeafSpec("router", (d, e), PLAIN, init_scale=0.02)
        s["w_gate"] = LeafSpec("w_gate", (e, d, ff), GATHER_Q)
        s["w_up"] = LeafSpec("w_up", (e, d, ff), GATHER_Q)
        s["w_down"] = LeafSpec("w_down", (e, ff, d), GATHER_Q)
    return s


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Ctx:
    positions: Any                      # (S_loc,) int32 global positions
    seq_axes: tuple[str, ...] = ()      # cache sequence-sharding axes
    axis_sizes: Any = None              # dict axis -> size (for offsets)
    enc_out: Any = None                 # (B, F, d) encoder output
    want_cache: bool = False
    seq_parallel: bool = False          # activations sharded over seq_axes;
    # attention gathers K/V over seq_axes (gather-KV sequence parallelism)
    q_offset: int = 0                   # global position of local chunk 0


@dataclass(frozen=True)
class DecCtx:
    pos: Any                            # scalar int32: position being written
    seq_axes: tuple[str, ...] = ()
    axis_sizes: Any = None
    enc_out: Any = None


def _norm(v, p, name, x, cfg: ArchConfig):
    if cfg.norm == "ln":
        return L.layer_norm(x, v.get(p + name), v.get(p + name + "_b"))
    return L.rms_norm(x, v.get(p + name))


def _seq_shard(x, ctx) -> Any:
    """Slice this device's seq chunk out of a locally-full (B, S, ...) tensor."""
    if not ctx.seq_axes:
        return x
    n = math.prod(ctx.axis_sizes[a] for a in ctx.seq_axes)
    s_loc = x.shape[1] // n
    off = L.seq_offset(ctx.seq_axes, ctx.axis_sizes, s_loc)
    return lax.dynamic_slice_in_dim(x, off, s_loc, axis=1)


def _to_ring(k, window: int):
    """(B, S, kv, hd) -> ring (B, W, kv, hd) holding positions p at slot p%W."""
    b, s, kv, hd = k.shape
    w = window
    if s < w:
        pad = jnp.zeros((b, w - s, kv, hd), k.dtype)
        return jnp.concatenate([k, pad], axis=1)  # slots 0..s-1 filled
    pos = jnp.arange(s - w, s)
    ring = jnp.zeros((b, w, kv, hd), k.dtype)
    return ring.at[:, pos % w].set(k[:, s - w:])


# ---------------------------------------------------------------------------
# Mixers — full sequence
# ---------------------------------------------------------------------------

def _attn_fwd(v, p, cfg, m: KindMeta, x, ctx: Ctx):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hdim
    q = v.mm(p + "wq", x).reshape(b, s, h, hd)
    k = v.mm(p + "wk", x).reshape(b, s, kv, hd)
    val = v.mm(p + "wv", x).reshape(b, s, kv, hd)
    if cfg.qkv_bias:
        q = q + v.get(p + "bq").reshape(h, hd)
        k = k + v.get(p + "bk").reshape(kv, hd)
        val = val + v.get(p + "bv").reshape(kv, hd)
    if m.rope:
        cos, sin = L.rope_freqs(ctx.positions, hd, m.theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    if ctx.seq_parallel:
        # gather-KV sequence parallelism: q stays local (S/n positions),
        # K/V (already rope'd at their global positions) gathered once
        k_full = lax.all_gather(k, ctx.seq_axes, axis=1, tiled=True)
        v_full = lax.all_gather(val, ctx.seq_axes, axis=1, tiled=True)
        o = L.flash_attention(q, k_full, v_full, causal=m.causal,
                              window=m.window, q_offset=ctx.q_offset)
    else:
        o = L.flash_attention(q, k, val, causal=m.causal, window=m.window)
    out = v.mm(p + "wo", o.reshape(b, s, h * hd))
    cache = None
    if ctx.want_cache:
        if m.window:
            src_k = k_full if ctx.seq_parallel else k
            src_v = v_full if ctx.seq_parallel else val
            cache = {"k": _to_ring(src_k, m.window),
                     "v": _to_ring(src_v, m.window)}
        elif ctx.seq_parallel:
            cache = {"k": k, "v": val}        # already this device's chunk
        else:
            cache = {"k": _seq_shard(k, ctx), "v": _seq_shard(val, ctx)}
    return out, cache


def _cross_fwd(v, p, cfg, x, ctx: Ctx):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hdim
    enc = ctx.enc_out
    f = enc.shape[1]
    q = v.mm(p + "wq_x", x).reshape(b, s, h, hd)
    k = v.mm(p + "wk_x", enc).reshape(b, f, h, hd)
    val = v.mm(p + "wv_x", enc).reshape(b, f, h, hd)
    o = L.flash_attention(q, k, val, causal=False)
    out = v.mm(p + "wo_x", o.reshape(b, s, h * hd))
    cache = {"kx": k, "vx": val} if ctx.want_cache else None
    return out, cache


def _mla_fwd(v, p, cfg, m: KindMeta, x, ctx: Ctx):
    ml = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, vh = ml.qk_nope, ml.qk_rope, ml.v_head
    q_lat = L.rms_norm(v.mm(p + "w_dq", x), v.get(p + "q_norm"))
    q = v.mm(p + "w_uq", q_lat).reshape(b, s, h, nope + rope)
    kv_full = v.mm(p + "w_dkv", x)                       # (B,S,kv_lora+rope)
    kv_lat = L.rms_norm(kv_full[..., :ml.kv_lora], v.get(p + "kv_norm"))
    k_rope = kv_full[..., ml.kv_lora:]                   # (B,S,rope) shared
    cos, sin = L.rope_freqs(ctx.positions, rope, m.theta)
    q_rope = L.apply_rope(q[..., nope:], cos, sin)
    k_rope = L.apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rope)
    q_full = jnp.concatenate([q[..., :nope], q_rope], axis=-1)
    if ctx.seq_parallel:
        # MLA's signature win: gather the *compressed latent* over the seq
        # shards ((kv_lora+rope) per position, ~18x smaller than K+V for
        # minicpm3), then decompress locally for the local-q flash pass.
        lat_loc = jnp.concatenate([kv_lat, k_rope[:, :, 0, :]], axis=-1)
        lat_all = lax.all_gather(lat_loc, ctx.seq_axes, axis=1, tiled=True)
        s_all = lat_all.shape[1]
        kv_up = v.mm(p + "w_ukv",
                     lat_all[..., :ml.kv_lora]).reshape(b, s_all, h,
                                                        nope + vh)
        k_nope, val = kv_up[..., :nope], kv_up[..., nope:]
        k_rope_all = lat_all[:, :, None, ml.kv_lora:]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_all, (b, s_all, h, rope))],
            axis=-1)
        o = L.flash_attention(q_full, k_full, val, causal=True,
                              q_offset=ctx.q_offset)
    else:
        kv_up = v.mm(p + "w_ukv", kv_lat).reshape(b, s, h, nope + vh)
        k_nope, val = kv_up[..., :nope], kv_up[..., nope:]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1)
        o = L.flash_attention(q_full, k_full, val, causal=True)
    out = v.mm(p + "wo", o.reshape(b, s, h * vh))
    cache = None
    if ctx.want_cache:
        lat = jnp.concatenate([kv_lat, k_rope[:, :, 0, :]], axis=-1)
        cache = {"lat": lat if ctx.seq_parallel else _seq_shard(lat, ctx)}
    return out, cache


# ---------------------------------------------------------------------------
# Mixers — decode
# ---------------------------------------------------------------------------

def _attn_decode(v, p, cfg, m: KindMeta, x, cache, dc: DecCtx):
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hdim
    q = v.mm(p + "wq", x).reshape(b, 1, h, hd)
    k = v.mm(p + "wk", x).reshape(b, 1, kv, hd)
    val = v.mm(p + "wv", x).reshape(b, 1, kv, hd)
    if cfg.qkv_bias:
        q = q + v.get(p + "bq").reshape(h, hd)
        k = k + v.get(p + "bk").reshape(kv, hd)
        val = val + v.get(p + "bv").reshape(kv, hd)
    if m.rope:
        posv = L._row_positions(dc.pos, b)[:, None]     # (B,1)
        cos, sin = L.rope_freqs(posv, hd, m.theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    q1, k1, v1 = q[:, 0], k, val
    if m.window:
        w = cache["k"].shape[1]
        slot = dc.pos % w
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype), slot, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype), slot, axis=1)
        o = L.ring_decode(q1, ck, cv, dc.pos, m.window)
    else:
        ck = L.sharded_cache_write(cache["k"], k1, dc.pos,
                                   seq_axes=dc.seq_axes, axis_sizes=dc.axis_sizes)
        cv = L.sharded_cache_write(cache["v"], v1, dc.pos,
                                   seq_axes=dc.seq_axes, axis_sizes=dc.axis_sizes)
        off = L.seq_offset(dc.seq_axes, dc.axis_sizes, ck.shape[1]) \
            if dc.seq_axes else 0
        o = L.flash_decode(q1, ck, cv, dc.pos, seq_axes=dc.seq_axes,
                           seq_offset=off)
    out = v.mm(p + "wo", o.reshape(b, 1, h * hd))
    return out, {"k": ck, "v": cv}


def _cross_decode(v, p, cfg, x, cache, dc: DecCtx):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.hdim
    q = v.mm(p + "wq_x", x).reshape(b, h, hd)
    o = L.flash_decode(q, cache["kx"], cache["vx"],
                       jnp.asarray(cache["kx"].shape[1] - 1))
    return v.mm(p + "wo_x", o.reshape(b, 1, h * hd)), cache


def _mla_decode(v, p, cfg, m: KindMeta, x, cache, dc: DecCtx):
    """Absorbed MLA decode over the compressed latent cache."""
    ml = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, vh = ml.qk_nope, ml.qk_rope, ml.v_head
    q_lat = L.rms_norm(v.mm(p + "w_dq", x), v.get(p + "q_norm"))
    q = v.mm(p + "w_uq", q_lat).reshape(b, 1, h, nope + rope)
    posv = L._row_positions(dc.pos, b)[:, None]         # (B,1)
    cos, sin = L.rope_freqs(posv, rope, m.theta)
    q_rope = L.apply_rope(q[..., nope:], cos, sin)[:, 0]      # (B,h,rope)
    q_nope = q[:, 0, :, :nope]
    kv_full = v.mm(p + "w_dkv", x)                            # (B,1,kv_lora+rope)
    kv_lat = L.rms_norm(kv_full[..., :ml.kv_lora], v.get(p + "kv_norm"))
    k_rope_new = L.apply_rope(kv_full[:, :, None, ml.kv_lora:], cos, sin)[:, :, 0]
    lat_new = jnp.concatenate([kv_lat, k_rope_new], axis=-1)  # (B,1,lora+rope)
    clat = _lat_write(cache["lat"], lat_new, dc)
    # absorbed scores: q_abs (B,h,kv_lora) via W_ukv's key half
    w_ukv = v.get(p + "w_ukv").reshape(ml.kv_lora, h, nope + vh)
    w_k = w_ukv[..., :nope]                                   # (lora,h,nope)
    w_v = w_ukv[..., nope:]                                   # (lora,h,vh)
    q_abs = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))
    lat_c = clat[..., :ml.kv_lora].astype(jnp.float32)        # (B,S,lora)
    rope_c = clat[..., ml.kv_lora:].astype(jnp.float32)       # (B,S,rope)
    s_loc = clat.shape[1]
    off = L.seq_offset(dc.seq_axes, dc.axis_sizes, s_loc) if dc.seq_axes else 0
    kpos = off + jnp.arange(s_loc)
    valid = kpos[None, :] <= L._row_positions(dc.pos, b)[:, None]   # (B,S)
    scores = (jnp.einsum("bhc,bsc->bhs", q_abs, lat_c)
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), rope_c))
    scores = scores / math.sqrt(nope + rope)
    scores = jnp.where(valid[:, None, :], scores, L.NEG_INF)
    m_loc = jnp.max(scores, axis=-1)
    m_g = lax.pmax(m_loc, dc.seq_axes) if dc.seq_axes else m_loc
    pr = jnp.exp(scores - m_g[..., None])
    ctx_lat = jnp.einsum("bhs,bsc->bhc", pr, lat_c)
    den = pr.sum(-1)
    if dc.seq_axes:
        # contract: allow[raw-psum] -- seq-parallel softmax partials over the
        # intra-tier seq axes; fp32 throughout, single-process decode path
        ctx_lat = lax.psum(ctx_lat, dc.seq_axes)
        den = lax.psum(den, dc.seq_axes)  # contract: allow[raw-psum]
    ctx_lat = ctx_lat / jnp.maximum(den[..., None], 1e-30)
    o = jnp.einsum("bhc,chv->bhv", ctx_lat, w_v.astype(jnp.float32))
    out = v.mm(p + "wo", o.reshape(b, 1, h * vh).astype(x.dtype))
    return out, {"lat": clat}


def _lat_write(lat, new, dc: DecCtx):
    """Write (B,1,C) latent row at global pos (scalar or per-row) into the
    seq-sharded (B,S,C) latent cache."""
    b, s_loc, _ = lat.shape
    p = jnp.asarray(dc.pos, jnp.int32)
    if dc.seq_axes:
        idx = L._linear_index(dc.seq_axes, dc.axis_sizes)
        local = p - idx * s_loc
    else:
        local = p
    if p.ndim == 0:
        inb = (local >= 0) & (local < s_loc)
        upd = lax.dynamic_update_slice_in_dim(lat, new.astype(lat.dtype),
                                              jnp.clip(local, 0, s_loc - 1),
                                              axis=1)
        return jnp.where(inb, upd, lat)
    oh = jnp.arange(s_loc)[None, :] == local.reshape(b)[:, None]
    return jnp.where(oh[:, :, None], new.astype(lat.dtype), lat)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def _ffn(v, p, cfg, m: KindMeta, x):
    if m.ffn == "none":
        return None, jnp.zeros((), jnp.float32)
    h = _norm(v, p, "ln2", x, cfg)
    if m.ffn == "moe":
        y, aux = moe_ffn(v, p, cfg, h)
        return y, aux
    if cfg.act.endswith("_glu"):
        act = jax.nn.silu if cfg.act.startswith("silu") else jax.nn.gelu
        y = v.mm(p + "w_down", act(v.mm(p + "w_gate", h)) * v.mm(p + "w_up", h))
    else:
        z = v.mm(p + "w_in", h)
        if cfg.norm == "ln":
            z = z + v.get(p + "b_in")
        y = v.mm(p + "w_out_ff", jax.nn.gelu(z))
        if cfg.norm == "ln":
            y = y + v.get(p + "b_out")
    return y, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Block forward / decode
# ---------------------------------------------------------------------------

def block_fwd(kind: str, v, cfg: ArchConfig, x, ctx: Ctx):
    """Returns (x, aux_loss, cache_entry | None)."""
    m = kind_meta(kind, cfg)
    p = kind + "."
    cache: dict[str, Any] = {}

    h = _norm(v, p, "ln1", x, cfg)
    if m.mixer == "attn":
        o, c = _attn_fwd(v, p, cfg, m, h, ctx)
    elif m.mixer == "mla":
        o, c = _mla_fwd(v, p, cfg, m, h, ctx)
    else:
        o, st = mamba_mixer(v, p, cfg, h)
        c = {"h": st[0], "conv": st[1]} if ctx.want_cache else None
    if c:
        cache.update(c)

    if m.parallel:
        y, aux = _ffn(v, p, cfg, m, x)
        x = x + o + (y if y is not None else 0.0)
    else:
        x = x + o
        if m.cross:
            xo, cc = _cross_fwd(v, p, cfg, _norm(v, p, "ln_x", x, cfg), ctx)
            x = x + xo
            if cc:
                cache.update(cc)
        y, aux = _ffn(v, p, cfg, m, x)
        if y is not None:
            x = x + y
    return x, aux, (cache or None)


def block_decode(kind: str, v, cfg: ArchConfig, x, cache, dc: DecCtx):
    """x (B,1,d); cache = this layer's entry. Returns (x, new_cache)."""
    m = kind_meta(kind, cfg)
    p = kind + "."
    h = _norm(v, p, "ln1", x, cfg)
    new_cache = dict(cache)
    if m.mixer == "attn":
        o, upd = _attn_decode(v, p, cfg, m, h, cache, dc)
        new_cache.update(upd)
    elif m.mixer == "mla":
        o, upd = _mla_decode(v, p, cfg, m, h, cache, dc)
        new_cache.update(upd)
    else:
        o, st = mamba_decode(v, p, cfg, h, (cache["h"], cache["conv"]))
        new_cache.update({"h": st[0], "conv": st[1]})

    if m.parallel:
        y, _ = _ffn(v, p, cfg, m, x)
        x = x + o + (y if y is not None else 0.0)
    else:
        x = x + o
        if m.cross:
            xo, _ = _cross_decode(v, p, cfg, _norm(v, p, "ln_x", x, cfg),
                                  cache, dc)
            x = x + xo
        y, _ = _ffn(v, p, cfg, m, x)
        if y is not None:
            x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

def _sinusoid(positions, d: int):
    half = d // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / half)
    ang = positions.astype(jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class LM:
    """Decoder-only LM (dense/MoE/SSM/hybrid/VLM) or encoder-decoder (audio)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.kinds = list(dict.fromkeys(cfg.pattern))
        self.counts = cfg.kind_counts()

    # -- specs ---------------------------------------------------------------

    def leaf_specs(self) -> dict[str, LeafSpec]:
        cfg = self.cfg
        out: dict[str, LeafSpec] = {
            "embed": LeafSpec("embed", (cfg.vocab, cfg.d_model), MATMUL,
                              init_scale=0.02),
        }
        out.update(_norm_specs("final_norm", cfg.d_model, cfg))
        if not cfg.tie_embeddings:
            out["lm_head"] = LeafSpec("lm_head", (cfg.vocab, cfg.d_model),
                                      MATMUL, init_scale=0.02)
        for kind in self.kinds:
            for n, spec in block_specs(kind, cfg).items():
                name = f"{kind}.{n}"
                out[name] = replace(spec, name=name, stack=self.counts[kind])
        if cfg.enc_layers:
            for n, spec in block_specs("enc", cfg).items():
                name = f"enc.{n}"
                out[name] = replace(spec, name=name, stack=cfg.enc_layers)
            for k, v in _norm_specs("enc_norm", cfg.d_model, cfg).items():
                out[k] = v
        return out

    def _block_names(self, kind: str) -> list[str]:
        return [f"{kind}.{n}" for n in block_specs(kind, self.cfg)]

    # -- embeddings ------------------------------------------------------------

    def _embed(self, view, tokens):
        x = view.embed_lookup("embed", tokens)
        if self.cfg.embed_scale:
            x = x * math.sqrt(self.cfg.d_model)
        return x

    def _head_weight(self, view):
        return view.get("embed") if self.cfg.tie_embeddings \
            else view.get("lm_head")

    def _encode(self, view, frames, ctx: Ctx):
        """Whisper-style encoder over precomputed frame embeddings."""
        cfg = self.cfg
        pos = jnp.arange(frames.shape[1])
        x = frames + _sinusoid(pos, cfg.d_model).astype(frames.dtype)
        ectx = replace(ctx, positions=pos, want_cache=False, enc_out=None)

        names = self._block_names("enc")

        def body(v, c):
            x2, _, _ = block_fwd("enc", v, cfg, c, ectx)
            return x2

        if hasattr(view, "scan_layers"):
            return _norm(view, "", "enc_norm",
                         view.scan_layers(body, x, names), cfg)
        stacked = view.stacked(names)

        def f(c, lp):
            return body(view.sub(lp), c), None

        x, _ = lax.scan(jax.checkpoint(f, prevent_cse=False), x, stacked)
        return _norm(view, "", "enc_norm", x, cfg)

    # -- stack execution ---------------------------------------------------------

    def _run(self, view, x, ctx: Ctx):
        """Full-sequence pass. Returns (x, aux, caches_by_kind | None).

        The layer loops route through ``view.scan_layers``/``loop_layers``
        (the ZeRO ParamView protocol) so the engine's comm-schedule layer
        (core/schedule.py) can rotate its gather-prefetch buffers and
        thread the streaming grad sinks through them; plain views without
        those methods fall back to the inline scan/loop with identical
        semantics.
        """
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)
        caches: dict[str, Any] = {}
        if cfg.uniform:
            kind = cfg.pattern[0]
            names = self._block_names(kind)

            def body(v, c):
                xx, aa = c
                x2, aux, cache = block_fwd(kind, v, cfg, xx, ctx)
                return (x2, aa + aux), cache

            if hasattr(view, "scan_layers"):
                (x, aux), kc = view.scan_layers(body, (x, aux0), names,
                                                with_ys=True)
            else:
                stacked = view.stacked(names)

                def f(c, lp):
                    return body(view.sub(lp), c)

                (x, aux), kc = lax.scan(
                    jax.checkpoint(f, prevent_cse=False), (x, aux0), stacked)
            if ctx.want_cache:
                caches[kind] = kc
        else:
            stacks = {k: view.stacked(self._block_names(k)) for k in self.kinds}
            idx = {k: 0 for k in self.kinds}
            steps = []
            for kind in cfg.pattern:
                i = idx[kind]
                idx[kind] += 1
                steps.append((kind,
                              jax.tree.map(lambda a, i=i: a[i], stacks[kind])))

            def body(v, c, kind):
                xx, aa = c
                x2, aux, cache = block_fwd(kind, v, cfg, xx, ctx)
                return (x2, aa + aux), cache

            if hasattr(view, "loop_layers"):
                (x, aux), ys = view.loop_layers(body, (x, aux0), steps)
            else:
                aux = aux0
                ys = []
                for kind, lp in steps:
                    def one(c, lp_=lp, kind_=kind):
                        return body(view.sub(lp_), c, kind_)

                    (x, aux), cache = jax.checkpoint(
                        one, prevent_cse=False)((x, aux))
                    ys.append(cache)
            if ctx.want_cache:
                percache: dict[str, list] = {k: [] for k in self.kinds}
                for kind, cache in zip(cfg.pattern, ys):
                    percache[kind].append(cache)
                for k, lst in percache.items():
                    caches[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *lst)
        return x, aux, (caches if ctx.want_cache else None)

    def _run_decode(self, view, x, caches, dc: DecCtx):
        cfg = self.cfg
        new: dict[str, Any] = {}
        if cfg.uniform:
            kind = cfg.pattern[0]
            stacked = view.stacked(self._block_names(kind))

            def body(c, inp):
                lp, cl = inp
                x2, nc = block_decode(kind, view.sub(lp), cfg, c, cl, dc)
                return x2, nc

            x, nk = lax.scan(body, x, (stacked, caches[kind]))
            new[kind] = nk
        else:
            stacks = {k: view.stacked(self._block_names(k)) for k in self.kinds}
            idx = {k: 0 for k in self.kinds}
            updated: dict[str, list] = {k: [] for k in self.kinds}
            for kind in cfg.pattern:
                i = idx[kind]
                idx[kind] += 1
                lp = jax.tree.map(lambda a: a[i], stacks[kind])
                cl = jax.tree.map(lambda a: a[i], caches[kind])
                x, nc = block_decode(kind, view.sub(lp), cfg, x, cl, dc)
                updated[kind].append(nc)
            for k, lst in updated.items():
                new[k] = jax.tree.map(lambda *xs: jnp.stack(xs), *lst)
        return x, new

    # -- public entry points --------------------------------------------------

    def loss(self, view, batch):
        """batch: tokens (B, St+1) [+ patches (B,P,d) | frames (B,F,d)]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        x = self._embed(view, inputs)
        n_prefix = 0
        ctx = Ctx(positions=jnp.arange(x.shape[1]))
        if cfg.n_patches:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            n_prefix = cfg.n_patches
            ctx = Ctx(positions=jnp.arange(x.shape[1]))
        if cfg.enc_layers:
            enc = self._encode(view, batch["frames"].astype(x.dtype), ctx)
            ctx = replace(ctx, enc_out=enc)
        x, aux, _ = self._run(view, x, ctx)
        x = _norm(view, "", "final_norm", x, cfg)
        if n_prefix:
            x = x[:, n_prefix:]
        w = self._head_weight(view)
        loss_sum, ntok = L.chunked_cross_entropy(
            x, w, labels, jnp.ones_like(labels, jnp.float32))
        return loss_sum + aux * ntok, ntok

    def sp_eligible(self) -> bool:
        """Gather-KV sequence parallelism needs every mixer to be attention
        (SSM scans have a serial cross-chunk dependency; see DESIGN.md)."""
        return all(kind_meta(k, self.cfg).mixer in ("attn", "mla")
                   for k in self.cfg.pattern)

    def prefill(self, view, batch, *, seq_axes=(), axis_sizes=None,
                seq_parallel: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(view, tokens)
        ctx = Ctx(positions=jnp.arange(x.shape[1]), seq_axes=seq_axes,
                  axis_sizes=axis_sizes, want_cache=True)
        if cfg.n_patches:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            ctx = replace(ctx, positions=jnp.arange(x.shape[1]))
        if cfg.enc_layers:
            enc = self._encode(view, batch["frames"].astype(x.dtype), ctx)
            ctx = replace(ctx, enc_out=enc)

        s_total = x.shape[1]
        n_sp = math.prod(axis_sizes[a] for a in seq_axes) if seq_axes else 1
        seq_parallel = (seq_parallel and self.sp_eligible() and n_sp > 1
                        and s_total % n_sp == 0)
        if seq_parallel:
            s_loc = s_total // n_sp
            off = L.seq_offset(seq_axes, axis_sizes, s_loc)
            x = lax.dynamic_slice_in_dim(x, off, s_loc, axis=1)
            # q_offset is traced (device-dependent) — the jnp flash path
            # masks with traced positions; the Pallas path requires a static
            # offset and falls back automatically (layers.flash_attention).
            ctx = replace(ctx, positions=off + jnp.arange(s_loc),
                          seq_parallel=True, q_offset=off)
        x, _, caches = self._run(view, x, ctx)
        x = _norm(view, "", "final_norm", x, cfg)
        if seq_parallel:
            idx = L._linear_index(seq_axes, axis_sizes)
            x_last = jnp.where(idx == n_sp - 1, x[:, -1:], 0)
            # contract: allow[raw-psum] -- one-hot selection broadcast (only
            # one shard contributes non-zeros): order-exact by construction
            x_last = lax.psum(x_last.astype(jnp.float32),
                              seq_axes).astype(x.dtype)
        else:
            x_last = x[:, -1:]
        logits = self._head_logits(view, x_last)
        caches["pos"] = jnp.asarray(s_total, jnp.int32)
        return logits, caches

    def _head_logits(self, view, x_last):
        name = "embed" if self.cfg.tie_embeddings else "lm_head"
        return view.mm(name, x_last, transpose=True)[:, 0].astype(jnp.float32)

    def decode(self, view, caches, batch, *, seq_axes=(), axis_sizes=None):
        """One token. batch: {"token": (B,) int32, ["row_pos": (B,) int32]}.

        ``row_pos`` (continuous batching) overrides the shared cache position
        with per-row write/attend positions. Returns (logits, caches)."""
        cfg = self.cfg
        pos = batch.get("row_pos", caches["pos"])
        x = self._embed(view, batch["token"][:, None])
        dc = DecCtx(pos=pos, seq_axes=seq_axes, axis_sizes=axis_sizes)
        layer_caches = {k: v for k, v in caches.items() if k != "pos"}
        x, new = self._run_decode(view, x, layer_caches, dc)
        x = _norm(view, "", "final_norm", x, cfg)
        logits = self._head_logits(view, x)
        new["pos"] = (jnp.max(pos) if jnp.ndim(pos) else pos) \
            .astype(jnp.int32) + 1
        return logits, new
