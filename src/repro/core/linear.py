"""The ZeRO-topo weight path as custom-VJP primitives (paper Fig. 4).

``zero_matmul``:
  forward : INT8 block-quantized all-gather of the primary shard over the
            **weight axes** (L0, fastest tier), then the **fused
            dequant-matmul kernel** (kernels/dequant_matmul.py, DESIGN.md
            §5) consumes the gathered wire-format (q, scales) buffer
            directly — the dense weight never round-trips through HBM.
            The forward-gathered quantized copy is sliced into the
            **secondary partition** (ZeRO++: "retains a copy within the
            node") and saved as the only weight residual. Leaves whose
            column dim is not block-aligned (ops.matmul_fusable) fall back
            to the dequant -> matmul pair.
  backward: weights are re-materialized by an all-gather of the secondary
            over the **secondary axes** (intra tier; never crosses the slow
            tier), again kept in wire format for the fused dX = g.Wt.
            The weight gradient is immediately reduce-scattered with INT4
            quantization via one all-to-all over the weight axes, so the
            cotangent has primary-shard layout. On fusable leaves the
            quantize runs *inside* the dW matmul epilogue
            (ops.matmul_quant, DESIGN.md §5): the backward emits wire
            format directly and the dense f32 dW never touches HBM.

Cross-replica reduction is deliberately *deferred*: primaries are marked
device-varying (`pvary`) on entry, the engine performs the hierarchical
stage-2 reduce-scatter and the inter-replica sync after micro-batch
accumulation (paper §V-B/C).

``zero_gather_q`` is the same machinery for weights consumed by non-matmul
ops (embedding lookups, scan parameters): quantized gather forward, quantized
reduce-scatter backward.

The ``*_stream`` variants (DESIGN.md §8) take an extra optimizer-shard
**sink** argument: their backward runs the *full* reduce chain — stage-1 RS
over W (issue/wait split of the INT4 a2a via ``core/schedule.py``), the
seed path's cast through the primary dtype, stage-2 RS over E and the
cross-replica sync — inside the (reverse) scan step, and emits the
fully-reduced fp32 os-layout row as the sink's cotangent. The primary gets
an exact-zero cotangent, so the engine can accumulate microbatch gradients
in os-shard layout (4*psi/os_degree) instead of the primary-layout pytree
(4*psi/w_degree).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..analysis.tags import tag as _contract_tag
from ..kernels import ops
from . import collectives as col
from . import schedule as sched
from .partition import LeafSpec, ZeroConfig, padded_flat_size


def _dtype(cfg: ZeroConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pad_flat(x, padded: int):
    return jnp.pad(x.reshape(-1), (0, padded - x.size))


def _fusable(spec: LeafSpec, cfg: ZeroConfig) -> bool:
    """Route this leaf's matmuls through the fused dequant-matmul kernel?

    Requires the INT8 weight path and a flat block layout that tiles the
    (K, N) view row-by-row (ops.matmul_fusable); everything else falls back
    to the dequant -> matmul pair."""
    return cfg.quantize_weights and \
        ops.matmul_fusable(spec.shape, cfg.quant_block)


def _w_kn(spec: LeafSpec) -> tuple[int, int]:
    n = spec.shape[-1]
    return spec.logical_size // n, n


def _gather_full(primary, spec: LeafSpec, cfg: ZeroConfig):
    """Forward gather -> (w_full(logical shape), sec_q, sec_s)."""
    w_axes = cfg.axes.weight
    n = spec.logical_size
    if cfg.quantize_weights:
        full_flat, qf, sf = col.quant_all_gather_int8(primary, w_axes, cfg, _dtype(cfg))
        if cfg.axes.secondary is not None:
            sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary, cfg)
        else:
            sec_q = sec_s = None
    else:
        full_flat = col.all_gather_flat(primary, w_axes).astype(_dtype(cfg))
        sec_q = sec_s = None
    w = lax.slice(full_flat, (0,), (n,)).reshape(spec.shape)
    return w, sec_q, sec_s


def _gather_full_q(primary, spec: LeafSpec, cfg: ZeroConfig):
    """Forward gather kept in wire format -> (qf, sf, sec_q, sec_s).

    Op-for-op the collective half of ``_gather_full`` (same quantize, same
    two all-gathers — the HLO census is identical), but the dequant is left
    to the fused matmul kernel, so the dense weight never hits HBM."""
    qf, sf = col.gather_issue_int8(primary, cfg.axes.weight, cfg)
    if cfg.axes.secondary is not None:
        sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary, cfg)
    else:
        sec_q = sec_s = None
    return qf, sf, sec_q, sec_s


def _regather_bwd(primary, sec_q, sec_s, spec: LeafSpec, cfg: ZeroConfig):
    """Backward weight re-materialization (secondary if present, else primary).

    issue (schedule.regather_issue: ends at the collective) + wait (local
    dequant) — op-for-op the fused quant_all_gather_int8 / gather_secondary.
    """
    n = spec.logical_size
    if sec_q is not None or cfg.quantize_weights:
        qf, sf = sched.regather_issue(primary, sec_q, sec_s, cfg)
        full_flat = sched.regather_wait(qf, sf, cfg, _dtype(cfg))
    else:
        full_flat = col.all_gather_flat(primary, cfg.axes.weight).astype(_dtype(cfg))
    return lax.slice(full_flat, (0,), (n,)).reshape(spec.shape)


def _regather_bwd_q(primary, sec_q, sec_s, cfg: ZeroConfig):
    """Backward re-gather in wire format -> (qf, sf); same collectives as
    ``_regather_bwd``, dequant deferred to the fused dX matmul."""
    return sched.regather_issue(primary, sec_q, sec_s, cfg)


def _grad_stage1(dw, spec: LeafSpec, cfg: ZeroConfig):
    """Stage-1: full dense weight grad -> primary-layout fp32 shard.

    The INT4 a2a reduce-scatter over the W axes, via the issue/wait split
    (schedule.py machine 3) — composition is bitwise the fused
    ``reduce_scatter_flat``; the split lets XLA overlap the a2a with the
    surrounding backward matmuls (nothing downstream of the issue depends
    on this layer's compute)."""
    padded = padded_flat_size(spec.logical_size, cfg)
    flat = _pad_flat(dw, padded)
    tok = sched.grad_rs_issue(flat, cfg.axes.weight, cfg)
    return sched.grad_rs_wait(tok, cfg, out_dtype=jnp.float32)


GRAD_RS_BITS = 4        # stage-1 wire width (must match grad_rs_issue default)


def _dw_fusable(spec: LeafSpec, cfg: ZeroConfig) -> bool:
    """Fuse the dW matmul with its wire-format quantize (DESIGN.md §5)?

    The gate is impl-invariant (jnp / pallas / pallas_interpret lower the
    same decision): the stage-1 RS must actually be the quantized a2a
    (quantize_grads, group > 1 — the nop/rs branches ship dense f32, there
    is no wire format to fuse into), and the flat quant blocks must tile
    the (K, N) dW view row-by-row, pad included, exactly like the weight
    path's ``_fusable``. Everything else keeps the dense matmul +
    quantize pair."""
    if not cfg.quantize_grads or cfg.size(cfg.axes.weight) <= 1:
        return False
    if not ops.matmul_fusable(spec.shape, cfg.quant_block):
        return False
    padded = padded_flat_size(spec.logical_size, cfg)
    return (padded - spec.logical_size) % cfg.quant_block == 0


def _dw_wire_stage1(x2, g2, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """Fused stage-1: dW is computed straight into wire format.

    ``ops.matmul_quant`` block-quantizes C = x2.T @ g2 in the matmul
    epilogue — the dense f32 dW never round-trips through HBM — and the
    pre-quantized (q, scales) buffers go directly into the a2a exchange
    (``grad_rs_issue_q``; same collectives, tags, and token format as the
    unfused issue). The pad blocks are exact (q=0, scale=1), matching what
    quantize-of-zero-padding ships on the unfused path."""
    padded = padded_flat_size(spec.logical_size, cfg)
    if transpose:
        # dW = (x2.T g2).T = g2.T x2: swap operands instead of transposing
        # the quantized output (wire layout is row-major over N)
        x2, g2 = g2, x2
    q, s = ops.matmul_quant(x2, g2, cfg.quant_block, bits=GRAD_RS_BITS,
                            pad_to=padded, impl=cfg.impl)
    tok = sched.grad_rs_issue_q(q, s, cfg.axes.weight, cfg,
                                bits=GRAD_RS_BITS)
    return sched.grad_rs_wait(tok, cfg, out_dtype=jnp.float32)


def _mm_dw_stage1(x2, g2, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """dW of a matmul backward -> primary-layout fp32 stage-1 shard:
    fused epilogue-quant path when eligible, else the dense matmul +
    ``_grad_stage1`` pair."""
    if _dw_fusable(spec, cfg):
        return _dw_wire_stage1(x2, g2, transpose, spec, cfg)
    dw2 = jnp.matmul(x2.T, g2)
    if transpose:
        dw2 = dw2.T
    return _grad_stage1(dw2.reshape(spec.shape), spec, cfg)


def _os_tail(g1, cfg: ZeroConfig, primary_dtype):
    """Stage-1 shard -> fully-reduced fp32 os-shard row: the cast through
    the primary dtype (the seed path accumulates the primary-layout
    cotangent in that dtype before ``to_os`` lifts it back to f32 — kept so
    streaming is bitwise identical at n_microbatch=1), stage-2 RS over E
    (issue/wait split), cross-replica sync over R."""
    g1 = g1.astype(primary_dtype).astype(jnp.float32)
    tok = sched.grad_rs_issue(g1, cfg.axes.extra_grad, cfg)
    g2 = sched.grad_rs_wait(tok, cfg, out_dtype=jnp.float32)
    return col.cross_replica_grad(g2, cfg, jnp.float32)


def _grad_to_primary_shard(dw, spec: LeafSpec, cfg: ZeroConfig, primary_dtype):
    """Stage-1: full dense weight grad -> primary-shard cotangent (INT4 a2a RS)."""
    return _grad_stage1(dw, spec, cfg).astype(primary_dtype)


def _grad_to_os_shard(dw, spec: LeafSpec, cfg: ZeroConfig, primary_dtype):
    """The streaming tap (DESIGN.md §8): dense weight grad -> fully-reduced
    fp32 optimizer-shard row, emitted inside the backward (stage-1 +
    ``_os_tail``)."""
    return _os_tail(_grad_stage1(dw, spec, cfg), cfg, primary_dtype)


def _zero_primary_cotangent(spec: LeafSpec, cfg: ZeroConfig):
    """Exact-zero cotangent for the primary arg of the ``*_stream`` VJPs
    (the true gradient leaves through the sink; XLA drops these zeros)."""
    shard = padded_flat_size(spec.logical_size, cfg) // cfg.w_degree
    return jnp.zeros((shard,), _dtype(cfg))


def _mm_apply(x, w, transpose, cfg: ZeroConfig):
    w2 = w.reshape(-1, w.shape[-1])
    if transpose:
        w2 = w2.T
    return jnp.matmul(x.astype(_dtype(cfg)), w2)


def _mm_apply_q(x, qf, sf, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """Fused dequant-matmul on the gathered wire-format buffer.

    x (..., K) @ dequant(W (K, N)) (or (..., N) @ W.T when transpose); the
    INT8 payload + per-block scales go straight into the kernel
    (kernels/dequant_matmul.py), impl-dispatched like every other quant op.
    """
    k, n = _w_kn(spec)
    out_dim = k if transpose else n
    x2 = x.reshape(-1, x.shape[-1]).astype(_dtype(cfg))
    # the fused kernel IS the wait of this buffer's issue (no explicit
    # gather_wait_int8 on the fused path) — mark it for analysis.dataflow
    qf, sf = _contract_tag((qf, sf), role="wait", machine="gather")
    y2 = ops.dequant_matmul(x2, qf, sf, (k, n), cfg.quant_block,
                            transpose=transpose, dtype=_dtype(cfg),
                            impl=cfg.impl)
    return y2.reshape(x.shape[:-1] + (out_dim,))


def _mm_bwd_core(res, g, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """Shared matmul backward math for every VJP flavor (inline, prefetched,
    streaming): returns ``(gx, x2, g2)`` — the input cotangent plus the
    f32 2-D dW operands, left unmultiplied so ``_mm_dw_stage1`` can route
    them through the fused matmul-quant epilogue.

    Single implementation on purpose: overlap/streaming on/off must stay
    bitwise-identical (test_overlap.py, test_stream_grads.py), so there is
    exactly one copy of the re-gather / dX / dW math to keep in sync.
    """
    x, primary, sec_q, sec_s = res
    if _fusable(spec, cfg):
        # dX = g @ W.T (or g @ W when the forward was transposed): the
        # re-gathered INT8 secondary feeds the fused kernel directly
        qf, sf = _regather_bwd_q(primary, sec_q, sec_s, cfg)
        gx = _mm_apply_q(g, qf, sf, not transpose, spec, cfg).astype(x.dtype)
    else:
        w = _regather_bwd(primary, sec_q, sec_s, spec, cfg)
        w2 = w.reshape(-1, w.shape[-1])
        if transpose:
            w2 = w2.T
        gx = jnp.matmul(g, w2.T).astype(x.dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    return gx, x2, g2


def _mm_bwd(res, g, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """Inline/prefetched backward: primary-shard weight cotangent."""
    gx, x2, g2 = _mm_bwd_core(res, g, transpose, spec, cfg)
    g1 = _mm_dw_stage1(x2, g2, transpose, spec, cfg)
    return gx, g1.astype(_dtype(cfg))


def _mm_bwd_stream(res, g, transpose, spec: LeafSpec, cfg: ZeroConfig):
    """Streaming backward: fully-reduced fp32 os-shard weight cotangent."""
    gx, x2, g2 = _mm_bwd_core(res, g, transpose, spec, cfg)
    g1 = _mm_dw_stage1(x2, g2, transpose, spec, cfg)
    return gx, _os_tail(g1, cfg, _dtype(cfg))


def make_zero_matmul(spec: LeafSpec, cfg: ZeroConfig):
    """Returns mm(x, primary) computing x @ W (or x @ W.T via transpose arg)."""
    assert len(spec.shape) >= 2
    fuse = _fusable(spec, cfg)

    @partial(jax.custom_vjp, nondiff_argnums=(2,))
    def mm(x, primary, transpose=False):
        if fuse:
            qf, sf, _, _ = _gather_full_q(primary, spec, cfg)
            return _mm_apply_q(x, qf, sf, transpose, spec, cfg)
        w, _, _ = _gather_full(primary, spec, cfg)
        return _mm_apply(x, w, transpose, cfg)

    def fwd(x, primary, transpose):
        if fuse:
            qf, sf, sec_q, sec_s = _gather_full_q(primary, spec, cfg)
            y = _mm_apply_q(x, qf, sf, transpose, spec, cfg)
        else:
            w, sec_q, sec_s = _gather_full(primary, spec, cfg)
            y = _mm_apply(x, w, transpose, cfg)
        if sec_q is None:
            # no secondary: keep primary handle for re-gather (aliases state)
            return y, (x, primary, None, None)
        return y, (x, None, sec_q, sec_s)

    def bwd(transpose, res, g):
        return _mm_bwd(res, g, transpose, spec, cfg)

    mm.defvjp(fwd, bwd)
    return mm


def make_zero_gather_q(spec: LeafSpec, cfg: ZeroConfig):
    """Returns full(primary) -> dense logical tensor with the quantized path."""

    @jax.custom_vjp
    def full(primary):
        w, _, _ = _gather_full(primary, spec, cfg)
        return w

    def fwd(primary):
        w, _, _ = _gather_full(primary, spec, cfg)
        return w, ()

    def bwd(res, g):
        del res
        return (_grad_to_primary_shard(g, spec, cfg, _dtype(cfg)),)

    full.defvjp(fwd, bwd)
    return full


# ---------------------------------------------------------------------------
# Prefetch/overlap variants (DESIGN.md §3)
#
# The engine's double-buffered scheduler issues layer i+1's weight gather
# while layer i computes.  The functions below are the two halves: ``issue``
# runs quantize+gather (ends at the collective, no dequant), and the ``*_pre``
# custom-VJP primitives consume the prefetched buffer instead of gathering
# inline.  The VJPs are identical to the inline ones — the true weight
# gradient still flows to ``primary`` (straight-through, like the inline
# path), and the buffer gets an exact-zero cotangent (float0 for the INT8
# payload) so nothing leaks back through the scan carry.
# ---------------------------------------------------------------------------

def make_gather_issue(spec: LeafSpec, cfg: ZeroConfig):
    """Prefetch half: primary shard -> gathered buffer (tuple pytree)."""

    def issue(primary):
        if cfg.quantize_weights:
            return col.gather_issue_int8(primary, cfg.axes.weight, cfg)
        return (col.all_gather_flat(primary, cfg.axes.weight),)

    return issue


def _consume_buf(buf, spec: LeafSpec, cfg: ZeroConfig):
    """Wait half: prefetched buffer -> (w(logical shape), sec_q, sec_s).

    Op-for-op the tail of ``_gather_full``, so forward results are bitwise
    identical to the inline gather.
    """
    n = spec.logical_size
    if cfg.quantize_weights:
        qf, sf = buf
        full_flat = col.gather_wait_int8(qf, sf, cfg, _dtype(cfg))
        if cfg.axes.secondary is not None:
            sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary, cfg)
        else:
            sec_q = sec_s = None
    else:
        full_flat = buf[0].astype(_dtype(cfg))
        sec_q = sec_s = None
    w = lax.slice(full_flat, (0,), (n,)).reshape(spec.shape)
    return w, sec_q, sec_s


def _buf_zero_cotangent(spec: LeafSpec, cfg: ZeroConfig):
    """Exact-zero cotangent matching the issue() buffer structure."""
    padded = padded_flat_size(spec.logical_size, cfg)
    if cfg.quantize_weights:
        return (np.zeros((padded,), jax.dtypes.float0),
                jnp.zeros((padded // cfg.quant_block,), jnp.float32))
    return (jnp.zeros((padded,), _dtype(cfg)),)


def make_zero_matmul_pre(spec: LeafSpec, cfg: ZeroConfig):
    """mm(x, primary, buf) consuming a prefetched gather buffer."""
    assert len(spec.shape) >= 2
    fuse = _fusable(spec, cfg)

    def _apply(x, buf, transpose):
        if fuse:
            # the prefetch buffer is already wire-format (qf, sf): feed it
            # to the fused kernel, identical to the inline _gather_full_q
            # path (bitwise: same buffer, same kernel)
            qf, sf = buf
            y = _mm_apply_q(x, qf, sf, transpose, spec, cfg)
            if cfg.axes.secondary is not None:
                sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary,
                                                   cfg)
            else:
                sec_q = sec_s = None
            return y, sec_q, sec_s
        w, sec_q, sec_s = _consume_buf(buf, spec, cfg)
        return _mm_apply(x, w, transpose, cfg), sec_q, sec_s

    @partial(jax.custom_vjp, nondiff_argnums=(3,))
    def mm(x, primary, buf, transpose=False):
        y, _, _ = _apply(x, buf, transpose)
        return y

    def fwd(x, primary, buf, transpose):
        y, sec_q, sec_s = _apply(x, buf, transpose)
        if sec_q is None:
            return y, (x, primary, None, None)
        return y, (x, None, sec_q, sec_s)

    def bwd(transpose, res, g):
        gx, dw_shard = _mm_bwd(res, g, transpose, spec, cfg)
        return gx, dw_shard, _buf_zero_cotangent(spec, cfg)

    mm.defvjp(fwd, bwd)
    return mm


def make_zero_gather_q_pre(spec: LeafSpec, cfg: ZeroConfig):
    """full(primary, buf) -> dense logical tensor from a prefetched buffer."""

    @jax.custom_vjp
    def full(primary, buf):
        w, _, _ = _consume_buf(buf, spec, cfg)
        return w

    def fwd(primary, buf):
        return full(primary, buf), ()

    def bwd(res, g):
        del res
        return (_grad_to_primary_shard(g, spec, cfg, _dtype(cfg)),
                _buf_zero_cotangent(spec, cfg))

    full.defvjp(fwd, bwd)
    return full


# ---------------------------------------------------------------------------
# Streaming-grad variants (DESIGN.md §8)
#
# Same forwards as the inline/prefetched primitives, plus an optimizer-shard
# ``sink`` argument that is *ignored* by the forward: its only role is to
# give the backward a leaf to hang the fully-reduced fp32 os-layout
# cotangent on.  The scan stacks those rows into the (layers, os_shard)
# gradient accumulation — the primary's cotangent is exact zero (and DCE'd:
# the engine never differentiates w.r.t. the primaries in streaming mode),
# so the 4*psi/w_degree primary-layout cotangent stack is never built.
# ---------------------------------------------------------------------------

def make_zero_matmul_stream(spec: LeafSpec, cfg: ZeroConfig):
    """mm(x, primary, sink) with the streaming (os-shard cotangent) VJP."""
    assert len(spec.shape) >= 2
    fuse = _fusable(spec, cfg)

    @partial(jax.custom_vjp, nondiff_argnums=(3,))
    def mm(x, primary, sink, transpose=False):
        if fuse:
            qf, sf, _, _ = _gather_full_q(primary, spec, cfg)
            return _mm_apply_q(x, qf, sf, transpose, spec, cfg)
        w, _, _ = _gather_full(primary, spec, cfg)
        return _mm_apply(x, w, transpose, cfg)

    def fwd(x, primary, sink, transpose):
        if fuse:
            qf, sf, sec_q, sec_s = _gather_full_q(primary, spec, cfg)
            y = _mm_apply_q(x, qf, sf, transpose, spec, cfg)
        else:
            w, sec_q, sec_s = _gather_full(primary, spec, cfg)
            y = _mm_apply(x, w, transpose, cfg)
        if sec_q is None:
            return y, (x, primary, None, None)
        return y, (x, None, sec_q, sec_s)

    def bwd(transpose, res, g):
        gx, os_row = _mm_bwd_stream(res, g, transpose, spec, cfg)
        return gx, _zero_primary_cotangent(spec, cfg), os_row

    mm.defvjp(fwd, bwd)
    return mm


def make_zero_matmul_stream_pre(spec: LeafSpec, cfg: ZeroConfig):
    """mm(x, primary, buf, sink): prefetched forward + streaming backward."""
    assert len(spec.shape) >= 2
    fuse = _fusable(spec, cfg)

    def _apply(x, buf, transpose):
        if fuse:
            qf, sf = buf
            y = _mm_apply_q(x, qf, sf, transpose, spec, cfg)
            if cfg.axes.secondary is not None:
                sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary,
                                                   cfg)
            else:
                sec_q = sec_s = None
            return y, sec_q, sec_s
        w, sec_q, sec_s = _consume_buf(buf, spec, cfg)
        return _mm_apply(x, w, transpose, cfg), sec_q, sec_s

    @partial(jax.custom_vjp, nondiff_argnums=(4,))
    def mm(x, primary, buf, sink, transpose=False):
        y, _, _ = _apply(x, buf, transpose)
        return y

    def fwd(x, primary, buf, sink, transpose):
        y, sec_q, sec_s = _apply(x, buf, transpose)
        if sec_q is None:
            return y, (x, primary, None, None)
        return y, (x, None, sec_q, sec_s)

    def bwd(transpose, res, g):
        gx, os_row = _mm_bwd_stream(res, g, transpose, spec, cfg)
        return (gx, _zero_primary_cotangent(spec, cfg),
                _buf_zero_cotangent(spec, cfg), os_row)

    mm.defvjp(fwd, bwd)
    return mm


def make_zero_gather_q_stream(spec: LeafSpec, cfg: ZeroConfig):
    """full(primary, sink) -> dense tensor with the streaming VJP."""

    @jax.custom_vjp
    def full(primary, sink):
        w, _, _ = _gather_full(primary, spec, cfg)
        return w

    def fwd(primary, sink):
        return full(primary, sink), ()

    def bwd(res, g):
        del res
        return (_zero_primary_cotangent(spec, cfg),
                _grad_to_os_shard(g, spec, cfg, _dtype(cfg)))

    full.defvjp(fwd, bwd)
    return full


def make_zero_gather_q_stream_pre(spec: LeafSpec, cfg: ZeroConfig):
    """full(primary, buf, sink): prefetched forward + streaming backward."""

    @jax.custom_vjp
    def full(primary, buf, sink):
        w, _, _ = _consume_buf(buf, spec, cfg)
        return w

    def fwd(primary, buf, sink):
        return full(primary, buf, sink), ()

    def bwd(res, g):
        del res
        return (_zero_primary_cotangent(spec, cfg),
                _buf_zero_cotangent(spec, cfg),
                _grad_to_os_shard(g, spec, cfg, _dtype(cfg)))

    full.defvjp(fwd, bwd)
    return full


def make_plain_gather(spec: LeafSpec, cfg: ZeroConfig):
    """Small params: FP gather over weight axes; AD gives psum_scatter bwd."""
    n = spec.logical_size

    def full(primary):
        flat = col.all_gather_flat(primary, cfg.axes.weight)
        return lax.slice(flat, (0,), (n,)).reshape(spec.shape).astype(_dtype(cfg))

    return full
