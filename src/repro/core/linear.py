"""The ZeRO-topo weight path as custom-VJP primitives (paper Fig. 4).

``zero_matmul``:
  forward : INT8 block-quantized all-gather of the primary shard over the
            **weight axes** (L0, fastest tier), dequant, matmul. The
            forward-gathered quantized copy is sliced into the **secondary
            partition** (ZeRO++: "retains a copy within the node") and saved
            as the only weight residual.
  backward: weights are re-materialized by an all-gather of the secondary
            over the **secondary axes** (intra tier; never crosses the slow
            tier). dX = g.Wt; the weight gradient is immediately
            reduce-scattered with INT4 quantization via one all-to-all over
            the weight axes, so the cotangent has primary-shard layout.

Cross-replica reduction is deliberately *deferred*: primaries are marked
device-varying (`pvary`) on entry, the engine performs the hierarchical
stage-2 reduce-scatter and the inter-replica sync after micro-batch
accumulation (paper §V-B/C).

``zero_gather_q`` is the same machinery for weights consumed by non-matmul
ops (embedding lookups, scan parameters): quantized gather forward, quantized
reduce-scatter backward.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives as col
from .partition import LeafSpec, ZeroConfig, padded_flat_size


def _dtype(cfg: ZeroConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pad_flat(x, padded: int):
    return jnp.pad(x.reshape(-1), (0, padded - x.size))


def _gather_full(primary, spec: LeafSpec, cfg: ZeroConfig):
    """Forward gather -> (w_full(logical shape), sec_q, sec_s)."""
    w_axes = cfg.axes.weight
    n = spec.logical_size
    if cfg.quantize_weights:
        full_flat, qf, sf = col.quant_all_gather_int8(primary, w_axes, cfg, _dtype(cfg))
        if cfg.axes.secondary is not None:
            sec_q, sec_s = col.secondary_slice(qf, sf, cfg.axes.secondary, cfg)
        else:
            sec_q = sec_s = None
    else:
        full_flat = col.all_gather_flat(primary, w_axes).astype(_dtype(cfg))
        sec_q = sec_s = None
    w = lax.slice(full_flat, (0,), (n,)).reshape(spec.shape)
    return w, sec_q, sec_s


def _regather_bwd(primary, sec_q, sec_s, spec: LeafSpec, cfg: ZeroConfig):
    """Backward weight re-materialization (secondary if present, else primary)."""
    n = spec.logical_size
    if sec_q is not None:
        full_flat = col.gather_secondary(sec_q, sec_s, cfg.axes.secondary, cfg,
                                         _dtype(cfg))
    elif cfg.quantize_weights:
        full_flat, _, _ = col.quant_all_gather_int8(primary, cfg.axes.weight,
                                                    cfg, _dtype(cfg))
    else:
        full_flat = col.all_gather_flat(primary, cfg.axes.weight).astype(_dtype(cfg))
    return lax.slice(full_flat, (0,), (n,)).reshape(spec.shape)


def _grad_to_primary_shard(dw, spec: LeafSpec, cfg: ZeroConfig, primary_dtype):
    """Stage-1: full dense weight grad -> primary-shard cotangent (INT4 a2a RS)."""
    padded = padded_flat_size(spec.logical_size, cfg)
    flat = _pad_flat(dw, padded)
    shard = col.reduce_scatter_flat(flat, cfg.axes.weight, cfg,
                                    out_dtype=jnp.float32)
    return shard.astype(primary_dtype)


def make_zero_matmul(spec: LeafSpec, cfg: ZeroConfig):
    """Returns mm(x, primary) computing x @ W (or x @ W.T via transpose arg)."""
    assert len(spec.shape) >= 2

    @partial(jax.custom_vjp, nondiff_argnums=(2,))
    def mm(x, primary, transpose=False):
        w, _, _ = _gather_full(primary, spec, cfg)
        return _apply(x, w, transpose)

    def _apply(x, w, transpose):
        w2 = w.reshape(-1, w.shape[-1])
        if transpose:
            w2 = w2.T
        return jnp.matmul(x.astype(_dtype(cfg)), w2)

    def fwd(x, primary, transpose):
        w, sec_q, sec_s = _gather_full(primary, spec, cfg)
        y = _apply(x, w, transpose)
        if sec_q is None:
            # no secondary: keep primary handle for re-gather (aliases state)
            return y, (x, primary, None, None)
        return y, (x, None, sec_q, sec_s)

    def bwd(transpose, res, g):
        x, primary, sec_q, sec_s = res
        w = _regather_bwd(primary, sec_q, sec_s, spec, cfg)
        w2 = w.reshape(-1, w.shape[-1])
        if transpose:
            w2 = w2.T
        gx = jnp.matmul(g, w2.T).astype(x.dtype)
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
        dw2 = jnp.matmul(x2.T, g2)
        if transpose:
            dw2 = dw2.T
        dw_shard = _grad_to_primary_shard(dw2.reshape(spec.shape), spec, cfg,
                                          _dtype(cfg))
        return gx, dw_shard

    mm.defvjp(fwd, bwd)
    return mm


def make_zero_gather_q(spec: LeafSpec, cfg: ZeroConfig):
    """Returns full(primary) -> dense logical tensor with the quantized path."""

    @jax.custom_vjp
    def full(primary):
        w, _, _ = _gather_full(primary, spec, cfg)
        return w

    def fwd(primary):
        w, _, _ = _gather_full(primary, spec, cfg)
        return w, ()

    def bwd(res, g):
        del res
        return (_grad_to_primary_shard(g, spec, cfg, _dtype(cfg)),)

    full.defvjp(fwd, bwd)
    return full


def make_plain_gather(spec: LeafSpec, cfg: ZeroConfig):
    """Small params: FP gather over weight axes; AD gives psum_scatter bwd."""
    n = spec.logical_size

    def full(primary):
        flat = col.all_gather_flat(primary, cfg.axes.weight)
        return lax.slice(flat, (0,), (n,)).reshape(spec.shape).astype(_dtype(cfg))

    return full
