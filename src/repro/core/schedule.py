"""The comm-schedule layer: every overlap machine in the engine, one idiom.

The engine hides collectives behind compute in three places, and all three
are the same *issue/wait* pattern — start the collective where its inputs
are ready, consume its result where the data is needed, and keep the two
ends data-independent from the compute in between so XLA's latency-hiding
scheduler can run them concurrently:

1. **Forward gather prefetch** (DESIGN.md §3; ZeRO++ §IV, Dash et al. 2023).
   A 2-slot buffer of gathered-quantized weights rotates through the layer
   loop: slot A holds layer i's buffer (being consumed), slot B holds layer
   i+1's, whose quantize + all-gather (``collectives.gather_issue_int8``) is
   already in flight. ``scan_layers`` threads the buffer through the
   ``lax.scan`` carry (prologue issues layer 0, each step issues layer i+1,
   the last layer runs as an epilogue); ``loop_layers`` applies the same
   rotation across heterogeneous Python-unrolled patterns (gemma3 5:1
   local:global, jamba mamba/attn). Gather count stays exactly L per leaf
   per pass — comm volume unchanged, only the schedule moves.

2. **Backward secondary re-gather** (DESIGN.md §5). The weight
   re-materialization for dX is issued in wire format
   (``regather_issue`` -> ``collectives.gather_secondary_q`` /
   ``gather_issue_int8``) and *waited* only where it is consumed — by the
   fused dequant-matmul kernel directly, or by ``regather_wait`` (the local
   dequant) on the unfused fallback.

3. **Backward grad reduce-scatter** (DESIGN.md §8, streaming grad path).
   Each layer's weight cotangent is reduce-scattered *inside* the reverse
   scan step: ``grad_rs_issue`` ends at the collective (quantize + a2a, or
   the plain psum-scatter) and ``grad_rs_wait`` runs the local fused
   dequant-reduce. The result feeds only the optimizer-shard sink cotangent
   — nothing in layer i-1's backward matmuls depends on it — so layer i's
   grad collective overlaps layer i-1's backward compute exactly the way
   slot B's gather overlaps slot A's forward matmuls.

Every split composes op-for-op into its fused primitive
(``quant_all_gather_int8`` / ``a2a_quant_reduce_scatter`` /
``reduce_scatter_flat``), so issue/wait schedules are **bitwise identical**
to the serial ones (tests/test_overlap.py, tests/test_stream_grads.py,
tests/_scenarios.py).

Buffers are ``lax.stop_gradient``'d at issue time: the consuming ``*_pre``
custom VJPs route the true weight gradient to the primary shard (or the
streaming sink), so no cotangent — in particular no transposed collective —
flows back through a rotation.

Memory: forward overlap holds at most two layers' quantized buffers live
(the "2 slots", reported as ``memory_report()["prefetch_buffer"]``). Under
``remat=True`` the scan checkpoint saves its carry per step, which includes
the rotating buffer — an extra ~psi INT8 bytes across the backward pass.
See DESIGN.md §3/§8 for the trade-off tables.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..analysis.tags import tag as _tag
from ..obs import spans as _spans
from . import collectives as col
from .partition import ZeroConfig

AxisTuple = tuple[str, ...]


# ---------------------------------------------------------------------------
# Machine 1: forward gather prefetch (issue half; the *_pre VJPs are the wait)
# ---------------------------------------------------------------------------

def prefetchable_names(fns, names) -> tuple[str, ...]:
    """Leaves with an issue() half (MATMUL / GATHER_Q); PLAIN leaves are
    norm-scale sized and keep their (negligible) inline gather."""
    return tuple(n for n in names if fns[n].issue is not None)


def issue_buffers(fns, primaries, names):
    """Issue the gathers for one layer's prefetchable leaves.

    Returns {name: buffer pytree}. stop_gradient on the *input* keeps the
    whole issue chain (quantize kernel + collective) primal-only: no tangent
    ever enters it (the Pallas quantize has no JVP rule) and no cotangent —
    in particular no transposed collective — flows back through the scan
    carry (see module docstring).
    """
    # obs scope: names this issue site in profiler traces under --trace;
    # a nullcontext otherwise (spans.scope is dead by default, like _tag)
    with _spans.scope("gather/issue"):
        return {n: fns[n].issue(lax.stop_gradient(primaries[n]))
                for n in names}


# ---------------------------------------------------------------------------
# Machine 2: backward secondary re-gather (issue in wire format, wait = local
# dequant or the fused dequant-matmul kernel)
# ---------------------------------------------------------------------------

def regather_issue(primary, sec_q, sec_s, cfg: ZeroConfig):
    """Backward weight re-materialization, kept in wire format (q, scales).

    Gathers the INT8 secondary partition over the secondary axes when one
    exists (never crossing the slow tier), else re-gathers the primary over
    the weight axes. Ends at the collective — the dense weight is never
    built here.
    """
    with _spans.scope("regather/issue"):
        if sec_q is not None:
            return col.gather_secondary_q(sec_q, sec_s, cfg.axes.secondary,
                                          cfg)
        return col.gather_issue_int8(primary, cfg.axes.weight, cfg)


def regather_wait(qf, sf, cfg: ZeroConfig, out_dtype=jnp.bfloat16):
    """Local dequant of a re-gathered wire buffer (unfused fallback; the
    fused dX kernel consumes the wire format directly and skips this)."""
    with _spans.scope("regather/wait"):
        return col.gather_wait_int8(qf, sf, cfg, out_dtype)


# ---------------------------------------------------------------------------
# Machine 3: backward grad reduce-scatter (streaming grad path, DESIGN.md §8)
# ---------------------------------------------------------------------------

def grad_rs_issue(flat, axes: AxisTuple, cfg: ZeroConfig, *,
                  quantized: bool | None = None, bits: int = 4):
    """Issue half of a gradient reduce-scatter over ``axes``: ends at the
    collective (quantize + all-to-all when quantized, the psum-scatter
    itself otherwise). Returns an opaque token for ``grad_rs_wait`` — the
    group size and quantization width ride the token, so mismatched
    issue/wait pairs cannot silently decode the wrong wire format."""
    with _spans.scope("grad_rs/issue"):
        if not axes or cfg.size(axes) == 1:
            return ("nop", _tag(flat, role="issue", machine="grad_rs"))
        if quantized is None:
            quantized = cfg.quantize_grads
        if not quantized:
            return ("rs",
                    _tag(lax.psum_scatter(flat, tuple(axes), tiled=True),
                         role="issue", machine="grad_rs"))
        return ("a2a", _tag(col.a2a_rs_issue(flat, axes, cfg, bits),
                            role="issue", machine="grad_rs"),
                cfg.size(axes), bits)


def grad_rs_issue_q(q, s, axes: AxisTuple, cfg: ZeroConfig, *, bits: int = 4):
    """Issue half for a *pre-quantized* gradient: the wire-format (q, s)
    came out of the fused matmul-quant epilogue (ops.matmul_quant), so only
    the a2a exchange remains. Token format and contract tags are identical
    to the quantized branch of ``grad_rs_issue`` — the verifier census and
    ``grad_rs_wait`` cannot tell the producers apart. Callers gate on
    ``cfg.quantize_grads`` and group size > 1 (the dense nop/rs branches
    have no wire format to skip)."""
    with _spans.scope("grad_rs/issue"):
        assert axes and cfg.size(axes) > 1, axes
        return ("a2a", _tag(col.a2a_rs_issue_q(q, s, axes, cfg),
                            role="issue", machine="grad_rs"),
                cfg.size(axes), bits)


def grad_rs_wait(token, cfg: ZeroConfig, *, out_dtype=jnp.float32):
    """Wait half: local fused dequant + reduce of the received chunks (no
    communication). Everything the receive side needs — group size, bit
    width, payload — rides the token, so issue/wait pairs cannot mismatch.
    ``grad_rs_wait(grad_rs_issue(x)) == collectives.reduce_scatter_flat(x)``
    op-for-op — bitwise."""
    with _spans.scope("grad_rs/wait"):
        kind = token[0]
        if kind in ("nop", "rs"):
            return _tag(token[1], role="wait",
                        machine="grad_rs").astype(out_dtype)
        _, (q2, s2), d, bits = token
        q2, s2 = _tag((q2, s2), role="wait", machine="grad_rs")
        return col.a2a_rs_wait(q2, s2, d, cfg, bits, out_dtype)


# ---------------------------------------------------------------------------
# The buffer-rotation idiom over layer loops (used via ParamView)
# ---------------------------------------------------------------------------

def scan_layers(view, body, carry, names, *, remat: bool = True,
                unroll: int = 1, with_ys: bool = False,
                overlap: bool | None = None):
    """lax.scan over stacked leaves `names` with the prefetch rotation and
    the streaming grad sinks threaded through the xs.

    body(view, carry) -> carry, or (carry, y) when ``with_ys`` (per-layer
    outputs are stacked like lax.scan's ys). ``overlap=None`` inherits the
    view's setting (ZeroConfig.overlap via the engine).

    Overlapped schedule: a prologue issues layer 0's gathers, each scan step
    consumes the carried buffer for layer i while issuing layer i+1's, and
    the last layer runs as an epilogue — so the gather count stays exactly
    one per leaf per layer (comm volume unchanged; only the schedule moves).

    Streaming grads (DESIGN.md §8): when the view carries optimizer-shard
    sinks, each layer's sink row rides the xs next to that layer's
    primaries, so the reverse scan step emits that layer's fully-reduced
    cotangent straight into the stacked os-layout accumulation.
    """
    stacked = view.stacked(names)
    if overlap is None:
        overlap = view._overlap
    fns = view._fns
    pf = prefetchable_names(fns, names) if overlap and fns else ()
    sinks = view.sink_stacks(names)

    def sub(lp, ls, buf=None):
        kw = {}
        if buf is not None:
            kw["bufs"] = buf
        if ls:
            kw["sinks"] = ls
        return view.sub(lp, **kw)

    if not pf:
        def f(c, xs):
            lp, ls = xs
            out = body(sub(lp, ls), c)
            return out if with_ys else (out, None)

        if remat:
            f = jax.checkpoint(f, prevent_cse=False)
        c, ys = lax.scan(f, carry, (stacked, sinks), unroll=unroll)
        return (c, ys) if with_ys else c

    buf0 = issue_buffers(fns, {n: stacked[n][0] for n in pf}, pf)

    def f(c, xs):
        cur, cur_s, nxt = xs
        inner, buf = c
        buf_next = issue_buffers(fns, nxt, pf)
        out = body(sub(cur, cur_s, buf), inner)
        inner, y = out if with_ys else (out, None)
        return (inner, buf_next), y

    def last(c):
        inner, buf = c
        out = body(sub({n: stacked[n][-1] for n in names},
                       {n: sinks[n][-1] for n in sinks}, buf), inner)
        return out if with_ys else (out, None)

    if remat:
        f = jax.checkpoint(f, prevent_cse=False)
        last = jax.checkpoint(last, prevent_cse=False)
    cur = {n: stacked[n][:-1] for n in names}
    cur_s = {n: sinks[n][:-1] for n in sinks}
    nxt = {n: stacked[n][1:] for n in pf}
    c2, ys = lax.scan(f, (carry, buf0), (cur, cur_s, nxt), unroll=unroll)
    carry, y_last = last(c2)
    if not with_ys:
        return carry
    if y_last is not None:
        ys = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b[None]], axis=0), ys, y_last)
    return carry, ys


def loop_layers(view, body, carry, steps, *, remat: bool = True,
                overlap: bool | None = None):
    """Python loop for heterogeneous block patterns.

    steps: sequence of ``(tag, layer_primaries)`` pairs — one entry per
    layer in pattern order, ``layer_primaries`` already indexed out of the
    per-kind stacks. body(view, carry, tag) -> (carry, y).
    Returns (carry, [y per layer]).

    With overlap, layer j+1's gathers are issued alongside layer j's
    compute — including across block-kind boundaries (gemma3's 5:1
    local:global interleave, jamba's mamba/attn mix). Streaming sinks are
    indexed per leaf by occurrence order: leaf names are unique to their
    block kind, so the running count of a name across steps IS its layer
    index within its stacked leaf.
    """
    if overlap is None:
        overlap = view._overlap
    fns = view._fns
    overlap = overlap and fns is not None
    bufs_next = None
    if overlap and len(steps):
        _, lp0 = steps[0]
        bufs_next = issue_buffers(fns, lp0, prefetchable_names(fns, lp0))
    counts: dict[str, int] = {}
    ys = []
    for j, (tag, lp) in enumerate(steps):
        bufs, bufs_next = bufs_next, None
        if overlap and j + 1 < len(steps):
            _, lpn = steps[j + 1]
            bufs_next = issue_buffers(fns, lpn, prefetchable_names(fns, lpn))
        ls = {}
        for n in lp:
            i = counts.get(n, 0)
            counts[n] = i + 1
            sink = view.sink_stack(n)
            if sink is not None:
                ls[n] = sink[i]
        # plain positional sub() for subclasses that don't know about
        # bufs/sinks (serve.resident.ResidentView)
        kw = {}
        if bufs is not None:
            kw["bufs"] = bufs
        if ls:
            kw["sinks"] = ls
        v = view.sub(lp, **kw) if kw else view.sub(lp)

        def one(c, v=v, tag=tag):
            return body(v, c, tag)

        if remat:
            one = jax.checkpoint(one, prevent_cse=False)
        carry, y = one(carry)
        ys.append(y)
    return carry, ys
