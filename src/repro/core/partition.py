"""Hierarchical partitioning: sharding degrees, slice assignment, presets.

The paper's design reduces to one rule: each category of training state is
sharded over a *prefix* of the bandwidth hierarchy,

    weights  ->  W axes               (fastest links;   paper: GCD pair)
    grads    ->  W + E axes           (intra tier;      paper: node, 8 GCDs)
    optimizer->  W + E + R axes       (everything;      paper: all GCDs)

with the AMSP dependency rule ``deg(os) >= deg(grad) >= deg(weight)`` holding
by construction. Flat parameter storage uses a canonical slice hierarchy
[W major, E, R minor]: the collective tuple order passed to
all_gather/psum_scatter/all_to_all is always major-to-minor, which makes every
stage's slice a contiguous refinement of the previous stage's slice (verified
by tests/test_collectives.py).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

AxisTuple = tuple[str, ...]


@dataclass(frozen=True)
class ZeroAxes:
    """Per-category mesh axes, ordered major -> minor within each tuple."""
    weight: AxisTuple          # L0: primary shard + fwd all-gather
    extra_grad: AxisTuple      # E: additional gradient sharding (L1 minus L0)
    replica: AxisTuple         # R: pure data-parallel replication (slowest)
    secondary: AxisTuple | None = None  # secondary partition axes (ZeRO++).
    # The secondary is sliced from the forward-gathered *full* quantized
    # tensor (each member of the secondary group keeps 1/|S| of it), so any
    # axis set works. None = no secondary partition: backward re-gathers the
    # primary (paper's Sec-Degree=W row / plain ZeRO-3).

    def __post_init__(self):
        cats = (self.weight, self.extra_grad, self.replica)
        flat = [a for c in cats for a in c]
        assert len(set(flat)) == len(flat), f"axes must be disjoint: {cats}"
        if self.secondary is not None:
            for a in self.secondary:
                assert a in flat, (a, self)

    @property
    def grad(self) -> AxisTuple:
        return self.weight + self.extra_grad

    @property
    def all(self) -> AxisTuple:  # optimizer axes == all participating axes
        return self.weight + self.extra_grad + self.replica


@dataclass(frozen=True)
class ZeroConfig:
    axes: ZeroAxes
    axis_sizes: tuple[tuple[str, int], ...]   # full mesh axis -> size
    quantize_weights: bool = False      # INT8 block quant on weight all-gather
    quantize_grads: bool = False        # INT4 a2a-based gradient reduce-scatter
    quant_block: int = 512
    cross_replica: str = "allreduce"    # paper: allreduce over R then select;
    # "reduce_scatter": beyond-paper psum_scatter over R (half the volume)
    quantize_update_gather: bool = False  # beyond-paper: INT8 update all-gather
    overlap: bool = False               # double-buffered prefetch of layer i+1's
    # weight all-gather during layer i's compute (DESIGN.md §3). Schedule-only:
    # per-step comm volume and forward numerics are unchanged (test_overlap.py).
    stream_grads: bool = False          # streaming gradient path (DESIGN.md §8):
    # stacked-leaf weight cotangents run the full reduce chain (stage-1 RS
    # over W -> stage-2 RS over E -> cross-replica over R) *inside* the
    # reverse scan step and accumulate in fp32 optimizer-shard layout, so the
    # per-device grad buffer shrinks from 4*psi/w_degree to ~4*psi/os_degree
    # and the per-layer grad collectives overlap the backward matmuls.
    # Layout-neutral: not part of fingerprint() (checkpoints interchange).
    impl: str | None = None             # kernel impl (jnp | pallas |
    # pallas_interpret). None inherits the process default
    # (kernels.ops.set_default_impl — the launchers' --kernel-impl flag and
    # the CI interpret leg's REPRO_KERNEL_IMPL both set it); an explicit
    # value here pins this config regardless of the process default.
    compute_dtype: str = "bfloat16"
    name: str = "custom"

    def size(self, axes: AxisTuple) -> int:
        d = dict(self.axis_sizes)
        return math.prod(d[a] for a in axes) if axes else 1

    @property
    def w_degree(self) -> int:
        return self.size(self.axes.weight)

    @property
    def g_degree(self) -> int:
        return self.size(self.axes.grad)

    @property
    def os_degree(self) -> int:
        return self.size(self.axes.all)

    @property
    def sec_degree(self) -> int | None:
        return None if self.axes.secondary is None else self.size(self.axes.secondary)

    def validate_dependency_rule(self) -> None:
        """AMSP/paper §V: N_os*P_os >= N_g*P_g >= N_w*P_w."""
        assert self.os_degree >= self.g_degree >= self.w_degree, self

    def fingerprint(self) -> dict:
        """Shard-layout identity (JSON-serializable): everything about this
        config that determines how a flat parameter is split across devices.
        ZeroEngine.scheme_fingerprint() extends it with per-leaf padded sizes;
        train/checkpoint.py refuses to restore across different fingerprints."""
        return dict(
            scheme=self.name,
            axes=dict(weight=list(self.axes.weight),
                      extra_grad=list(self.axes.extra_grad),
                      replica=list(self.axes.replica),
                      secondary=None if self.axes.secondary is None
                      else list(self.axes.secondary)),
            axis_sizes={a: s for a, s in self.axis_sizes},
            degrees=dict(w=self.w_degree, g=self.g_degree, os=self.os_degree,
                         sec=self.sec_degree),
            quant_block=self.quant_block,
        )

    def block_for(self, logical_size: int) -> int:
        """Effective quantization block for a leaf: large leaves use the full
        configured block; small leaves (norm scales, biases) shrink it so the
        alignment padding (os_degree * block) never dwarfs the leaf."""
        per_dev = -(-logical_size // self.os_degree)
        b = 4
        while b < per_dev and b < self.quant_block:
            b *= 2
        return b

    def for_leaf(self, logical_size: int) -> "ZeroConfig":
        b = self.block_for(logical_size)
        return self if b == self.quant_block else \
            dataclasses.replace(self, quant_block=b)


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def padded_flat_size(logical_size: int, cfg: ZeroConfig) -> int:
    """Pad so every stage's shard is block-aligned.

    padded % (D_total * block) == 0  =>  primary shard % (|E||R|*block) == 0,
    grad shard % (|R|*block) == 0, optimizer shard % block == 0.
    """
    return round_up(max(logical_size, 1),
                    cfg.os_degree * cfg.block_for(logical_size))


# ---------------------------------------------------------------------------
# Leaf specifications
# ---------------------------------------------------------------------------

MATMUL = "matmul"    # quantized gather + secondary + quantized grad RS (custom_vjp)
GATHER_Q = "gather_q"  # quantized gather of full tensor (embeddings) (custom_vjp)
PLAIN = "plain"      # small params: fp gather over W, AD reduce-scatter
EXPERT = "expert"    # expert-parallel: sharded by computation, never gathered


@dataclass(frozen=True)
class LeafSpec:
    name: str
    shape: tuple[int, ...]          # logical (per-layer) shape
    kind: str = PLAIN
    stack: int | None = None        # leading stacked-layers dimension
    init: str = "normal"            # "normal" | "zeros" | "ones" | "ssm_a" | "dt_bias"
    init_scale: float | None = None  # stddev override (default fan-in)
    expert_axes: AxisTuple = ()     # EXPERT only: mesh axes sharding dim 0

    @property
    def logical_size(self) -> int:
        return math.prod(self.shape)


# ---------------------------------------------------------------------------
# Scheme presets (paper Table IV)
# ---------------------------------------------------------------------------

def preset(scheme: str, *, intra_axes: AxisTuple, inter_axes: AxisTuple,
           axis_sizes: dict[str, int], l0_axes: AxisTuple | None = None,
           **over) -> ZeroConfig:
    """Build a scheme config for a mesh split into bandwidth tiers.

    intra_axes: the fast tier (paper: within node / TPU: short ICI paths).
    inter_axes: the slow tier (paper: Slingshot / TPU: long ICI + DCI).
    l0_axes:    optional fastest sub-tier inside intra (paper: GCD pair).
    """
    sizes = tuple(sorted(axis_sizes.items()))
    every = (l0_axes or ()) + tuple(a for a in intra_axes if a not in (l0_axes or ())) + inter_axes
    if scheme == "zero3":
        axes = ZeroAxes(weight=every, extra_grad=(), replica=())
        cfg = ZeroConfig(axes, sizes, name="zero3", **over)
    elif scheme == "zeropp":
        # ZeRO++: weights sharded over all devices, INT8 weight all-gather,
        # secondary partition within the intra tier (backward gather never
        # crosses the slow tier), INT4 a2a gradient reduce-scatter.
        l0 = l0_axes or ()
        intra_full = l0 + tuple(a for a in intra_axes if a not in l0)
        axes = ZeroAxes(weight=every, extra_grad=(), replica=(),
                        secondary=intra_full)
        cfg = ZeroConfig(axes, sizes, quantize_weights=True, quantize_grads=True,
                         name="zeropp", **over)
    elif scheme == "zero_topo":
        w = l0_axes if l0_axes else intra_axes
        e = tuple(a for a in intra_axes if a not in w)
        # secondary spans the intra tier; kept even when it equals the weight
        # group (paper Table V "Sec-Degree=2": the INT8 copy makes the
        # backward gather quantized without re-quantizing the primary).
        sec = w + e
        axes = ZeroAxes(weight=w, extra_grad=e, replica=inter_axes, secondary=sec)
        cfg = ZeroConfig(axes, sizes, quantize_weights=True, quantize_grads=True,
                         name="zero_topo", **over)
    elif scheme == "zero1":
        axes = ZeroAxes(weight=(), extra_grad=(), replica=every)
        cfg = ZeroConfig(axes, sizes, name="zero1", cross_replica="allreduce", **over)
    elif scheme == "zero2":
        axes = ZeroAxes(weight=(), extra_grad=every, replica=())
        cfg = ZeroConfig(axes, sizes, name="zero2", **over)
    else:
        raise ValueError(scheme)
    cfg.validate_dependency_rule()
    return cfg


def sharding_factor_table(cfg: ZeroConfig) -> dict[str, int]:
    """Paper Table IV row for this config."""
    return {"weights": cfg.w_degree, "grads": cfg.g_degree,
            "optimizer": cfg.os_degree,
            "secondary": cfg.sec_degree or cfg.w_degree}


def weight_memory_bytes(cfg: ZeroConfig, psi: int) -> int:
    """Paper Table V: per-device weight-shard bytes (bf16 primary + INT8 sec)."""
    primary = 2 * psi // cfg.w_degree
    sec = 0 if cfg.sec_degree is None else psi // cfg.sec_degree
    return primary + sec


def grad_memory_bytes(cfg: ZeroConfig, psi: int, *,
                      grad_bytes: int = 4) -> int:
    """Paper Table VI: per-device gradient buffer at the *grad-shard* degree.

    ``grad_bytes``: 4 = this repo's fp32 accumulation, 2 = the paper's fp16
    accounting (benchmarks/memory_table.py prints both, same formula)."""
    return grad_bytes * psi // cfg.g_degree


def grad_buffer_bytes(cfg: ZeroConfig, psi: int, *,
                      streaming: bool | None = None,
                      grad_bytes: int = 4) -> int:
    """Bytes of the gradient buffer the engine *actually allocates*.

    The seed path accumulates microbatch gradients in **primary layout**
    (``grad_bytes * psi / w_degree`` — the full per-layer cotangent stack,
    pre stage-2), strictly more than the paper's Table VI grad-shard figure
    whenever E is non-trivial. The streaming path (``ZeroConfig.
    stream_grads``, DESIGN.md §8) reduces each layer's cotangent to
    **optimizer-shard layout inside the backward**, shrinking the buffer to
    ``grad_bytes * psi / os_degree``. One formula for ``ZeroEngine.
    memory_report``, ``topo.cost`` and ``benchmarks/memory_table.py`` so the
    three can never drift (tests/test_stream_grads.py cross-checks)."""
    if streaming is None:
        streaming = cfg.stream_grads
    deg = cfg.os_degree if streaming else cfg.w_degree
    return grad_bytes * psi // deg


def prefetch_buffer_bytes(cfg: ZeroConfig, layer_bytes: int) -> int:
    """Per-device bytes of the 2-slot gather-prefetch buffer (DESIGN.md §3).

    ``layer_bytes`` is one layer's worth of gathered weights in wire format
    (INT8 payload + f32 scales when quantized, compute dtype otherwise) —
    ``ZeroEngine.memory_report`` computes it per scheme; zero when overlap
    is off."""
    return 2 * layer_bytes if cfg.overlap else 0


def optimizer_memory_bytes(cfg: ZeroConfig, psi: int) -> int:
    """fp32 master + adam m + v, sharded over all devices (K=12)."""
    return 12 * psi // cfg.os_degree


def resident_memory_bytes(cfg: ZeroConfig, psi: int, *,
                          res_degree: int) -> int:
    """Per-device bytes of the serving wire residency (DESIGN.md §12).

    INT8 payload + fp32 per-block scales of the quantized leaves, sharded
    over the residency axes — the secondary partition's footprint applied to
    serving. One formula for ``serve.resident.ResidentLayout.memory_report``
    and the serving cost model (``topo.cost.serve_memory_bytes``) so the two
    can never drift."""
    deg = max(res_degree, 1)
    scales = 4 * psi // max(cfg.quant_block, 1)
    return (psi + scales) // deg
