"""ZeroEngine: the distributed training/serving runtime (paper §V end-to-end).

Storage model (DeepSpeed-style): every parameter leaf is flattened, padded to
a multiple of ``os_degree * quant_block`` and stored as a 1-D *primary shard*
per device, sharded over the **weight axes** (L0) and replicated over the
extra-grad (L1) + replica (L2) axes. Optimizer state (fp32 master, Adam m/v)
lives in *optimizer-shard* layout: the same flat tensor sharded over **all**
axes. Stacked (per-layer) leaves carry a leading layer dimension that
``lax.scan`` consumes, so the per-layer weight all-gather happens inside the
scan body — one gather per layer per pass, exactly ZeRO-3's schedule.

The train step (inside one ``shard_map`` over the full mesh):

  1. value_and_grad of the model loss w.r.t. the primary shards. MATMUL /
     GATHER_Q leaves use the custom-VJP path from ``linear.py`` (INT8 gather
     fwd, secondary-partition re-gather bwd, INT4 all-to-all reduce-scatter of
     the weight grad over the weight axes). Cross-replica reduction is
     deferred: grads stay device-varying over the E/R axes.
  2. stage-2 reduce-scatter of the accumulated primary-layout grads over the
     **extra-grad axes** (paper: intra-node a2a INT4 RS; deferred here to once
     per step instead of once per microbatch — strictly less communication).
  3. cross-replica sync over the **replica axes**: the paper's allreduce +
     select, or (beyond-paper) a reduce-scatter at half the volume.
  4. AdamW on the fp32 master shard; grad-norm clipping uses one scalar psum.
  5. *update all-gather* over (E + R) axes rebuilds the bf16 primary shards
     (volume psi*(d-1)/d over the OS group, paper §V-D), optionally
     INT8-quantized (beyond-paper).

``check_vma=False``: the engine manages replication manually — automatic
psum-insertion on replicated-input cotangents would defeat the paper's
deferred hierarchical gradient sync.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from . import collectives as col
from .linear import (make_gather_issue, make_plain_gather, make_zero_gather_q,
                     make_zero_gather_q_pre, make_zero_matmul,
                     make_zero_matmul_pre)
from .partition import (EXPERT, GATHER_Q, MATMUL, PLAIN, LeafSpec, ZeroConfig,
                        padded_flat_size)
from .prefetch import issue_buffers, prefetchable_names


def host_scalar(v):
    """Fetch a replicated scalar as a host numpy value on any process.

    Reading the first *addressable* shard is the whole fetch for a fully
    replicated array; a plain ``np.asarray``/``float`` would demand every
    shard and fail on multi-process arrays under older jax. The single
    shared implementation for trainer step counters, metric fetches and the
    test harness.
    """
    if hasattr(v, "addressable_data"):
        return np.asarray(v.addressable_data(0))
    return v


# ---------------------------------------------------------------------------
# Parameter views
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _LeafFns:
    spec: LeafSpec
    mm: Callable | None
    full: Callable
    issue: Callable | None = None      # prefetch: primary -> gathered buffer
    mm_pre: Callable | None = None     # matmul consuming a prefetched buffer
    full_pre: Callable | None = None   # dense tensor from a prefetched buffer


class ParamView:
    """What model code sees: named weights, materialized on demand.

    ``mm(name, x)`` runs the ZeRO matmul (gather fwd / secondary re-gather
    bwd / quantized grad RS) without ever saving the dense weight;
    ``get(name)`` materializes the dense tensor (embeddings, norms, scan
    params). For stacked leaves, ``stacked(names)`` returns the raw stacked
    primaries to feed ``lax.scan`` and ``sub(layer_slice)`` rebinds the view
    inside the scan body.

    With ``overlap=True`` (ZeroConfig.overlap), ``scan_layers``/``loop_layers``
    rotate a 2-slot prefetch buffer through the layer loop (prefetch.py):
    views bound inside the loop carry the current layer's pre-gathered
    quantized weights in ``bufs`` and consume them via the ``*_pre`` VJPs
    instead of gathering inline.
    """

    # class-level defaults so subclasses with their own __init__
    # (serve.resident.ResidentView, which also has no _fns) inherit the
    # non-overlap behavior without any getattr probing
    _fns: dict[str, "_LeafFns"] | None = None
    _bufs: dict[str, Any] | None = None
    _overlap: bool = False

    def __init__(self, fns: dict[str, _LeafFns], primaries: dict[str, Any],
                 bufs: dict[str, Any] | None = None, overlap: bool = False):
        self._fns = fns
        self._p = primaries
        self._bufs = bufs
        self._overlap = overlap

    def _buf(self, name: str):
        return None if self._bufs is None else self._bufs.get(name)

    def mm(self, name: str, x, transpose: bool = False):
        fn = self._fns[name]
        assert fn.mm is not None, f"{name} is not a matmul leaf"
        buf = self._buf(name)
        if buf is not None and fn.mm_pre is not None:
            return fn.mm_pre(x, self._p[name], buf, transpose)
        return fn.mm(x, self._p[name], transpose)

    def get(self, name: str):
        fn = self._fns[name]
        buf = self._buf(name)
        if buf is not None and fn.full_pre is not None:
            return fn.full_pre(self._p[name], buf)
        return fn.full(self._p[name])

    def embed_lookup(self, name: str, ids):
        """Token-embedding gather. Overridable (resident TP shards rows)."""
        import jax.numpy as jnp
        return jnp.take(self.get(name), ids, axis=0)

    def expert_ffn(self, prefix: str, e_in):
        """MoE expert GLU FFN on dispatched slots e_in (E, C, d) -> (E, C, d).

        Default: dense-materialized experts (ZeRO gather). ResidentView
        overrides with Megatron-style sharded experts + one psum.
        """
        import jax
        import jax.numpy as jnp
        wg = self.get(prefix + "w_gate")
        wu = self.get(prefix + "w_up")
        wd = self.get(prefix + "w_down")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", e_in, wg)) \
            * jnp.einsum("ecd,edf->ecf", e_in, wu)
        return jnp.einsum("ecf,efd->ecd", h, wd)

    def has(self, name: str) -> bool:
        return name in self._p

    def stacked(self, names) -> dict[str, Any]:
        return {n: self._p[n] for n in names}

    def sub(self, primaries: dict[str, Any],
            bufs: dict[str, Any] | None = None) -> "ParamView":
        return ParamView(self._fns, primaries, bufs=bufs,
                         overlap=self._overlap)

    def scan_layers(self, body, carry, names, *, remat: bool = True,
                    unroll: int = 1, with_ys: bool = False,
                    overlap: bool | None = None):
        """lax.scan over stacked leaves `names`.

        body(view, carry) -> carry, or (carry, y) when ``with_ys`` (per-layer
        outputs are stacked like lax.scan's ys). ``overlap=None`` inherits the
        view's setting (ZeroConfig.overlap via the engine).

        Overlapped schedule (prefetch.py): a prologue issues layer 0's
        gathers, each scan step consumes the carried buffer for layer i while
        issuing layer i+1's, and the last layer runs as an epilogue — so the
        gather count stays exactly one per leaf per layer (comm volume
        unchanged; only the schedule moves).
        """
        stacked = self.stacked(names)
        if overlap is None:
            overlap = self._overlap
        fns = self._fns
        pf = prefetchable_names(fns, names) if overlap and fns else ()
        if not pf:
            def f(c, layer_p):
                out = body(self.sub(layer_p), c)
                return out if with_ys else (out, None)

            if remat:
                f = jax.checkpoint(f, prevent_cse=False)
            c, ys = lax.scan(f, carry, stacked, unroll=unroll)
            return (c, ys) if with_ys else c

        buf0 = issue_buffers(fns, {n: stacked[n][0] for n in pf}, pf)

        def f(c, xs):
            cur, nxt = xs
            inner, buf = c
            buf_next = issue_buffers(fns, nxt, pf)
            out = body(self.sub(cur, bufs=buf), inner)
            inner, y = out if with_ys else (out, None)
            return (inner, buf_next), y

        def last(c):
            inner, buf = c
            out = body(self.sub({n: stacked[n][-1] for n in names},
                                bufs=buf), inner)
            return out if with_ys else (out, None)

        if remat:
            f = jax.checkpoint(f, prevent_cse=False)
            last = jax.checkpoint(last, prevent_cse=False)
        cur = {n: stacked[n][:-1] for n in names}
        nxt = {n: stacked[n][1:] for n in pf}
        c2, ys = lax.scan(f, (carry, buf0), (cur, nxt), unroll=unroll)
        carry, y_last = last(c2)
        if not with_ys:
            return carry
        if y_last is not None:
            ys = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                ys, y_last)
        return carry, ys

    def loop_layers(self, body, carry, steps, *, remat: bool = True,
                    overlap: bool | None = None):
        """Python loop for heterogeneous block patterns.

        steps: sequence of ``(tag, layer_primaries)`` pairs — one entry per
        layer in pattern order, ``layer_primaries`` already indexed out of the
        per-kind stacks. body(view, carry, tag) -> (carry, y).
        Returns (carry, [y per layer]).

        With overlap, layer j+1's gathers are issued alongside layer j's
        compute — including across block-kind boundaries (gemma3's 5:1
        local:global interleave, jamba's mamba/attn mix).
        """
        if overlap is None:
            overlap = self._overlap
        fns = self._fns
        overlap = overlap and fns is not None
        bufs_next = None
        if overlap and len(steps):
            _, lp0 = steps[0]
            bufs_next = issue_buffers(fns, lp0,
                                      prefetchable_names(fns, lp0))
        ys = []
        for j, (tag, lp) in enumerate(steps):
            bufs, bufs_next = bufs_next, None
            if overlap and j + 1 < len(steps):
                _, lpn = steps[j + 1]
                bufs_next = issue_buffers(fns, lpn,
                                          prefetchable_names(fns, lpn))
            # plain two-arg sub() for subclasses that don't know about bufs
            v = self.sub(lp, bufs=bufs) if bufs is not None else self.sub(lp)

            def one(c, v=v, tag=tag):
                return body(v, c, tag)

            if remat:
                one = jax.checkpoint(one, prevent_cse=False)
            carry, y = one(carry)
            ys.append(y)
        return carry, ys


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _storage_shape(spec: LeafSpec, shard_len: int) -> tuple[int, ...]:
    return (spec.stack, shard_len) if spec.stack else (shard_len,)


@dataclass
class TrainHparams:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    n_microbatch: int = 1
    overlap: bool | None = None   # None = follow ZeroConfig.overlap; a bool
    # here overrides the scheme config (launch/train.py --overlap plumbs this)


class ZeroEngine:
    """Builds sharded state + train/serve steps for one model under one scheme."""

    def __init__(self, specs: dict[str, LeafSpec], cfg: ZeroConfig, mesh: Mesh,
                 hp: TrainHparams | None = None):
        if hp is not None and hp.overlap is not None \
                and hp.overlap != cfg.overlap:
            import dataclasses
            cfg = dataclasses.replace(cfg, overlap=hp.overlap)
        cfg.validate_dependency_rule()
        for a, size in cfg.axis_sizes:
            assert a in mesh.axis_names and mesh.shape[a] == size, \
                (a, size, dict(mesh.shape))
        self.specs = dict(specs)
        self.cfg = cfg
        self.mesh = mesh
        self.hp = hp or TrainHparams()
        # per-leaf configs: small leaves get a reduced quant block so the
        # os_degree*block alignment padding stays proportionate
        self.leaf_cfg = {n: cfg.for_leaf(s.logical_size)
                         for n, s in self.specs.items()}
        self.fns = {n: self._build_fns(s) for n, s in self.specs.items()}

        self._pad = {n: padded_flat_size(s.logical_size, cfg)
                     for n, s in self.specs.items()}

    # -- per-leaf machinery --------------------------------------------------

    def _layer_spec(self, spec: LeafSpec) -> LeafSpec:
        import dataclasses
        return dataclasses.replace(spec, stack=None)

    def _build_fns(self, spec: LeafSpec) -> _LeafFns:
        ls = self._layer_spec(spec)
        cfg = self.leaf_cfg[spec.name] if spec.name in self.leaf_cfg \
            else self.cfg.for_leaf(ls.logical_size)
        if spec.kind == MATMUL:
            return _LeafFns(spec, make_zero_matmul(ls, cfg),
                            make_zero_gather_q(ls, cfg),
                            issue=make_gather_issue(ls, cfg),
                            mm_pre=make_zero_matmul_pre(ls, cfg),
                            full_pre=make_zero_gather_q_pre(ls, cfg))
        if spec.kind == GATHER_Q:
            return _LeafFns(spec, None, make_zero_gather_q(ls, cfg),
                            issue=make_gather_issue(ls, cfg),
                            full_pre=make_zero_gather_q_pre(ls, cfg))
        if spec.kind == PLAIN:
            return _LeafFns(spec, None, make_plain_gather(ls, cfg))
        raise ValueError(spec.kind)

    # -- shapes & shardings ---------------------------------------------------

    def primary_shard_len(self, name: str) -> int:
        return self._pad[name] // self.cfg.w_degree

    def os_shard_len(self, name: str) -> int:
        return self._pad[name] // self.cfg.os_degree

    def _primary_spec(self, spec: LeafSpec) -> P:
        w = self.cfg.axes.weight
        return P(None, w) if spec.stack else P(w)

    def _os_spec(self, spec: LeafSpec) -> P:
        a = self.cfg.axes.all
        return P(None, a) if spec.stack else P(a)

    def state_shardings(self):
        prim = {n: NamedSharding(self.mesh, self._primary_spec(s))
                for n, s in self.specs.items()}
        osd = {n: NamedSharding(self.mesh, self._os_spec(s))
               for n, s in self.specs.items()}
        rep = NamedSharding(self.mesh, P())
        return dict(primaries=prim, master=osd, opt_m=osd, opt_v=osd, step=rep)

    def state_in_specs(self):
        prim = {n: self._primary_spec(s) for n, s in self.specs.items()}
        osd = {n: self._os_spec(s) for n, s in self.specs.items()}
        return dict(primaries=prim, master=osd, opt_m=osd, opt_v=osd, step=P())

    def abstract_state(self):
        """ShapeDtypeStructs (global shapes) with shardings — for .lower()."""
        sh = self.state_shardings()
        cdt = jnp.dtype(self.cfg.compute_dtype)

        def leaf(n, s, dtype, kind):
            length = self._pad[n]
            return jax.ShapeDtypeStruct(_storage_shape(s, length), dtype,
                                        sharding=sh[kind][n] if kind != "step" else sh["step"])

        state = dict(
            primaries={n: leaf(n, s, cdt, "primaries") for n, s in self.specs.items()},
            master={n: leaf(n, s, jnp.float32, "master") for n, s in self.specs.items()},
            opt_m={n: leaf(n, s, jnp.float32, "opt_m") for n, s in self.specs.items()},
            opt_v={n: leaf(n, s, jnp.float32, "opt_v") for n, s in self.specs.items()},
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=sh["step"]),
        )
        return state

    def scheme_fingerprint(self) -> dict:
        """Layout identity of this engine's checkpoints (JSON-serializable).

        Everything that determines the on-disk shard layout: a checkpoint
        written under one fingerprint cannot be restored under another
        (train/checkpoint.py fails loudly on mismatch).
        """
        fp = self.cfg.fingerprint()
        fp["padded_sizes"] = {n: self._pad[n] for n in sorted(self._pad)}
        return fp

    def param_count(self) -> int:
        return sum(s.logical_size * (s.stack or 1) for s in self.specs.values())

    def padded_param_count(self) -> int:
        return sum(self._pad[n] * (s.stack or 1) for n, s in self.specs.items())

    def memory_report(self) -> dict[str, float]:
        """Per-device training-state bytes (paper Tables V/VI analogue)."""
        cfg = self.cfg
        psi = self.padded_param_count()
        bytes_per = jnp.dtype(cfg.compute_dtype).itemsize
        primary = bytes_per * psi // cfg.w_degree
        sec = 0 if cfg.sec_degree is None else \
            (psi // cfg.sec_degree + 4 * psi // (cfg.quant_block * cfg.sec_degree))
        grads_buf = 4 * psi // cfg.w_degree       # fp32 accumulation, primary layout
        optimizer = 12 * psi // cfg.os_degree
        return dict(primary=primary, secondary=sec, grad_buffer=grads_buf,
                    optimizer=optimizer,
                    total=primary + sec + grads_buf + optimizer)

    # -- init -----------------------------------------------------------------

    def _init_full(self, name: str, key) -> jnp.ndarray:
        """Global padded fp32 init for one leaf (layout: [stack,] pad)."""
        spec = self.specs[name]
        pad = self._pad[name]
        n = spec.logical_size
        shape = _storage_shape(spec, pad)
        if spec.init == "zeros":
            return jnp.zeros(shape, jnp.float32)
        if spec.init == "ones":
            base = jnp.ones((spec.stack or 1, n), jnp.float32)
        elif spec.init == "ssm_a":
            # mamba: A_log = log(1..d_state) broadcast over d_inner
            d_inner, d_state = spec.shape
            a = jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32))
            base = jnp.broadcast_to(a, (spec.stack or 1, d_inner, d_state))
            base = base.reshape(spec.stack or 1, n)
        elif spec.init == "dt_bias":
            import numpy as _np
            lo, hi = 1e-3, 1e-1
            u = jax.random.uniform(key, (spec.stack or 1, n), jnp.float32)
            base = jnp.log(jnp.exp(jnp.exp(u * (math.log(hi) - math.log(lo))
                                           + math.log(lo))) - 1.0 + 1e-9)
        else:
            scale = spec.init_scale
            if scale is None:
                fan_in = spec.shape[0] if len(spec.shape) >= 2 else n
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            base = jax.random.normal(key, (spec.stack or 1, n), jnp.float32) * scale
        full = jnp.zeros((spec.stack or 1, pad), jnp.float32)
        full = lax.dynamic_update_slice_in_dim(full, base, 0, axis=1)
        return full if spec.stack else full[0]

    def init_state(self, key):
        """jit-compiled global init; out_shardings place the shards."""
        sh = self.state_shardings()
        names = sorted(self.specs)
        keys = {n: k for n, k in zip(names, jax.random.split(key, len(names)))}

        def build():
            master = {n: self._init_full(n, keys[n]) for n in names}
            prim = {n: master[n].astype(self.cfg.compute_dtype) for n in names}
            zeros = {n: jnp.zeros_like(master[n]) for n in names}
            return dict(primaries=prim, master=master, opt_m=zeros,
                        opt_v={n: jnp.zeros_like(master[n]) for n in names},
                        step=jnp.zeros((), jnp.int32))

        out_sh = dict(primaries=sh["primaries"], master=sh["master"],
                      opt_m=sh["opt_m"], opt_v=sh["opt_v"], step=sh["step"])
        return jax.jit(build, out_shardings=out_sh)()

    # -- schedule --------------------------------------------------------------

    def _lr(self, step):
        hp = self.hp
        warm = jnp.minimum(step / max(hp.warmup_steps, 1), 1.0)
        t = jnp.clip((step - hp.warmup_steps)
                     / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
        cos = hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return hp.lr * warm * cos

    # -- the train step ---------------------------------------------------------

    def make_train_step(self, loss_fn: Callable, batch_specs: dict[str, P]):
        """loss_fn(view, batch) -> (loss_sum, token_count). Returns jit'd step."""
        cfg = self.cfg
        hp = self.hp
        mesh = self.mesh
        state_specs = self.state_in_specs()

        def local_step(state, batch):
            primaries = state["primaries"]

            def mb_loss(prims, mb):
                view = ParamView(self.fns, prims, overlap=cfg.overlap)
                loss_sum, tok = loss_fn(view, mb)
                gtok = lax.psum(tok.astype(jnp.float32), cfg.axes.all)
                return loss_sum.astype(jnp.float32) / jnp.maximum(gtok, 1.0), gtok

            n_mb = hp.n_microbatch
            if n_mb == 1:
                (loss, gtok), grads = jax.value_and_grad(mb_loss, has_aux=True)(
                    primaries, batch)
            else:
                def split(x):
                    return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])
                mbs = jax.tree.map(split, batch)

                def acc(carry, mb):
                    gacc, lacc, tacc = carry
                    (l, t), g = jax.value_and_grad(mb_loss, has_aux=True)(
                        primaries, mb)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + l, tacc + t), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), primaries)
                (grads, loss, gtok), _ = lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), mbs)
                # each microbatch loss is normalized by its own global token
                # count; average the accumulated means
                grads = jax.tree.map(lambda g: g / n_mb, grads)
                loss = loss / n_mb

            # global loss for reporting: sum of per-device (local/global_tok).
            # det_psum, not lax.psum: the reduction order must not depend on
            # how the mesh is split across processes (tests/_mp.py asserts a
            # 2x4 cluster reproduces the 1x8 run bitwise). gtok above stays a
            # plain psum — token counts are integers in float32, exact in
            # any summation order.
            loss_rep = col.det_psum(loss, cfg.axes.all)

            # stage 2 + 3: primary-layout grads -> optimizer-shard grads
            def to_os(name, g):
                lcfg = self.leaf_cfg[name]
                g = g.astype(jnp.float32)
                flat = g.reshape(-1, g.shape[-1]) if g.ndim > 1 else g[None]

                def one(row):
                    row = col.reduce_scatter_flat(row, lcfg.axes.extra_grad,
                                                  lcfg)
                    return col.cross_replica_grad(row, lcfg)

                out = jax.vmap(one)(flat)
                return out if g.ndim > 1 else out[0]

            os_grads = {n: to_os(n, g) for n, g in grads.items()}

            # grad-norm clip (global: os shards partition the full gradient).
            # det_psum: gnorm feeds the clip scale applied to every gradient,
            # so a transport-dependent reduction order here would make the
            # entire update drift across process layouts.
            sq = sum(jnp.sum(jnp.square(g)) for g in os_grads.values())
            gnorm = jnp.sqrt(col.det_psum(sq, cfg.axes.all))
            scale = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-6))
            os_grads = {n: g * scale for n, g in os_grads.items()}

            # AdamW on the master shard (pure per-shard update: paper §V-C)
            from ..optim.adamw import adamw_update
            step = state["step"] + 1
            lr = self._lr(state["step"])
            b1, b2 = hp.betas
            new_m, new_v, new_master, new_prim = {}, {}, {}, {}
            for n in sorted(self.specs):
                wd = hp.weight_decay if self.specs[n].kind in (MATMUL, GATHER_Q) else 0.0
                master, m, v = adamw_update(
                    state["master"][n], state["opt_m"][n], state["opt_v"][n],
                    os_grads[n], step=step, lr=lr, beta1=b1, beta2=b2,
                    eps=hp.eps, weight_decay=wd)
                new_m[n], new_v[n], new_master[n] = m, v, master
                # update all-gather: os shard -> primary shard (bf16)
                ms = master.reshape(-1, master.shape[-1]) if master.ndim > 1 else master[None]
                lcfg = self.leaf_cfg[n]
                gathered = jax.vmap(
                    lambda row: col.update_all_gather(row, lcfg,
                                                      jnp.dtype(cfg.compute_dtype)))(ms)
                new_prim[n] = gathered if master.ndim > 1 else gathered[0]

            new_state = dict(primaries=new_prim, master=new_master,
                             opt_m=new_m, opt_v=new_v, step=step)
            # gtok: global token count summed over every microbatch (with
            # n_mb == 1 it is the single microbatch's global count). Both it
            # and loss_rep/gnorm are psummed over cfg.axes.all — which
            # includes any process-spanning axis — so the metrics leaving the
            # step are CLUSTER-global, not process-local; metrics_to_host
            # fetches them on every process without a second collective.
            metrics = dict(loss=loss_rep, grad_norm=gnorm, lr=lr, tokens=gtok)
            return new_state, metrics

        sm = shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, {k: P() for k in
                                     ("loss", "grad_norm", "lr", "tokens")}),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(0,))

    @staticmethod
    def metrics_to_host(metrics) -> dict[str, float]:
        """Fetch step metrics as python floats on every process.

        The train/eval steps emit metrics with out_spec ``P()`` after a psum
        over ``cfg.axes.all``, so each metric is fully replicated — globally
        aggregated already, even when the mesh spans processes.
        """
        return {k: float(host_scalar(v)) for k, v in metrics.items()}

    # -- eval / serve steps ------------------------------------------------------

    def make_eval_step(self, loss_fn: Callable, batch_specs: dict[str, P]):
        state_specs = self.state_in_specs()

        def local_eval(state, batch):
            view = ParamView(self.fns, state["primaries"],
                             overlap=self.cfg.overlap)
            loss_sum, tok = loss_fn(view, batch)
            # gtok: integer-valued, exact under any order; loss: det_psum so
            # eval losses match bitwise across process layouts (train step
            # rationale above)
            gtok = lax.psum(tok.astype(jnp.float32), self.cfg.axes.all)
            loss = col.det_psum(loss_sum.astype(jnp.float32),
                                self.cfg.axes.all)
            return loss / jnp.maximum(gtok, 1.0)

        sm = shard_map(local_eval, mesh=self.mesh,
                           in_specs=(state_specs, batch_specs),
                           out_specs=P(), check_vma=False)
        return jax.jit(sm)

    def make_apply(self, fn: Callable, in_specs, out_specs):
        """Generic shard_map-wrapped forward: fn(view, *args)."""
        prim_specs = self.state_in_specs()["primaries"]

        def local(primaries, *args):
            view = ParamView(self.fns, primaries, overlap=self.cfg.overlap)
            return fn(view, *args)

        sm = shard_map(local, mesh=self.mesh,
                           in_specs=(prim_specs,) + tuple(in_specs),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    def abstract_primaries(self):
        sh = self.state_shardings()["primaries"]
        cdt = jnp.dtype(self.cfg.compute_dtype)
        return {n: jax.ShapeDtypeStruct(
            _storage_shape(s, self._pad[n]), cdt, sharding=sh[n])
            for n, s in self.specs.items()}
