"""ZeroEngine: the distributed training/serving runtime (paper §V end-to-end).

Storage model (DeepSpeed-style): every parameter leaf is flattened, padded to
a multiple of ``os_degree * quant_block`` and stored as a 1-D *primary shard*
per device, sharded over the **weight axes** (L0) and replicated over the
extra-grad (L1) + replica (L2) axes. Optimizer state (fp32 master, Adam m/v)
lives in *optimizer-shard* layout: the same flat tensor sharded over **all**
axes. Stacked (per-layer) leaves carry a leading layer dimension that
``lax.scan`` consumes, so the per-layer weight all-gather happens inside the
scan body — one gather per layer per pass, exactly ZeRO-3's schedule.

The train step (inside one ``shard_map`` over the full mesh):

  1. value_and_grad of the model loss. MATMUL / GATHER_Q leaves use the
     custom-VJP path from ``linear.py`` (INT8 gather fwd, secondary-partition
     re-gather bwd, INT4 all-to-all reduce-scatter of the weight grad over
     the weight axes). Seed regime: differentiate w.r.t. the primary shards;
     cross-replica reduction is deferred and grads stay device-varying over
     the E/R axes. Streaming regime (``ZeroConfig.stream_grads``, §8):
     differentiate w.r.t. fp32 os-shard *sinks* — stacked leaves run the
     full reduce chain inside the reverse scan step and the accumulation
     buffer is os-layout (4psi/os instead of 4psi/w).
  2. stage-2 reduce-scatter of the accumulated primary-layout grads over the
     **extra-grad axes** (paper: intra-node a2a INT4 RS). Seed: once per
     step, after the backward; streaming: already folded into step 1, per
     layer per microbatch, overlapped with the backward matmuls.
  3. cross-replica sync over the **replica axes**: the paper's allreduce +
     select, or (beyond-paper) a reduce-scatter at half the volume (also
     folded into step 1 when streaming).
  4. AdamW on the fp32 master shard; grad-norm clipping uses one scalar psum.
  5. *update all-gather* over (E + R) axes rebuilds the bf16 primary shards
     (volume psi*(d-1)/d over the OS group, paper §V-D), optionally
     INT8-quantized (beyond-paper); stacked leaves gather their last axis in
     one batched collective.

``check_vma=False``: the engine manages replication manually — automatic
psum-insertion on replicated-input cotangents would defeat the paper's
deferred hierarchical gradient sync.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..analysis.tags import tag as _contract_tag
from ..compat import shard_map
from . import collectives as col
from . import schedule as sched
from .linear import (make_gather_issue, make_plain_gather, make_zero_gather_q,
                     make_zero_gather_q_pre, make_zero_gather_q_stream,
                     make_zero_gather_q_stream_pre, make_zero_matmul,
                     make_zero_matmul_pre, make_zero_matmul_stream,
                     make_zero_matmul_stream_pre)
from .partition import (EXPERT, GATHER_Q, MATMUL, PLAIN, LeafSpec, ZeroConfig,
                        grad_buffer_bytes, padded_flat_size,
                        prefetch_buffer_bytes)


def host_scalar(v):
    """Fetch a replicated scalar as a host numpy value on any process.

    Reading the first *addressable* shard is the whole fetch for a fully
    replicated array; a plain ``np.asarray``/``float`` would demand every
    shard and fail on multi-process arrays under older jax. The single
    shared implementation for trainer step counters, metric fetches and the
    test harness.
    """
    if hasattr(v, "addressable_data"):
        return np.asarray(v.addressable_data(0))
    return v


# ---------------------------------------------------------------------------
# Parameter views
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _LeafFns:
    spec: LeafSpec
    mm: Callable | None
    full: Callable
    issue: Callable | None = None      # prefetch: primary -> gathered buffer
    mm_pre: Callable | None = None     # matmul consuming a prefetched buffer
    full_pre: Callable | None = None   # dense tensor from a prefetched buffer
    # streaming-grad variants (DESIGN.md §8): take an os-shard sink whose
    # cotangent is the fully-reduced fp32 gradient row; built only for
    # stacked MATMUL/GATHER_Q leaves (the layer loop)
    mm_stream: Callable | None = None
    mm_stream_pre: Callable | None = None
    full_stream: Callable | None = None
    full_stream_pre: Callable | None = None


class ParamView:
    """What model code sees: named weights, materialized on demand.

    ``mm(name, x)`` runs the ZeRO matmul (gather fwd / secondary re-gather
    bwd / quantized grad RS) without ever saving the dense weight;
    ``get(name)`` materializes the dense tensor (embeddings, norms, scan
    params). For stacked leaves, ``stacked(names)`` returns the raw stacked
    primaries to feed ``lax.scan`` and ``sub(layer_slice)`` rebinds the view
    inside the scan body.

    With ``overlap=True`` (ZeroConfig.overlap), ``scan_layers``/``loop_layers``
    rotate a 2-slot prefetch buffer through the layer loop (schedule.py):
    views bound inside the loop carry the current layer's pre-gathered
    quantized weights in ``bufs`` and consume them via the ``*_pre`` VJPs
    instead of gathering inline.

    With ``sinks`` (ZeroConfig.stream_grads, DESIGN.md §8), the top-level
    view carries the per-leaf os-shard gradient sinks; the layer loops
    thread one row per layer to the bound sub-views, whose ``mm``/``get``
    route through the ``*_stream`` VJPs so each layer's weight cotangent is
    fully reduced inside the backward.
    """

    # class-level defaults so subclasses with their own __init__
    # (serve.resident.ResidentView, which also has no _fns) inherit the
    # non-overlap behavior without any getattr probing
    _fns: dict[str, "_LeafFns"] | None = None
    _bufs: dict[str, Any] | None = None
    _sinks: dict[str, Any] | None = None
    _overlap: bool = False

    def __init__(self, fns: dict[str, _LeafFns], primaries: dict[str, Any],
                 bufs: dict[str, Any] | None = None, overlap: bool = False,
                 sinks: dict[str, Any] | None = None):
        self._fns = fns
        self._p = primaries
        self._bufs = bufs
        self._overlap = overlap
        self._sinks = sinks

    def _buf(self, name: str):
        return None if self._bufs is None else self._bufs.get(name)

    def _sink(self, name: str):
        return None if self._sinks is None else self._sinks.get(name)

    def sink_stack(self, name: str):
        """Full (layers, os_shard) sink for a stacked leaf, else None."""
        return self._sink(name)

    def sink_stacks(self, names) -> dict[str, Any]:
        return {} if self._sinks is None else \
            {n: self._sinks[n] for n in names if n in self._sinks}

    def mm(self, name: str, x, transpose: bool = False):
        fn = self._fns[name]
        assert fn.mm is not None, f"{name} is not a matmul leaf"
        buf = self._buf(name)
        sink = self._sink(name)
        if sink is not None:
            sink = _contract_tag(sink, role="sink", machine="stream",
                                 name=name)
            if buf is not None and fn.mm_stream_pre is not None:
                return fn.mm_stream_pre(x, self._p[name], buf, sink, transpose)
            if fn.mm_stream is not None:
                return fn.mm_stream(x, self._p[name], sink, transpose)
        if buf is not None and fn.mm_pre is not None:
            return fn.mm_pre(x, self._p[name], buf, transpose)
        return fn.mm(x, self._p[name], transpose)

    def get(self, name: str):
        fn = self._fns[name]
        buf = self._buf(name)
        sink = self._sink(name)
        if sink is not None:
            sink = _contract_tag(sink, role="sink", machine="stream",
                                 name=name)
            if buf is not None and fn.full_stream_pre is not None:
                return fn.full_stream_pre(self._p[name], buf, sink)
            if fn.full_stream is not None:
                return fn.full_stream(self._p[name], sink)
        if buf is not None and fn.full_pre is not None:
            return fn.full_pre(self._p[name], buf)
        return fn.full(self._p[name])

    def embed_lookup(self, name: str, ids):
        """Token-embedding gather. Overridable (resident TP shards rows)."""
        import jax.numpy as jnp
        return jnp.take(self.get(name), ids, axis=0)

    def expert_ffn(self, prefix: str, e_in):
        """MoE expert GLU FFN on dispatched slots e_in (E, C, d) -> (E, C, d).

        Default: dense-materialized experts (ZeRO gather). ResidentView
        overrides with Megatron-style sharded experts + one psum.
        """
        import jax
        import jax.numpy as jnp
        wg = self.get(prefix + "w_gate")
        wu = self.get(prefix + "w_up")
        wd = self.get(prefix + "w_down")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", e_in, wg)) \
            * jnp.einsum("ecd,edf->ecf", e_in, wu)
        return jnp.einsum("ecf,efd->ecd", h, wd)

    def has(self, name: str) -> bool:
        return name in self._p

    def stacked(self, names) -> dict[str, Any]:
        return {n: self._p[n] for n in names}

    def sub(self, primaries: dict[str, Any],
            bufs: dict[str, Any] | None = None,
            sinks: dict[str, Any] | None = None) -> "ParamView":
        return ParamView(self._fns, primaries, bufs=bufs,
                         overlap=self._overlap, sinks=sinks)

    def scan_layers(self, body, carry, names, *, remat: bool = True,
                    unroll: int = 1, with_ys: bool = False,
                    overlap: bool | None = None):
        """lax.scan over stacked leaves `names`, via the comm-schedule layer
        (core/schedule.py): the 2-slot gather-prefetch rotation and the
        streaming grad sinks both ride the scan xs/carry there.

        body(view, carry) -> carry, or (carry, y) when ``with_ys`` (per-layer
        outputs are stacked like lax.scan's ys). ``overlap=None`` inherits
        the view's setting (ZeroConfig.overlap via the engine).
        """
        return sched.scan_layers(self, body, carry, names, remat=remat,
                                 unroll=unroll, with_ys=with_ys,
                                 overlap=overlap)

    def loop_layers(self, body, carry, steps, *, remat: bool = True,
                    overlap: bool | None = None):
        """Python loop for heterogeneous block patterns, via
        core/schedule.py (same rotation/sink threading as ``scan_layers``,
        across block-kind boundaries — gemma3's 5:1 local:global interleave,
        jamba's mamba/attn mix).

        steps: sequence of ``(tag, layer_primaries)`` pairs — one entry per
        layer in pattern order, ``layer_primaries`` already indexed out of
        the per-kind stacks. body(view, carry, tag) -> (carry, y).
        Returns (carry, [y per layer]).
        """
        return sched.loop_layers(self, body, carry, steps, remat=remat,
                                 overlap=overlap)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _storage_shape(spec: LeafSpec, shard_len: int) -> tuple[int, ...]:
    return (spec.stack, shard_len) if spec.stack else (shard_len,)


@dataclass
class TrainHparams:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    n_microbatch: int = 1
    overlap: bool | None = None   # None = follow ZeroConfig.overlap; a bool
    # here overrides the scheme config (launch/train.py --overlap plumbs this)
    stream_grads: bool | None = None  # None = follow ZeroConfig.stream_grads;
    # a bool overrides the scheme config (launch/train.py --stream-grads)


class ZeroEngine:
    """Builds sharded state + train/serve steps for one model under one scheme."""

    def __init__(self, specs: dict[str, LeafSpec], cfg: ZeroConfig, mesh: Mesh,
                 hp: TrainHparams | None = None):
        if hp is not None:
            over = {}
            if hp.overlap is not None and hp.overlap != cfg.overlap:
                over["overlap"] = hp.overlap
            if hp.stream_grads is not None \
                    and hp.stream_grads != cfg.stream_grads:
                over["stream_grads"] = hp.stream_grads
            if over:
                import dataclasses
                cfg = dataclasses.replace(cfg, **over)
        cfg.validate_dependency_rule()
        for a, size in cfg.axis_sizes:
            assert a in mesh.axis_names and mesh.shape[a] == size, \
                (a, size, dict(mesh.shape))
        self.specs = dict(specs)
        self.cfg = cfg
        self.mesh = mesh
        self.hp = hp or TrainHparams()
        # per-leaf configs: small leaves get a reduced quant block so the
        # os_degree*block alignment padding stays proportionate
        self.leaf_cfg = {n: cfg.for_leaf(s.logical_size)
                         for n, s in self.specs.items()}
        self.fns = {n: self._build_fns(s) for n, s in self.specs.items()}

        self._pad = {n: padded_flat_size(s.logical_size, cfg)
                     for n, s in self.specs.items()}

    # -- per-leaf machinery --------------------------------------------------

    def _layer_spec(self, spec: LeafSpec) -> LeafSpec:
        import dataclasses
        return dataclasses.replace(spec, stack=None)

    def _build_fns(self, spec: LeafSpec) -> _LeafFns:
        ls = self._layer_spec(spec)
        cfg = self.leaf_cfg[spec.name] if spec.name in self.leaf_cfg \
            else self.cfg.for_leaf(ls.logical_size)
        # streaming variants exist only for stacked leaves: a stacked leaf's
        # per-layer slice is consumed exactly once per pass, so its stage-2
        # quantization sees the same values as the seed path (bitwise at
        # n_microbatch=1); a shared non-stacked leaf (tied embeddings) can
        # be used twice per pass and stays on the primary-layout path
        stream = bool(spec.stack)
        if spec.kind == MATMUL:
            return _LeafFns(
                spec, make_zero_matmul(ls, cfg),
                make_zero_gather_q(ls, cfg),
                issue=make_gather_issue(ls, cfg),
                mm_pre=make_zero_matmul_pre(ls, cfg),
                full_pre=make_zero_gather_q_pre(ls, cfg),
                mm_stream=make_zero_matmul_stream(ls, cfg) if stream else None,
                mm_stream_pre=make_zero_matmul_stream_pre(ls, cfg)
                if stream else None,
                full_stream=make_zero_gather_q_stream(ls, cfg)
                if stream else None,
                full_stream_pre=make_zero_gather_q_stream_pre(ls, cfg)
                if stream else None)
        if spec.kind == GATHER_Q:
            return _LeafFns(
                spec, None, make_zero_gather_q(ls, cfg),
                issue=make_gather_issue(ls, cfg),
                full_pre=make_zero_gather_q_pre(ls, cfg),
                full_stream=make_zero_gather_q_stream(ls, cfg)
                if stream else None,
                full_stream_pre=make_zero_gather_q_stream_pre(ls, cfg)
                if stream else None)
        if spec.kind == PLAIN:
            return _LeafFns(spec, None, make_plain_gather(ls, cfg))
        raise ValueError(spec.kind)

    # -- shapes & shardings ---------------------------------------------------

    def primary_shard_len(self, name: str) -> int:
        return self._pad[name] // self.cfg.w_degree

    def os_shard_len(self, name: str) -> int:
        return self._pad[name] // self.cfg.os_degree

    def _primary_spec(self, spec: LeafSpec) -> P:
        w = self.cfg.axes.weight
        return P(None, w) if spec.stack else P(w)

    def _os_spec(self, spec: LeafSpec) -> P:
        a = self.cfg.axes.all
        return P(None, a) if spec.stack else P(a)

    def state_shardings(self):
        prim = {n: NamedSharding(self.mesh, self._primary_spec(s))
                for n, s in self.specs.items()}
        osd = {n: NamedSharding(self.mesh, self._os_spec(s))
               for n, s in self.specs.items()}
        rep = NamedSharding(self.mesh, P())
        return dict(primaries=prim, master=osd, opt_m=osd, opt_v=osd, step=rep)

    def state_in_specs(self):
        prim = {n: self._primary_spec(s) for n, s in self.specs.items()}
        osd = {n: self._os_spec(s) for n, s in self.specs.items()}
        return dict(primaries=prim, master=osd, opt_m=osd, opt_v=osd, step=P())

    def abstract_state(self):
        """ShapeDtypeStructs (global shapes) with shardings — for .lower()."""
        sh = self.state_shardings()
        cdt = jnp.dtype(self.cfg.compute_dtype)

        def leaf(n, s, dtype, kind):
            length = self._pad[n]
            return jax.ShapeDtypeStruct(_storage_shape(s, length), dtype,
                                        sharding=sh[kind][n] if kind != "step" else sh["step"])

        state = dict(
            primaries={n: leaf(n, s, cdt, "primaries") for n, s in self.specs.items()},
            master={n: leaf(n, s, jnp.float32, "master") for n, s in self.specs.items()},
            opt_m={n: leaf(n, s, jnp.float32, "opt_m") for n, s in self.specs.items()},
            opt_v={n: leaf(n, s, jnp.float32, "opt_v") for n, s in self.specs.items()},
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=sh["step"]),
        )
        return state

    def scheme_fingerprint(self) -> dict:
        """Layout identity of this engine's checkpoints (JSON-serializable).

        Everything that determines the on-disk shard layout: a checkpoint
        written under one fingerprint cannot be restored under another
        (train/checkpoint.py fails loudly on mismatch).
        """
        fp = self.cfg.fingerprint()
        fp["padded_sizes"] = {n: self._pad[n] for n in sorted(self._pad)}
        return fp

    def param_count(self) -> int:
        return sum(s.logical_size * (s.stack or 1) for s in self.specs.values())

    def padded_param_count(self) -> int:
        return sum(self._pad[n] * (s.stack or 1) for n, s in self.specs.items())

    def stream_leaf_names(self) -> tuple[str, ...]:
        """Leaves on the streaming grad path (stacked MATMUL/GATHER_Q):
        their microbatch gradients accumulate in fp32 os-shard layout."""
        return tuple(n for n in sorted(self.specs)
                     if self.specs[n].stack
                     and self.specs[n].kind in (MATMUL, GATHER_Q))

    def _prefetch_slot_bytes(self) -> int:
        """One slot of the 2-slot gather-prefetch buffer (DESIGN.md §3): the
        largest single layer's gathered wire-format weights — INT8 payload +
        f32 scales when quantized, compute dtype otherwise — summed over
        that layer's prefetchable leaves."""
        per_kind: dict[str, int] = {}
        bytes_per = jnp.dtype(self.cfg.compute_dtype).itemsize
        for n, s in self.specs.items():
            if not s.stack or self.fns[n].issue is None:
                continue
            kind = n.split(".", 1)[0]
            pad = self._pad[n]
            lcfg = self.leaf_cfg[n]
            b = pad + 4 * pad // lcfg.quant_block \
                if lcfg.quantize_weights else bytes_per * pad
            per_kind[kind] = per_kind.get(kind, 0) + b
        return max(per_kind.values(), default=0)

    def memory_report(self) -> dict[str, float]:
        """Per-device training-state bytes (paper Tables V/VI analogue).

        ``grad_buffer`` is exact per-leaf accounting of what the step
        allocates: streamed leaves (``stream_leaf_names``) at fp32 os-shard
        layout, everything else at the fp32 primary-layout accumulation —
        one shared formula with ``benchmarks/memory_table.py`` and
        ``topo.cost`` (partition.grad_buffer_bytes). ``prefetch_buffer`` is
        the 2-slot gathered-weight buffer the §3 overlap schedule keeps
        live (0 when overlap is off)."""
        cfg = self.cfg
        psi = self.padded_param_count()
        bytes_per = jnp.dtype(cfg.compute_dtype).itemsize
        primary = bytes_per * psi // cfg.w_degree
        sec = 0 if cfg.sec_degree is None else \
            (psi // cfg.sec_degree + 4 * psi // (cfg.quant_block * cfg.sec_degree))
        stream = set(self.stream_leaf_names()) if cfg.stream_grads else set()
        grads_buf = sum(
            grad_buffer_bytes(cfg, self._pad[n] * (s.stack or 1),
                              streaming=(n in stream))
            for n, s in self.specs.items())
        optimizer = 12 * psi // cfg.os_degree
        prefetch = prefetch_buffer_bytes(cfg, self._prefetch_slot_bytes())
        return dict(primary=primary, secondary=sec, grad_buffer=grads_buf,
                    optimizer=optimizer, prefetch_buffer=prefetch,
                    total=primary + sec + grads_buf + optimizer + prefetch)

    # -- init -----------------------------------------------------------------

    def _init_full(self, name: str, key) -> jnp.ndarray:
        """Global padded fp32 init for one leaf (layout: [stack,] pad)."""
        spec = self.specs[name]
        pad = self._pad[name]
        n = spec.logical_size
        shape = _storage_shape(spec, pad)
        if spec.init == "zeros":
            return jnp.zeros(shape, jnp.float32)
        if spec.init == "ones":
            base = jnp.ones((spec.stack or 1, n), jnp.float32)
        elif spec.init == "ssm_a":
            # mamba: A_log = log(1..d_state) broadcast over d_inner
            d_inner, d_state = spec.shape
            a = jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32))
            base = jnp.broadcast_to(a, (spec.stack or 1, d_inner, d_state))
            base = base.reshape(spec.stack or 1, n)
        elif spec.init == "dt_bias":
            import numpy as _np
            lo, hi = 1e-3, 1e-1
            u = jax.random.uniform(key, (spec.stack or 1, n), jnp.float32)
            base = jnp.log(jnp.exp(jnp.exp(u * (math.log(hi) - math.log(lo))
                                           + math.log(lo))) - 1.0 + 1e-9)
        else:
            scale = spec.init_scale
            if scale is None:
                fan_in = spec.shape[0] if len(spec.shape) >= 2 else n
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            base = jax.random.normal(key, (spec.stack or 1, n), jnp.float32) * scale
        full = jnp.zeros((spec.stack or 1, pad), jnp.float32)
        full = lax.dynamic_update_slice_in_dim(full, base, 0, axis=1)
        return full if spec.stack else full[0]

    def init_state(self, key):
        """jit-compiled global init; out_shardings place the shards."""
        sh = self.state_shardings()
        names = sorted(self.specs)
        keys = {n: k for n, k in zip(names, jax.random.split(key, len(names)))}

        def build():
            master = {n: self._init_full(n, keys[n]) for n in names}
            prim = {n: master[n].astype(self.cfg.compute_dtype) for n in names}
            zeros = {n: jnp.zeros_like(master[n]) for n in names}
            return dict(primaries=prim, master=master, opt_m=zeros,
                        opt_v={n: jnp.zeros_like(master[n]) for n in names},
                        step=jnp.zeros((), jnp.int32))

        out_sh = dict(primaries=sh["primaries"], master=sh["master"],
                      opt_m=sh["opt_m"], opt_v=sh["opt_v"], step=sh["step"])
        return jax.jit(build, out_shardings=out_sh)()

    # -- schedule --------------------------------------------------------------

    def _lr(self, step):
        hp = self.hp
        warm = jnp.minimum(step / max(hp.warmup_steps, 1), 1.0)
        t = jnp.clip((step - hp.warmup_steps)
                     / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
        cos = hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return hp.lr * warm * cos

    # -- the train step ---------------------------------------------------------

    # -- post-backward helpers (shared by both grad regimes) -------------------

    def _zero_sinks(self):
        """fp32 optimizer-shard gradient sinks for the streamed leaves: the
        zeros whose cotangent stack IS the os-layout accumulation buffer."""
        return {n: jnp.zeros(_storage_shape(self.specs[n],
                                            self.os_shard_len(n)),
                             jnp.float32)
                for n in self.stream_leaf_names()}

    def _stage2_rs(self, name: str, g):
        """Stage 2 for a primary-layout grad: reduce-scatter over the
        extra-grad axes (paper: intra-node a2a INT4 RS). Output is scattered
        over weight+extra-grad axes but still device-varying over the
        replica axes — stage 3 below completes the sync."""
        lcfg = self.leaf_cfg[name]
        g = g.astype(jnp.float32)
        flat = g.reshape(-1, g.shape[-1]) if g.ndim > 1 else g[None]
        out = jax.vmap(lambda row: col.reduce_scatter_flat(
            row, lcfg.axes.extra_grad, lcfg))(flat)
        return out if g.ndim > 1 else out[0]

    def _replica_sync(self, name: str, g):
        """Stage 3: cross-replica sync of a stage-2-scattered grad."""
        lcfg = self.leaf_cfg[name]
        flat = g.reshape(-1, g.shape[-1]) if g.ndim > 1 else g[None]
        out = jax.vmap(lambda row: col.cross_replica_grad(row, lcfg))(flat)
        return out if g.ndim > 1 else out[0]

    def _to_os(self, name: str, g):
        """Stage 2 + 3 for a primary-layout grad (seed path; streamed
        leaves arrive here already reduced). Split into the two stages so
        the phased traced step (obs/phased.py) can fence each phase while
        running the identical per-row collectives."""
        return self._replica_sync(name, self._stage2_rs(name, g))

    def _grads_to_os(self, g_primary: dict, g_os: dict) -> dict:
        """Assemble the full optimizer-shard grad dict in sorted-leaf order
        (the order the grad-norm fold below depends on): streamed leaves
        pass through, primary-layout leaves run the seed stage-2/3 chain."""
        return {n: g_os[n] if n in g_os else self._to_os(n, g_primary[n])
                for n in sorted(self.specs)}

    def _apply_updates(self, state, os_grads: dict):
        """AdamW on the master shards + the update all-gather, vectorized
        over stacked leaves (paper §V-C/D).

        ``adamw_update`` is elementwise and runs on the whole (layers,
        shard) leaf at once; ``collectives.update_all_gather`` tiles the
        last axis directly, so stacked leaves rebuild their bf16 primaries
        with one batched collective instead of a per-row vmap (same data
        movement, bitwise-identical values)."""
        from ..optim.adamw import adamw_update
        cfg, hp = self.cfg, self.hp
        step = state["step"] + 1
        lr = self._lr(state["step"])
        b1, b2 = hp.betas
        cdt = jnp.dtype(cfg.compute_dtype)
        new_m, new_v, new_master, new_prim = {}, {}, {}, {}
        for n in sorted(self.specs):
            wd = hp.weight_decay \
                if self.specs[n].kind in (MATMUL, GATHER_Q) else 0.0
            master, m, v = adamw_update(
                state["master"][n], state["opt_m"][n], state["opt_v"][n],
                os_grads[n], step=step, lr=lr, beta1=b1, beta2=b2,
                eps=hp.eps, weight_decay=wd)
            new_m[n], new_v[n], new_master[n] = m, v, master
            new_prim[n] = col.update_all_gather(master, self.leaf_cfg[n], cdt)
        return dict(primaries=new_prim, master=new_master,
                    opt_m=new_m, opt_v=new_v, step=step), lr

    def make_train_step(self, loss_fn: Callable, batch_specs: dict[str, P]):
        """loss_fn(view, batch) -> (loss_sum, token_count). Returns jit'd step.

        Two gradient regimes (DESIGN.md §8):

        * seed (``stream_grads=False``): differentiate w.r.t. the primaries;
          microbatch grads accumulate in fp32 **primary layout**
          (4*psi/w_degree), then one stage-2 reduce-scatter + cross-replica
          sync per step lifts them to optimizer-shard layout (``_to_os``).
        * streaming (``stream_grads=True``): stacked-leaf cotangents leave
          the backward already reduced — differentiate w.r.t. the os-shard
          **sinks** (plus the few non-stacked/PLAIN primaries), so the
          accumulation buffer is fp32 os-shard layout (4*psi/os_degree) and
          the per-layer grad collectives overlap the backward. Bitwise
          identical to the seed regime at n_microbatch=1; at n_microbatch>1
          the stage-2 quantization applies per microbatch (within
          block-quant tolerance of the seed path, still bitwise across
          kernel impls and process layouts).
        """
        cfg = self.cfg
        mesh = self.mesh
        state_specs = self.state_in_specs()
        stream = cfg.stream_grads
        local_grads = self._make_local_grads(loss_fn)

        def local_step(state, batch):
            grads, loss_rep, gtok = local_grads(state["primaries"], batch)

            g_legacy, g_sinks = grads if stream else (grads, {})
            os_grads = self._grads_to_os(g_legacy, g_sinks)

            new_state, metrics = self._finish_step(state, os_grads,
                                                   loss_rep, gtok)
            return new_state, metrics

        sm = shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, {k: P() for k in
                                     ("loss", "grad_norm", "lr", "tokens")}),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(0,))

    def _make_local_grads(self, loss_fn: Callable) -> Callable:
        """The microbatch value_and_grad loop of the train step as a
        reusable *local* function (must run inside shard_map):
        ``local_grads(primaries, batch) -> (grads, loss_rep, gtok)`` with
        ``grads`` still in the differentiation layout — a primary dict, or
        ``(legacy_primaries, os_sinks)`` when streaming. Shared verbatim by
        ``make_train_step`` and the phased traced step (obs/phased.py), so
        the two can never diverge."""
        cfg = self.cfg
        hp = self.hp
        stream = cfg.stream_grads
        snames = set(self.stream_leaf_names()) if stream else set()

        def local_grads(primaries, batch):

            def mb_loss(diff, mb):
                if stream:
                    legacy_p, sinks = diff
                    prims = dict(primaries)
                    prims.update(legacy_p)
                else:
                    prims, sinks = diff, None
                view = ParamView(self.fns, prims, overlap=cfg.overlap,
                                 sinks=sinks)
                loss_sum, tok = loss_fn(view, mb)
                # contract: allow[raw-psum] -- integer token counts in f32:
                # exact in any summation order, no det_psum needed
                gtok = lax.psum(tok.astype(jnp.float32), cfg.axes.all)
                return loss_sum.astype(jnp.float32) / jnp.maximum(gtok, 1.0), gtok

            if stream:
                diff0 = ({n: p for n, p in primaries.items()
                          if n not in snames}, self._zero_sinks())
            else:
                diff0 = primaries

            n_mb = hp.n_microbatch
            if n_mb == 1:
                (loss, gtok), grads = jax.value_and_grad(mb_loss, has_aux=True)(
                    diff0, batch)
            else:
                def split(x):
                    return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])
                mbs = jax.tree.map(split, batch)

                def acc(carry, mb):
                    gacc, lacc, tacc = carry
                    (l, t), g = jax.value_and_grad(mb_loss, has_aux=True)(
                        diff0, mb)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + l, tacc + t), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), diff0)
                (grads, loss, gtok), _ = lax.scan(
                    acc, (g0, jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), mbs)
                # each microbatch loss is normalized by its own global token
                # count; average the accumulated means
                grads = jax.tree.map(lambda g: g / n_mb, grads)
                loss = loss / n_mb

            # global loss for reporting: sum of per-device (local/global_tok).
            # det_psum, not lax.psum: the reduction order must not depend on
            # how the mesh is split across processes (tests/_mp.py asserts a
            # 2x4 cluster reproduces the 1x8 run bitwise). gtok above stays a
            # plain psum — token counts are integers in float32, exact in
            # any summation order.
            loss_rep = col.det_psum(loss, cfg.axes.all)
            return grads, loss_rep, gtok

        return local_grads

    def _clip_grads(self, os_grads: dict):
        """Grad-norm clip (global: os shards partition the full gradient).
        det_psum: gnorm feeds the clip scale applied to every gradient, so
        a transport-dependent reduction order here would make the entire
        update drift across process layouts."""
        sq = sum(jnp.sum(jnp.square(g)) for g in os_grads.values())
        gnorm = jnp.sqrt(col.det_psum(sq, self.cfg.axes.all))
        scale = jnp.minimum(1.0, self.hp.grad_clip / (gnorm + 1e-6))
        return {n: g * scale for n, g in os_grads.items()}, gnorm

    def _finish_step(self, state, os_grads: dict, loss_rep, gtok):
        """Post-reduction tail of the train step (local, inside shard_map):
        clip + AdamW/update-gather + metrics assembly.

        gtok: global token count summed over every microbatch (with
        n_mb == 1 it is the single microbatch's global count). Both it and
        loss_rep/gnorm are psummed over cfg.axes.all — which includes any
        process-spanning axis — so the metrics leaving the step are
        CLUSTER-global, not process-local; metrics_to_host fetches them on
        every process without a second collective."""
        os_grads, gnorm = self._clip_grads(os_grads)
        new_state, lr = self._apply_updates(state, os_grads)
        metrics = dict(loss=loss_rep, grad_norm=gnorm, lr=lr, tokens=gtok)
        return new_state, metrics

    @staticmethod
    def metrics_to_host(metrics) -> dict[str, float]:
        """Fetch step metrics as python floats on every process.

        The train/eval steps emit metrics with out_spec ``P()`` after a psum
        over ``cfg.axes.all``, so each metric is fully replicated — globally
        aggregated already, even when the mesh spans processes.
        """
        return {k: float(host_scalar(v)) for k, v in metrics.items()}

    # -- eval / serve steps ------------------------------------------------------

    def make_eval_step(self, loss_fn: Callable, batch_specs: dict[str, P]):
        state_specs = self.state_in_specs()

        def local_eval(state, batch):
            view = ParamView(self.fns, state["primaries"],
                             overlap=self.cfg.overlap)
            loss_sum, tok = loss_fn(view, batch)
            # gtok: integer-valued, exact under any order; loss: det_psum so
            # eval losses match bitwise across process layouts (train step
            # rationale above)
            # contract: allow[raw-psum] -- integer token counts, order-exact
            gtok = lax.psum(tok.astype(jnp.float32), self.cfg.axes.all)
            loss = col.det_psum(loss_sum.astype(jnp.float32),
                                self.cfg.axes.all)
            return loss / jnp.maximum(gtok, 1.0)

        sm = shard_map(local_eval, mesh=self.mesh,
                           in_specs=(state_specs, batch_specs),
                           out_specs=P(), check_vma=False)
        return jax.jit(sm)

    def make_apply(self, fn: Callable, in_specs, out_specs):
        """Generic shard_map-wrapped forward: fn(view, *args)."""
        prim_specs = self.state_in_specs()["primaries"]

        def local(primaries, *args):
            view = ParamView(self.fns, primaries, overlap=self.cfg.overlap)
            return fn(view, *args)

        sm = shard_map(local, mesh=self.mesh,
                           in_specs=(prim_specs,) + tuple(in_specs),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(sm)

    def abstract_primaries(self):
        sh = self.state_shardings()["primaries"]
        cdt = jnp.dtype(self.cfg.compute_dtype)
        return {n: jax.ShapeDtypeStruct(
            _storage_shape(s, self._pad[n]), cdt, sharding=sh[n])
            for n, s in self.specs.items()}
