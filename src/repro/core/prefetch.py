"""Double-buffered prefetch/overlap scheduler for the per-layer weight
gathers (DESIGN.md §3; ZeRO++ §IV "communication overlap", Dash et al. 2023).

The baseline engine issues each layer's INT8 quantized all-gather *inside*
the ``lax.scan`` body, serially with that layer's matmuls: the collective
sits on the critical path once per layer per pass.  This module provides the
helpers for the overlapped schedule, in which a 2-slot buffer of
gathered-quantized weights rotates through the layer loop:

    slot A: layer i's buffer, being consumed by layer i's compute
    slot B: layer i+1's buffer, whose quantize+all-gather is already in
            flight (``collectives.gather_issue_int8``) — it has no data
            dependency on layer i's math, so XLA's latency-hiding scheduler
            overlaps the collective with the matmuls.

``ParamView.scan_layers`` threads the buffer through the scan carry (prologue
issues layer 0, each step issues layer i+1 and consumes slot A, and the last
layer runs as an epilogue outside the scan), so the total gather count — and
hence comm volume — is exactly the baseline's L per leaf per pass.
``ParamView.loop_layers`` applies the same rotation across a heterogeneous
Python-unrolled pattern (gemma3 local:global, jamba mamba/attn interleave),
prefetching across block-kind boundaries.

Buffers are ``lax.stop_gradient``'d at issue time: the consuming ``*_pre``
custom VJPs route the true weight gradient to the primary shard
(straight-through, identical to the inline path), so no cotangent — and in
particular no transposed collective — flows back through the rotation.

Memory note: forward, overlap holds at most two layers' quantized buffers
live (the "2 slots").  Under ``remat=True`` the scan checkpoint saves its
carry per step, which includes the rotating buffer — an extra ~psi INT8
bytes across the backward pass.  See DESIGN.md §3 for the trade-off table.
"""
from __future__ import annotations

from jax import lax


def prefetchable_names(fns, names) -> tuple[str, ...]:
    """Leaves with an issue() half (MATMUL / GATHER_Q); PLAIN leaves are
    norm-scale sized and keep their (negligible) inline gather."""
    return tuple(n for n in names if fns[n].issue is not None)


def issue_buffers(fns, primaries, names):
    """Issue the gathers for one layer's prefetchable leaves.

    Returns {name: buffer pytree}. stop_gradient on the *input* keeps the
    whole issue chain (quantize kernel + collective) primal-only: no tangent
    ever enters it (the Pallas quantize has no JVP rule) and no cotangent —
    in particular no transposed collective — flows back through the scan
    carry (see module docstring).
    """
    return {n: fns[n].issue(lax.stop_gradient(primaries[n])) for n in names}
