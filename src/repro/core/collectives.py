"""Quantization-assisted collectives (paper §V, ZeRO++ §III-C).

All functions run *inside* ``shard_map`` and take mesh axis-name tuples,
ordered major -> minor, matching the canonical flat-slice hierarchy of
``partition.py``. Empty axis tuples degrade to no-ops so the same engine code
expresses ZeRO-1/2/3, ZeRO++ and ZeRO-topo.

The key primitive is the **all-to-all based quantized reduce-scatter**
(ZeRO++ §"quantized gradients"): instead of a ring reduce-scatter that would
quantize/dequantize at every hop (accumulating error log(d) times), the input
is split into d chunks, each chunk is quantized once, exchanged with a single
all-to-all, dequantized once, and reduced locally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..analysis.tags import tag as _tag
from ..compat import pvary as _compat_pvary
from ..kernels import ops
from .partition import ZeroConfig

AxisTuple = tuple[str, ...]


def pvary(x, axes: AxisTuple):
    """Mark x as device-varying over `axes` (defers cross-replica psums)."""
    return _compat_pvary(x, axes)


def unvary(x, axes: AxisTuple):
    """Assert x is replicated over `axes` and drop the varying type."""
    if not axes:
        return x
    # pcast 'to_invariant' isn't exposed portably; an axis-wise max is a
    # semantic no-op on replicated values and re-types the array.
    return x


def det_psum(x, axes: AxisTuple):
    """Order-deterministic psum of a (near-)scalar: all-gather the per-device
    partials and reduce them locally in axis-index order.

    ``lax.psum``'s reduction order is transport-dependent — the in-process
    XLA ring and a cross-process gloo/NCCL tree associate the sum
    differently, so a metric computed with it drifts in the last float bits
    when the same mesh is split across processes. The all-gather is pure
    data movement (bitwise-safe on any transport) and lands the partials in
    canonical axis-index order on every device, so the local sum is bitwise
    identical across process layouts. Scalars/metrics only: the gather
    costs group_size elements per device.
    """
    if not axes:
        return x
    g = lax.all_gather(x, tuple(axes))
    return jnp.sum(g, axis=0)


def activation_psum(x, axes: AxisTuple, out_dtype=None):
    """Tensor-parallel activation reduction (serving/inference paths).

    Accumulates in fp32 regardless of the activation dtype — partial matmul
    products are the classic catastrophic-cancellation site — and is the one
    sanctioned home for a floating-point ``lax.psum`` on activations: TP
    activation sums stay on the intra tier by construction (the TP axes are
    the model axes), so the dtype-tier policy (DESIGN.md §9) does not apply,
    but routing them through here keeps the raw-psum lint rule's allowlist
    at exactly one file.
    """
    if not axes:
        return x if out_dtype is None else x.astype(out_dtype)
    out = lax.psum(x.astype(jnp.float32), tuple(axes))
    return out if out_dtype is None else out.astype(out_dtype)


def all_gather_flat(shard, axes: AxisTuple):
    """Plain (unquantized) tiled all-gather of a flat shard. AD: psum_scatter."""
    if not axes:
        return shard
    return lax.all_gather(shard, tuple(axes), tiled=True, axis=shard.ndim - 1)


def quant_all_gather_int8(shard, axes: AxisTuple, cfg: ZeroConfig,
                          out_dtype=jnp.bfloat16):
    """INT8 block-quantized all-gather: quantize -> gather(q, s) -> dequant.

    Halves the gather volume vs FP16/BF16 (paper Table VII). Returns the full
    dequantized tensor *and* the gathered quantized copy + scales (the caller
    may slice a secondary partition out of them at zero extra cost).
    """
    if not axes:
        q, s = ops.quantize_int8(shard, cfg.quant_block, impl=cfg.impl)
        return ops.dequantize_int8(q, s, cfg.quant_block, out_dtype, impl=cfg.impl), q, s
    q, s = ops.quantize_int8(shard, cfg.quant_block, impl=cfg.impl)
    qf = lax.all_gather(q, tuple(axes), tiled=True)
    sf = lax.all_gather(s, tuple(axes), tiled=True)
    full = ops.dequantize_int8(qf, sf, cfg.quant_block, out_dtype, impl=cfg.impl)
    return full, qf, sf


def dequant_gathered(qf, sf, axes_idx_len, cfg: ZeroConfig, out_dtype=jnp.bfloat16):
    return ops.dequantize_int8(qf, sf, cfg.quant_block, out_dtype, impl=cfg.impl)


# -- gather-issue / gather-wait split (prefetch/overlap, DESIGN.md §3) -------
#
# ``quant_all_gather_int8`` fuses quantize -> gather -> dequant into the
# consuming block, which puts the collective on the critical path of the
# layer that uses the weights.  The split primitives below let the engine
# *issue* layer i+1's gather while layer i computes: ``gather_issue_int8``
# ends at the collective (its result has no data dependency on the current
# layer's math, so XLA's latency-hiding scheduler can run it concurrently)
# and ``gather_wait_int8`` performs the local dequant at consume time.
# issue+wait is op-for-op the fused path, so results are bitwise identical.

def gather_issue_int8(shard, axes: AxisTuple, cfg: ZeroConfig):
    """Quantize + all-gather a flat shard, *without* dequantizing.

    Returns the gathered (q, scales) pair — the 2-slot prefetch buffer
    format. Same wire traffic as ``quant_all_gather_int8``.
    """
    q, s = ops.quantize_int8(shard, cfg.quant_block, impl=cfg.impl)
    if axes:
        q = lax.all_gather(q, tuple(axes), tiled=True)
        s = lax.all_gather(s, tuple(axes), tiled=True)
    return _tag((q, s), role="issue", machine="gather")


def gather_wait_int8(qf, sf, cfg: ZeroConfig, out_dtype=jnp.bfloat16):
    """Local dequant of a prefetched (q, scales) buffer (no communication)."""
    qf, sf = _tag((qf, sf), role="wait", machine="gather")
    return ops.dequantize_int8(qf, sf, cfg.quant_block, out_dtype,
                               impl=cfg.impl)


# -- a2a-RS issue / wait split (streaming grad path, DESIGN.md §8) -----------
#
# Mirrors the ``gather_issue_int8``/``gather_wait_int8`` split above, for the
# other direction: ``a2a_rs_issue`` ends at the all-to-all (quantize + a2a,
# no dequant — the point where the collective leaves the device), and
# ``a2a_rs_wait`` is the pure-local receive side (fused unpack + dequant +
# reduce). issue+wait composes op-for-op into ``a2a_quant_reduce_scatter``,
# so the streaming backward tap that uses the split halves is bitwise the
# fused primitive (tests/_scenarios.py::collectives_split). The issue half's
# result feeds nothing in the current layer's backward compute, so XLA's
# latency-hiding scheduler can run layer i's grad all-to-all concurrently
# with layer i-1's backward matmuls — the same mechanism as the forward
# gather prefetch (core/schedule.py owns both idioms).

def a2a_rs_issue(x, axes: AxisTuple, cfg: ZeroConfig, bits: int = 4):
    """Quantize the d chunks of a flat shard and exchange them with one
    all-to-all, *without* the receive-side dequant-reduce.

    Returns the received (q2, s2) wire buffers; same wire traffic as the
    fused ``a2a_quant_reduce_scatter``.
    """
    d = cfg.size(axes)
    chunks = x.reshape(d, -1)          # chunk j -> group member j (major order)
    flatc = chunks.reshape(-1)
    if bits == 4:
        q, s = ops.quantize_int4(flatc, cfg.quant_block, impl=cfg.impl)
        q = q.reshape(d, -1)
    else:
        q, s = ops.quantize_int8(flatc, cfg.quant_block, impl=cfg.impl)
        q = q.reshape(d, -1)
    s = s.reshape(d, -1)
    q2 = lax.all_to_all(q, tuple(axes), split_axis=0, concat_axis=0, tiled=False)
    s2 = lax.all_to_all(s, tuple(axes), split_axis=0, concat_axis=0, tiled=False)
    return q2, s2


def a2a_rs_issue_q(q, s, axes: AxisTuple, cfg: ZeroConfig):
    """Exchange *pre-quantized* wire buffers: the collective half of
    ``a2a_rs_issue`` (same two all-to-alls, same wire bytes) for producers
    that already emitted wire format — the fused matmul-quant epilogue
    (kernels/ops.matmul_quant) quantizes the weight grad inside the matmul,
    so the dense f32 tensor never round-trips through HBM here."""
    d = cfg.size(axes)
    q = q.reshape(d, -1)
    s = s.reshape(d, -1)
    q2 = lax.all_to_all(q, tuple(axes), split_axis=0, concat_axis=0, tiled=False)
    s2 = lax.all_to_all(s, tuple(axes), split_axis=0, concat_axis=0, tiled=False)
    return q2, s2


def a2a_rs_wait(q2, s2, d: int, cfg: ZeroConfig, bits: int = 4,
                out_dtype=jnp.float32):
    """Receive side of the a2a quantized RS: fused unpack + dequant + reduce
    over the d chunks in one kernel pass (no communication). The unfused
    tail would materialize d dequantized copies and re-read them for the
    sum."""
    if bits == 4:
        red = ops.dequantize_int4_sum(q2.reshape(-1), s2.reshape(-1), d,
                                      cfg.quant_block, jnp.float32,
                                      impl=cfg.impl)
    else:
        red = ops.dequantize_int8_sum(q2.reshape(-1), s2.reshape(-1), d,
                                      cfg.quant_block, jnp.float32,
                                      impl=cfg.impl)
    return red.astype(out_dtype)


def a2a_quant_reduce_scatter(x, axes: AxisTuple, cfg: ZeroConfig,
                             bits: int = 4, out_dtype=jnp.float32):
    """All-to-all based quantized reduce-scatter over `axes`.

    x: flat (n,) with n % (D * block) == 0, D = group size. Returns the
    (n // D,) shard for this device's group index, summed over the group,
    with exactly one quantize/dequantize round-trip (INT4 by default ->
    0.25x communication volume, paper Table VIII). Composition of the
    ``a2a_rs_issue``/``a2a_rs_wait`` halves above.
    """
    d = cfg.size(axes)
    if d == 1:
        return x.astype(out_dtype)
    q2, s2 = a2a_rs_issue(x, axes, cfg, bits)
    return a2a_rs_wait(q2, s2, d, cfg, bits, out_dtype)


def reduce_scatter_flat(x, axes: AxisTuple, cfg: ZeroConfig, *,
                        quantized: bool | None = None, out_dtype=jnp.float32):
    """Gradient reduce-scatter over `axes`, quantized per config."""
    if not axes or cfg.size(axes) == 1:
        return x.astype(out_dtype)
    if quantized is None:
        quantized = cfg.quantize_grads
    if quantized:
        return a2a_quant_reduce_scatter(x, axes, cfg, bits=4, out_dtype=out_dtype)
    return lax.psum_scatter(x, tuple(axes), tiled=True).astype(out_dtype)


def cross_replica_grad(x, cfg: ZeroConfig, out_dtype=jnp.float32):
    """Final gradient sync over the replica tier (paper §V-C).

    "allreduce": the paper's flow -- all-reduce node-sharded grads across
    nodes, then each device *selects* the sub-slice matching its optimizer
    shard and discards the rest.
    "reduce_scatter": beyond-paper -- a psum_scatter lands each device's
    optimizer slice directly at ~half the volume.
    Either way the result is the optimizer-shard gradient (degree = all axes).
    """
    axes = cfg.axes.replica
    if not axes or cfg.size(axes) == 1:
        return x.astype(out_dtype)
    if cfg.cross_replica == "reduce_scatter":
        return lax.psum_scatter(x, tuple(axes), tiled=True).astype(out_dtype)
    full = lax.psum(x, tuple(axes))
    r = cfg.size(axes)
    idx = lax.axis_index(tuple(axes))
    piece = x.shape[-1] // r if x.ndim else x.size // r
    return lax.dynamic_slice_in_dim(full, idx * piece, piece, axis=-1).astype(out_dtype)


def update_all_gather(master_shard, cfg: ZeroConfig, out_dtype=jnp.bfloat16):
    """Rebuild primary weight shards from updated optimizer shards.

    All-gather over (E + R) in major->minor order; comm volume
    psi*(d-1)/d over the OS group (paper §V-D). Optionally INT8-quantized
    (beyond-paper; consistent across replicas because dequant is
    deterministic).

    Accepts flat 1-D shards or stacked (layers, shard) 2-D leaves — the
    gather tiles the last axis, so stacked leaves need no per-row vmap
    (same data movement, one batched collective).
    """
    axes = cfg.axes.extra_grad + cfg.axes.replica
    x = master_shard.astype(out_dtype)
    if not axes or cfg.size(axes) == 1:
        return x
    if cfg.quantize_update_gather:
        # quantize blocks never cross rows (shard length % block == 0 by
        # padded_flat_size), so flat quantization of the stacked leaf is
        # bitwise the per-row quantization; gather per row, then dequant
        q, s = ops.quantize_int8(x.reshape(-1), cfg.quant_block, impl=cfg.impl)
        q = q.reshape(x.shape)
        s = s.reshape(x.shape[:-1] + (-1,))
        qf = lax.all_gather(q, tuple(axes), tiled=True, axis=x.ndim - 1)
        sf = lax.all_gather(s, tuple(axes), tiled=True, axis=x.ndim - 1)
        out = ops.dequantize_int8(qf.reshape(-1), sf.reshape(-1),
                                  cfg.quant_block, out_dtype, impl=cfg.impl)
        return out.reshape(x.shape[:-1] + (-1,))
    return lax.all_gather(x, tuple(axes), tiled=True, axis=x.ndim - 1)


def secondary_slice(qf, sf, axes: AxisTuple, cfg: ZeroConfig):
    """Slice this device's secondary partition out of gathered (q, scales).

    Both are block-aligned, so the slice keeps whole quantization blocks and
    their matching scales.
    """
    s_deg = cfg.size(axes)
    idx = lax.axis_index(tuple(axes))
    qlen = qf.shape[-1] // s_deg
    slen = sf.shape[-1] // s_deg
    q = lax.dynamic_slice_in_dim(qf, idx * qlen, qlen, axis=-1)
    s = lax.dynamic_slice_in_dim(sf, idx * slen, slen, axis=-1)
    return q, s


def gather_secondary_q(sec_q, sec_s, axes: AxisTuple, cfg: ZeroConfig):
    """Backward weight all-gather from the INT8 secondary partition, kept in
    wire format (q, scales) — the fused dequant-matmul backward consumes it
    without ever materializing the dense weight."""
    qf = lax.all_gather(sec_q, tuple(axes), tiled=True)
    sf = lax.all_gather(sec_s, tuple(axes), tiled=True)
    return _tag((qf, sf), role="issue", machine="regather")


def gather_secondary(sec_q, sec_s, axes: AxisTuple, cfg: ZeroConfig,
                     out_dtype=jnp.bfloat16):
    """Backward weight all-gather from the INT8 secondary partition (intra tier)."""
    qf, sf = gather_secondary_q(sec_q, sec_s, axes, cfg)
    return gather_wait_int8(qf, sf, cfg, out_dtype)


# -- serving residency (DESIGN.md §12) ---------------------------------------
#
# The serving weight residency IS the secondary-partition wire format: at
# server start each leaf is quantized + gathered once (``gather_issue_int8``)
# and every device keeps only its ``residency_slice``; the decode hot path
# re-gathers the INT8 payload + scales per layer (``gather_residency_q``)
# and feeds them straight to the fused dequant-matmul. slice-then-regather
# is a bitwise identity (tests/_scenarios.py::collectives), which is what
# makes the resident forward bitwise-equal to the training engine's.

def gather_issue_int8_rows(rows, axes: AxisTuple, cfg: ZeroConfig):
    """Row-batched ``gather_issue_int8`` for stacked (layers, shard) leaves.

    Every row's shard length is a whole number of quant blocks (the
    ``os_degree * block`` padding guarantees it), so quantizing the
    flattened stack produces exactly the per-row blocks — no block straddles
    a row boundary — and the tiled last-axis gather concatenates shards in
    axis-index order. Row ``r`` of the result is therefore bitwise
    ``gather_issue_int8(rows[r], ...)``.
    """
    stack, shard = rows.shape
    q, s = ops.quantize_int8(rows.reshape(-1), cfg.quant_block, impl=cfg.impl)
    q = q.reshape(stack, shard)
    s = s.reshape(stack, shard // cfg.quant_block)
    if axes:
        q = lax.all_gather(q, tuple(axes), tiled=True, axis=1)
        s = lax.all_gather(s, tuple(axes), tiled=True, axis=1)
    return _tag((q, s), role="issue", machine="gather")


def residency_slice(qf, sf, axes: AxisTuple, cfg: ZeroConfig):
    """Slice the serving residency partition out of gathered (q, scales).

    Same block-aligned last-axis slice as ``secondary_slice``; the
    empty-axes guard makes replicated residency (1-device meshes) a no-op.
    """
    if not axes:
        return qf, sf
    return secondary_slice(qf, sf, axes, cfg)


def gather_residency_q(res_q, res_s, axes: AxisTuple, cfg: ZeroConfig):
    """Decode-path wire re-gather: residency shards -> full (q, scales)."""
    if not axes:
        return _tag((res_q, res_s), role="issue", machine="regather")
    return gather_secondary_q(res_q, res_s, axes, cfg)
