"""Version-compatibility shims for the JAX APIs this repo straddles.

The code is written against the modern surface (``jax.shard_map`` with
``check_vma=``, ``jax.sharding.AxisType``, ``lax.pvary``) but must run on
stock JAX down to 0.4.35 (CI pins 0.4.37, where none of those exist yet).
Every call site imports the shim instead of feature-testing locally, so the
supported-version policy lives in exactly one file (see README "Supported
JAX versions").

  make_mesh(shape, axes)   jax.make_mesh with axis_types= when available;
                           plain jax.make_mesh on 0.4.35-0.4.x; explicit
                           Mesh(np.array(devices).reshape(shape)) pre-0.4.35.
  shard_map(...)           jax.shard_map(check_vma=...) when available, else
                           jax.experimental.shard_map.shard_map mapping
                           check_vma -> check_rep (same meaning: verify the
                           replication claims of out_specs).
  pvary(x, axes)           lax.pvary when the varying-manual-axes type system
                           exists; identity otherwise (pre-0.5 shard_map has
                           no device-variance types, so it is already a no-op).
"""
from __future__ import annotations

import inspect

import jax
import numpy as np

try:  # jax >= 0.5.x
    from jax.sharding import AxisType
except ImportError:  # stock 0.4.x
    AxisType = None

# jax.shard_map was promoted to the jax namespace before check_rep was
# renamed to check_vma, so the presence of the attribute alone doesn't pin
# the kwarg — read it off the signature once.
_SM_CHECK_KW = None
if hasattr(jax, "shard_map"):
    _SM_CHECK_KW = ("check_vma"
                    if "check_vma" in inspect.signature(jax.shard_map).parameters
                    else "check_rep")


def make_mesh(shape, axes):
    """Build a Mesh over the default devices, newest API first."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):  # 0.4.35+: no axis_types kwarg yet
        return jax.make_mesh(shape, axes)
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions (check_vma == old check_rep)."""
    if _SM_CHECK_KW is not None:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             **{_SM_CHECK_KW: check_vma})
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def pvary(x, axes):
    """Mark x device-varying over `axes`; identity where the type system
    (and hence the distinction) does not exist."""
    if not axes:
        return x
    from jax import lax
    if hasattr(lax, "pvary"):
        return lax.pvary(x, tuple(axes))
    return x


def new_primitive(name: str):
    """A ``jax.core.Primitive`` across the extend-API migration.

    ``jax.core.Primitive`` moved to ``jax.extend.core`` (the old path warns
    and is slated for removal); this is the one place that knows which spelling
    the installed JAX uses.
    """
    try:  # jax >= 0.4.34: the supported public surface
        from jax.extend.core import Primitive
    except ImportError:  # older releases
        from jax.core import Primitive
    return Primitive(name)


def enable_cpu_collectives(impl: str = "gloo") -> bool:
    """Select the cross-process collectives backend for the CPU client.

    Must run before the first jax device access AND before
    ``jax.distributed.initialize`` — without it, a multi-process CPU cluster
    forms but every cross-host collective deadlocks. Returns False on JAX
    versions that predate the option (single-process use is unaffected;
    multi-process runs will fail loudly at initialize time instead).
    """
    import os
    # the env var is the config's backing store on every version that has
    # the option; setting both covers config-name churn across releases
    os.environ.setdefault("JAX_CPU_COLLECTIVES_IMPLEMENTATION", impl)
    try:
        jax.config.update("jax_cpu_collectives_implementation", impl)
        return True
    except (AttributeError, ValueError):
        return False
