"""Layer 3: repo-specific source lint for the comm contracts.

AST-based rules that keep the communication discipline enforceable at the
source level, where the jaxpr/HLO layers cannot see intent:

  raw-psum       ``lax.psum`` on floating-point values is non-deterministic
                 across fabric schedules; every fp reduction must go through
                 ``core/collectives.py`` (``det_psum`` for bitwise-stable
                 metrics, ``activation_psum`` for serving activations).
                 Allowed in core/collectives.py itself.
  pallas-call    ``pl.pallas_call`` outside ``kernels/`` bypasses the
                 impl-dispatch layer (jnp / pallas / pallas_interpret) and
                 the interpret-mode CI leg.
  dequant-math   quantize/dequantize calls outside ``kernels/`` must be
                 ``ops.``-qualified: the dispatch table in ``kernels/ops.py``
                 is the only sanctioned entry to the quant math (the
                 reference formulas live in ``kernels/ref.py``).
  ops-dispatch   importing a kernel submodule directly (``from ..kernels.x
                 import ...``) outside ``kernels/`` skips the impl dispatch.
                 Every hot-path kernel (quant collectives, dequant_matmul,
                 flash_attention, selective_scan, matmul_quant) is promoted
                 into ``kernels/ops``, so the tracked-exemption table below
                 is empty; an exemption that no longer matches any import is
                 itself reported (``stale-exemption``) so the list cannot
                 rot.
  version-api    JAX-version-sensitive surfaces (``jax.shard_map``,
                 ``jax.make_mesh``, ``lax.pvary``, ``AxisType``,
                 ``jax.experimental.shard_map``, ``jax.core`` /
                 ``jax.extend``) may be touched only in ``compat.py`` — the
                 single version shim (its docstring explains each).

Waivers: a violation is silenced by the marker

    # contract: allow[rule-id] -- reason

on the violating line itself, or anywhere in the contiguous block of
comment-only lines directly above it (so multi-line justifications work).

Run as ``python -m repro.analysis.lint [paths...]`` (default: the installed
``repro`` package source); exits non-zero on unwaived findings.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

from .report import Report

# quant entry points whose math lives in kernels/: callers use ops.<fn>
QUANT_FNS = {
    "quantize_int8", "dequantize_int8", "quantize_int4", "dequantize_int4",
    "dequantize_int4_sum", "dequantize_int8_sum", "dequant_matmul",
    "matmul_fusable", "matmul_quant",
}

# rule -> path prefixes (relative to the repro package root) where the
# construct is the implementation, not a violation
ALLOWED = {
    "raw-psum": ("core/collectives.py",),
    "pallas-call": ("kernels/",),
    "dequant-math": ("kernels/",),
    "ops-dispatch": ("kernels/",),
    "version-api": ("compat.py",),
}

# ops-dispatch tracked exemptions: kernels still dispatched by hand, pending
# promotion into kernels/ops. Keyed by file, valued by the kernel submodules
# it may import directly. EMPTY since the attention/scan/matmul_quant
# promotion — the acceptance gate is that it stays empty.
OPS_DISPATCH_EXEMPT: dict[str, tuple[str, ...]] = {}

_WAIVER_RE = re.compile(r"#\s*contract:\s*allow\[([\w-]+)\]")

VERSION_ATTRS = {("jax", "shard_map"), ("jax", "make_mesh"),
                 ("jax", "core"), ("jax", "extend"), ("lax", "pvary"),
                 ("jax", "experimental")}
VERSION_MODULES = ("jax.core", "jax.extend", "jax.experimental.shard_map")


def _dotted(node) -> str:
    """Best-effort dotted name of a call target ('jax.lax.psum', 'psum')."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ".".join(reversed(parts)) if parts else ""


class _Visitor(ast.NodeVisitor):
    def __init__(self):
        self.raw = []                 # (rule, lineno, message)
        self.kernel_imports = []      # (submodule, lineno)

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        last = name.rsplit(".", 1)[-1]
        if last == "psum":
            self.raw.append((
                "raw-psum", node.lineno,
                "raw lax.psum: fp reductions must go through "
                "core/collectives (det_psum / activation_psum)"))
        elif last == "pallas_call":
            self.raw.append((
                "pallas-call", node.lineno,
                "pl.pallas_call outside kernels/ bypasses the impl-dispatch "
                "layer"))
        elif last in QUANT_FNS:
            qual = name.rsplit(".", 1)[0] if "." in name else ""
            if qual.rsplit(".", 1)[-1] != "ops":
                self.raw.append((
                    "dequant-math", node.lineno,
                    f"{last}() must be called through the kernels/ops "
                    f"dispatch table (ops.{last})"))
        self.generic_visit(node)

    def _kernel_submodule(self, module: str, level: int) -> str | None:
        """'foo' if this import reaches kernels.foo, else None."""
        mod = module or ""
        if level > 0:                      # relative: ..kernels.x
            if mod == "kernels" or mod.startswith("kernels."):
                pass
            else:
                return None
        elif not (mod == "repro.kernels" or mod.startswith("repro.kernels.")):
            return None
        tail = mod.split("kernels", 1)[1].lstrip(".")
        return tail.split(".")[0] if tail else ""

    def visit_ImportFrom(self, node: ast.ImportFrom):
        sub = self._kernel_submodule(node.module, node.level)
        if sub is not None:
            if sub == "":
                # from ..kernels import X: only the ops dispatch table
                for a in node.names:
                    if a.name != "ops":
                        self.kernel_imports.append((a.name, node.lineno))
            elif sub != "ops":
                self.kernel_imports.append((sub, node.lineno))
        mod = node.module or ""
        if mod in VERSION_MODULES or mod.startswith("jax.extend") \
                or mod.startswith("jax.core"):
            self.raw.append((
                "version-api", node.lineno,
                f"import from {mod!r} outside compat.py (the version shim)"))
        elif mod == "jax.sharding":
            for a in node.names:
                if a.name == "AxisType":
                    self.raw.append((
                        "version-api", node.lineno,
                        "AxisType import outside compat.py"))
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            if a.name.startswith("repro.kernels.") \
                    and a.name.split(".")[2] != "ops":
                self.kernel_imports.append((a.name.split(".")[2],
                                            node.lineno))
            if a.name in VERSION_MODULES or a.name.startswith("jax.extend"):
                self.raw.append((
                    "version-api", node.lineno,
                    f"import of {a.name!r} outside compat.py"))
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if isinstance(node.value, ast.Name) \
                and (node.value.id, node.attr) in VERSION_ATTRS:
            self.raw.append((
                "version-api", node.lineno,
                f"{node.value.id}.{node.attr} is version-sensitive; use the "
                f"compat shim"))
        self.generic_visit(node)


def _waived(lines: list[str], lineno: int, rule: str) -> bool:
    """Marker on the line, or in the comment-only block directly above."""
    def has(ln: int) -> bool:
        if not (1 <= ln <= len(lines)):
            return False
        return any(m == rule for m in _WAIVER_RE.findall(lines[ln - 1]))

    if has(lineno):
        return True
    ln = lineno - 1
    while 1 <= ln <= len(lines) and lines[ln - 1].strip().startswith("#"):
        if has(ln):
            return True
        ln -= 1
    return False


def lint_file(path: Path, rel: str, report: Report) -> None:
    src = path.read_text()
    lines = src.splitlines()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        report.add("parse-error", f"{rel}:{e.lineno}", str(e))
        return
    v = _Visitor()
    v.visit(tree)

    exempt = OPS_DISPATCH_EXEMPT.get(rel, ())
    used_exempt = set()
    for sub, lineno in v.kernel_imports:
        if sub in exempt:
            used_exempt.add(sub)
            continue
        v.raw.append((
            "ops-dispatch", lineno,
            f"direct import of kernels.{sub} outside kernels/ skips the "
            f"impl-dispatch table (kernels/ops.py)"))
    for sub in exempt:
        if sub not in used_exempt:
            report.add(
                "stale-exemption", rel,
                f"ops-dispatch exemption for kernels.{sub} matches no "
                f"import — remove it from OPS_DISPATCH_EXEMPT")

    for rule, lineno, msg in v.raw:
        if any(rel == p or rel.startswith(p) for p in ALLOWED.get(rule, ())):
            continue
        if _waived(lines, lineno, rule):
            continue
        report.add(rule, f"{rel}:{lineno}", msg)


def lint_paths(paths: list[str] | None = None) -> Report:
    """Lint .py files under ``paths`` (default: the repro package source)."""
    root = Path(__file__).resolve().parents[1]        # .../repro
    targets = [Path(p) for p in paths] if paths else [root]
    report = Report()
    for t in targets:
        files = sorted(t.rglob("*.py")) if t.is_dir() else [t]
        for f in files:
            try:
                rel = str(f.resolve().relative_to(root)).replace("\\", "/")
            except ValueError:
                rel = f.name
            lint_file(f, rel, report)
    return report


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    report = lint_paths(args or None)
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
