"""Layer 2: dtype-tier and determinism contracts on compiled HLO.

``launch/hlo.py`` already parses the compiled module into a census of
collectives; this layer classifies every one of those collectives against
the mesh's bandwidth tiers (``launch.mesh.zero_tiers``) and enforces the
paper's wire-format policy (DESIGN.md §9):

  dtype-tier      a large floating-point collective spans the inter tier
                  (the Slingshot fabric) without an allowlisted reason.
                  Everything big that crosses the slow links must ride the
                  quantized wire formats (s8/u8/u4/s4); fp32/bf16 is allowed
                  only for: small metrics (loss/gnorm/token counts), the
                  block-quant scale siblings of an int gather, the
                  cross-replica gradient sync (fp32 by design, paper §V-C),
                  and phases the config explicitly leaves unquantized
                  (``quantize_weights/grads/update_gather=False``, PLAIN
                  leaves which are never quantized).
  determinism     more small floating-point all-reduces spanning beyond the
                  replica axes than the token-psum budget (one per
                  microbatch; XLA may hoist or fold them, so fewer is fine).
                  The token psums are exact in any summation order (they sum
                  integers); every other fp metric reduction must go through
                  ``collectives.det_psum`` — which lowers to all-gather +
                  local fixed-order sum, never to an all-reduce — so an
                  extra all-reduce here is a raw ``lax.psum`` whose
                  summation order the fabric chooses. (Cross-replica grad
                  syncs span exactly the replica axes and are excluded:
                  they are the paper's fp32-by-design phase.)
  cost-model      the measured quantized wire bytes disagree with
                  ``topo/cost.py``'s ``phase_volumes`` prediction by more
                  than a factor — the analytic model and the compiled
                  program have drifted apart. The bound is deliberately
                  loose (XLA re-gathers under remat, combines collectives,
                  and hoists loop-invariant ones, all of which move the
                  measured count around the per-step accounting).

Replica groups are parsed from both HLO spellings — explicit
``{{0,1},{2,3}}`` lists and the iota form ``[G,D]<=[dims]T(perm)`` — and
member ids are interpreted as flat positions in the mesh's device grid
(XLA partition ids follow the sharding's device order, which is
``mesh.devices.ravel()``), so each group maps to the exact set of mesh axes
it spans, and from there to a tier.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from ..launch import hlo
from ..launch.mesh import zero_tiers
from .report import Report

# wire formats allowed to cross the inter tier at volume
INT_WIRE = {"s8", "u8", "s4", "u4", "s2", "u2", "f8e4m3fn", "f8e5m2"}
FP = {"f64", "f32", "bf16", "f16"}
# anything at or below this many fp elements is a metric, not a payload
SMALL_ELEMS = 4096

_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_GROUPS_SIG_RE = re.compile(r"replica_groups=(\[[^<\s]*(?:<=\[[\d,]+\]"
                            r"(?:T\([\d,]+\))?)?|\{.*?\}\})")


def group_members(line: str) -> list[int] | None:
    """Flat device positions of the first replica group, or None."""
    m = _EXPLICIT_RE.search(line)
    if m:
        return [int(x) for x in m.group(1).split(",") if x.strip() != ""]
    m = _IOTA_RE.search(line)
    if m:
        g, d = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        grid = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            grid = grid.transpose([int(x) for x in m.group(4).split(",")])
        return [int(x) for x in grid.reshape(g, d)[0]]
    return None


def group_signature(line: str) -> str:
    m = _GROUPS_SIG_RE.search(line)
    return m.group(1) if m else ""


def spanned_axes(members: list[int], mesh_dims: tuple[int, ...],
                 axis_names: tuple[str, ...]) -> tuple[str, ...]:
    """Mesh axes whose coordinate varies across the group members."""
    coords = np.stack(np.unravel_index(np.asarray(members), mesh_dims),
                      axis=1)                       # (n_members, n_axes)
    varies = (coords != coords[0]).any(axis=0)
    return tuple(a for a, v in zip(axis_names, varies) if v)


def _dtype_census(out_type: str) -> dict[str, int]:
    """elems per dtype family in an output type (tuples flattened)."""
    out = {"int_elems": 0, "int_bytes": 0, "fp_elems": 0, "fp_bytes": 0,
           "other_elems": 0}
    for dt, dims in hlo._SHAPE_RE.findall(out_type):
        if dt not in hlo._DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * hlo._DTYPE_BYTES[dt]
        if dt in INT_WIRE:
            out["int_elems"] += n
            out["int_bytes"] += b
        elif dt in FP:
            out["fp_elems"] += n
            out["fp_bytes"] += b
        else:
            out["other_elems"] += n
    return out


@dataclass
class Classified:
    """One collective, classified for the policy checks."""
    rec: hlo.CollectiveRecord
    spans: tuple[str, ...]
    tier: str                 # "l0" | "intra" | "inter" | "none"
    dclass: str               # "int" | "fp" | "other"
    fp_elems: int
    int_elems: int

    @property
    def sig(self) -> str:
        return group_signature(self.rec.line)


def classify(analysis: hlo.HLOAnalysis, mesh) -> list[Classified]:
    """Tier- and dtype-classify every collective record against the mesh."""
    tiers = zero_tiers(mesh)
    mesh_dims = tuple(mesh.shape[a] for a in mesh.axis_names)
    n_dev = int(np.prod(mesh_dims))
    out = []
    for rec in analysis.records:
        members = group_members(rec.line)
        if members is None or len(members) <= 1:
            continue
        if max(members) >= n_dev:
            # ids outside the mesh grid (multi-host global ids shifted by a
            # process offset still index the same grid modulo n_dev)
            members = [m % n_dev for m in members]
        spans = spanned_axes(members, mesh_dims, tuple(mesh.axis_names))
        if set(spans) & set(tiers["inter"]):
            tier = "inter"
        elif set(spans) & (set(tiers["intra"]) - set(tiers["l0"])):
            tier = "intra"
        elif spans:
            tier = "l0"
        else:
            tier = "none"
        c = _dtype_census(rec.out_type)
        if c["int_bytes"] and c["int_bytes"] >= c["fp_bytes"]:
            dclass = "int"
        elif c["fp_bytes"]:
            dclass = "fp"
        else:
            dclass = "other"
        out.append(Classified(rec, spans, tier, dclass,
                              c["fp_elems"], c["int_elems"]))
    return out


def _justify_fp(c: Classified, cfg, int_sibling_elems: dict[str, int],
                plain_max_elems: int,
                serve_axes: tuple[str, ...] = (),
                serve_act_elems: int = 0) -> str | None:
    """Why a floating-point inter-tier collective is allowed, or None.

    ``serve_axes`` marks a SERVING module (DESIGN.md §12): the residency
    axes the decode re-gathers weights over. The INT8 wire re-gather itself
    classifies int (its f32 scales ride the quant-scales sibling rule); the
    extra serving classes cover what training never emits — dense-fallback
    leaf gathers (norms/embeds, bounded by ``plain_max_elems``) and the
    per-token activation psums of the decode shard_map (bounded by
    ``serve_act_elems`` = batch x d_model)."""
    if c.fp_elems <= SMALL_ELEMS:
        return "small-metric"
    # block-quant scales riding next to an int payload over the same group
    sib = int_sibling_elems.get((c.rec.opcode, c.sig), 0)
    if sib and sib >= c.fp_elems * max(2, cfg.quant_block // 2):
        return "quant-scales"
    spans = set(c.spans)
    axes = cfg.axes
    if serve_axes and spans <= set(serve_axes):
        if c.rec.opcode == "all-gather" and c.fp_elems <= plain_max_elems:
            return "serve-dense-leaf"   # never-quantized leaves stay dense
        if c.rec.opcode == "all-gather" and not cfg.quantize_weights:
            return "serve-gather-unquantized"   # the fp-materialized backend
        if c.rec.opcode in ("all-reduce", "reduce-scatter") \
                and c.fp_elems <= serve_act_elems:
            return "serve-activation-psum"  # single-token rows, per layer
    if c.rec.opcode in ("all-reduce", "reduce-scatter") \
            and spans <= set(axes.replica):
        return "cross-replica-sync"     # fp32 by design (paper §V-C)
    weighty = set(axes.weight) | set(axes.secondary or ())
    if c.rec.opcode == "all-gather":
        if spans <= set(axes.extra_grad) | set(axes.replica) \
                and not cfg.quantize_update_gather:
            return "update-gather-unquantized"
        if spans <= weighty:
            if not cfg.quantize_weights:
                return "weights-unquantized-by-config"
            if c.fp_elems <= plain_max_elems:
                return "plain-leaf"     # norms/biases are never quantized
    if c.rec.opcode in ("all-reduce", "reduce-scatter", "all-to-all") \
            and spans <= set(axes.grad) | set(axes.replica) \
            and not cfg.quantize_grads:
        return "grads-unquantized-by-config"
    return None


def check_hlo(text: str, cfg, mesh, *, n_microbatch: int = 1,
              psi: float | None = None, plain_max_elems: int = 0,
              cost_factor: float = 2.5, label: str = "hlo",
              serve_axes: tuple[str, ...] = (),
              serve_act_elems: int = 0) -> Report:
    """Run the Layer-2 contracts on one compiled HLO module.

    ``plain_max_elems`` is the largest padded PLAIN (never-quantized) leaf,
    so fp weight gathers of at most that size are exempt from the dtype-tier
    rule; ``psi`` (the padded parameter count) enables the cost-model
    crosscheck against ``topo/cost.phase_volumes``, which must agree with
    the measured wire bytes within a factor of ``cost_factor``.
    ``serve_axes``/``serve_act_elems`` mark a serving module and enable the
    serving gather/psum classes (see ``_justify_fp``).
    """
    report = Report()
    analysis = hlo.analyze(text)
    classified = classify(analysis, mesh)

    # index: biggest int payload per (opcode, replica-group signature), for
    # recognizing the fp scale gathers that ride alongside an int gather
    int_sibling: dict[tuple[str, str], int] = {}
    for c in classified:
        if c.dclass == "int":
            key = (c.rec.opcode, c.sig)
            int_sibling[key] = max(int_sibling.get(key, 0), c.int_elems)

    # ---- dtype-tier policy ---------------------------------------------
    for c in classified:
        where = f"{label}:%{c.rec.name}"
        key = f"collectives/{c.rec.opcode}/{c.tier}/{c.dclass}"
        report.census[key] = report.census.get(key, 0) + round(c.rec.mult)
        if c.tier != "inter" or c.dclass != "fp":
            continue
        why = _justify_fp(c, cfg, int_sibling, plain_max_elems,
                          serve_axes, serve_act_elems)
        if why is None:
            report.add(
                "dtype-tier", where,
                f"{c.rec.opcode} of {c.fp_elems} fp elements spans the "
                f"inter tier (axes {c.spans}) un-quantized and matches no "
                f"allowlist class — inter-tier payloads must ride the "
                f"s8/u8/u4 wire formats")

    # ---- determinism: small fp all-reduce census ------------------------
    # Cross-replica grad syncs span exactly the replica axes and are fp32 by
    # design; beyond them, the only legitimate small fp all-reduces are the
    # integer-token psums — at most one per microbatch, and usually fewer
    # because XLA constant-folds the token counts and hoists the merged
    # psum out of the microbatch loop.
    replica = set(cfg.axes.replica)
    small_ar = sum(round(c.rec.mult) for c in classified
                   if c.rec.opcode == "all-reduce" and c.dclass == "fp"
                   and c.fp_elems <= SMALL_ELEMS
                   and not set(c.spans) <= replica)
    if small_ar > n_microbatch:
        report.add(
            "determinism", label,
            f"{small_ar} small floating-point all-reduce(s) beyond the "
            f"replica axes, budget {n_microbatch} (one integer-token psum "
            f"per microbatch): every other fp reduction must lower through "
            f"det_psum's all-gather, so an extra all-reduce is a raw "
            f"lax.psum whose summation order the fabric chooses")
    report.census["collectives/small_fp_allreduce"] = small_ar

    # ---- cost-model crosscheck ------------------------------------------
    measured_int = sum(c.rec.wire * c.rec.mult for c in classified
                      if c.dclass == "int")
    report.census["wire/int_bytes"] = round(measured_int)
    if psi:
        from ..topo.cost import phase_volumes
        vols = phase_volumes(cfg, psi)
        pred = 0.0
        if cfg.quantize_weights:
            pred += n_microbatch * (vols["fwd_allgather"]
                                    + vols["bwd_allgather"])
        if cfg.quantize_grads:
            pred += n_microbatch * vols["grad_rs_w"]
            pred += (n_microbatch if cfg.stream_grads else 1) \
                * vols["grad_rs_e"]
        if cfg.quantize_update_gather:
            pred += vols["update_gather"]
        report.census["wire/int_bytes_predicted"] = round(pred)
        if pred > 0 and not (pred / cost_factor <= measured_int
                             <= pred * cost_factor):
            report.add(
                "cost-model", label,
                f"measured quantized wire bytes {measured_int:.3g} vs "
                f"phase_volumes prediction {pred:.3g} disagree by more "
                f"than {cost_factor}x: the analytic cost model and the "
                f"compiled program have drifted apart")
    return report
