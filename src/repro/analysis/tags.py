"""Contract tags: trace-visible markers on the schedule's issue/wait values.

The schedule contracts (issue before wait, one wait per issue, rotation slot
not overwritten before its wait) are properties of *values* flowing through
the traced program, but a jaxpr walker cannot tell a quantized gather payload
from any other int8 array. ``tag(x, role=..., machine=...)`` threads the
value through a no-op primitive whose params name the contract role, so the
dataflow layer (``analysis.dataflow``) can pair issues with waits by
following actual data dependencies instead of pattern-matching shapes.

Tags are OFF by default — ``tag`` is the identity function unless tracing
happens under the ``tagging()`` context manager, so the production train
step's jaxpr (and therefore its HLO, its jit cache key, and every CI
bitwise check) is byte-identical to the untagged build.

Transformation behaviour of the primitive:

  - impl / abstract eval: identity.
  - JVP: primal stays tagged, tangent passes through UNtagged. The backward
    pass re-issues its own collectives (regather, grad-RS) which carry their
    own tags; tagging cotangents of a forward tag would mislabel them.
  - batching: vectorized identity (vmap just maps through).
  - lowering: identity (defensive — tagged programs are meant for tracing
    and jaxpr inspection, but compiling one must not crash).
"""
from __future__ import annotations

import threading
from functools import partial

import jax

from ..compat import new_primitive

ROLES = ("issue", "wait", "sink")
MACHINES = ("gather", "regather", "grad_rs", "stream")

contract_tag_p = new_primitive("contract_tag")
contract_tag_p.def_impl(lambda x, **_: x)
contract_tag_p.def_abstract_eval(lambda x, **_: x)

from jax.interpreters import ad, batching, mlir  # noqa: E402

ad.defjvp(contract_tag_p, lambda g, x, **_: g)
batching.defvectorized(contract_tag_p)
mlir.register_lowering(contract_tag_p, lambda ctx, x, **_: [x])


_state = threading.local()


def enabled() -> bool:
    return getattr(_state, "on", False)


class tagging:
    """Context manager enabling contract tags for traces opened inside it."""

    def __enter__(self):
        self._prev = enabled()
        _state.on = True
        return self

    def __exit__(self, *exc):
        _state.on = self._prev
        return False


def tag(x, *, role: str, machine: str, name: str = ""):
    """Mark every array leaf of ``x`` with a contract role.

    Identity (returns ``x`` untouched, no primitive bound) unless tracing
    under ``tagging()``. ``name`` distinguishes concurrent machines — for
    streamed sinks it is the parameter leaf name, so the sink-multiplicity
    rule can count per-leaf occurrences.
    """
    if not enabled():
        return x
    assert role in ROLES, role
    assert machine in MACHINES, machine
    bind = partial(contract_tag_p.bind, role=role, machine=machine, name=name)
    return jax.tree.map(bind, x)
