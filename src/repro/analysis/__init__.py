"""Static comm-contract verification (DESIGN.md §9).

Three layers, checked before a program ever runs:

  Layer 1 — jaxpr dataflow (``analysis.dataflow``): issue/wait pairing and
      buffer-rotation safety of the overlap machines, proved on the traced
      train step with contract tags (``analysis.tags``) marking the
      schedule-relevant values.
  Layer 2 — HLO contracts (``analysis.contracts``): every collective in the
      compiled module classified against the mesh's bandwidth tiers; the
      dtype-tier policy (quantized wire formats on inter-tier links) and the
      determinism budget enforced, and the measured wire volume cross-checked
      against ``topo.cost.phase_volumes``.
  Layer 3 — source lint (``analysis.lint``): AST rules for the invariants
      that live in the source rather than the trace (no raw fp ``lax.psum``
      outside core/collectives.py, kernels stay behind ``kernels.ops``, ...).

CLI entry points:

  python -m repro.analysis.check --model <name> --scheme <scheme>
  python -m repro.analysis.lint [paths...]
"""
from .report import Finding, Report

__all__ = ["Finding", "Report"]
