"""Layer 1: jaxpr dataflow verification of the schedule contracts.

Walks a traced program (the train step traced under ``tags.tagging()``) and
checks the issue/wait discipline of the three overlap machines
(core/schedule.py):

  gather-wait-without-issue   a wait tag consumes a locally-produced value
                              that no issue tag produced — the buffer being
                              dequantized never went through quantize+gather.
  gather-double-wait          the same buffer value is waited twice in one
                              scope (the wait is a local dequant; two waits
                              mean duplicated work or a pairing bug).
  gather-dead-issue           an issue's result is consumed by nothing and
                              escapes nowhere — a collective whose bytes are
                              simply dropped.
  buffer-overwrite-before-wait  in a scan body, a rotation slot's carry-out
                              is a fresh issue while the carried-in buffer
                              is never consumed: the prefetched weights are
                              overwritten before anything dequantized them
                              (the buffer-reuse race the 2-slot rotation
                              must avoid).
  sink-not-from-xs            a streaming sink consumed inside a scan body
                              does not ride the scan xs — its cotangent
                              would not stack per-layer (DESIGN.md §8).
  sink-multiplicity           one leaf's sink is consumed more than once in
                              a single scan step — its gradient row would be
                              double-counted.

Scopes are walked compositionally: every sub-jaxpr (scan/while bodies, pjit,
remat/checkpoint, custom_vjp calls, cond branches) is analyzed with its
parent's knowledge of where each operand came from, and returns a summary
(which inputs it waits/uses, which outputs are fresh issues) so the parent
can reason about calls without inlining. Cross-scope pairing is deliberately
permissive — a wait on a value that entered through a scope boundary is
assumed paired with an issue in some ancestor (the carry-threading of
``scan_layers`` makes exact cross-scope matching equivalent to re-proving
the schedule; the rules above catch every *locally provable* break, which
is what the mutation tests in tests/test_analysis.py pin down).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

from .report import Report

TAG_PRIMITIVE = "contract_tag"

# wait machines accept these issue machines (the fused dX kernel consumes
# regather buffers through the same gather-wait site)
COMPATIBLE = {"gather": ("gather", "regather"),
              "regather": ("gather", "regather"),
              "grad_rs": ("grad_rs",)}


@dataclass
class Summary:
    """What a sub-jaxpr does to its inputs/outputs, seen from the caller."""
    waited_in: set = field(default_factory=set)     # invar positions waited
    used_in: set = field(default_factory=set)       # invar positions used
    issued_out: set = field(default_factory=set)    # outvar positions = fresh issue
    # sinks consumed in this scope (or nested non-scan scopes), keyed by the
    # invar position their operand entered through (None = locally produced)
    sink_in: list = field(default_factory=list)     # (pos|None, name)


def _is_jaxpr(x) -> bool:
    return hasattr(x, "eqns") and hasattr(x, "invars")


def _as_open(x):
    """ClosedJaxpr or Jaxpr -> the open Jaxpr (duck-typed across versions)."""
    return x.jaxpr if hasattr(x, "jaxpr") and _is_jaxpr(x.jaxpr) else x


def _sub_jaxprs(eqn):
    """Every sub-jaxpr hiding in an eqn's params (scan/pjit/remat/cond/
    custom_vjp/...), version-robustly."""
    out = []
    for v in eqn.params.values():
        if isinstance(v, (list, tuple)):
            out.extend(_as_open(b) for b in v if _is_jaxpr(_as_open(b)))
        else:
            o = _as_open(v)
            if _is_jaxpr(o):
                out.append(o)
    return out


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")


class _Walker:
    def __init__(self, report: Report):
        self.report = report

    # origins: "xs" | "carry" | "const" | "boundary" | "local" | "issue"

    def walk(self, jaxpr, path: str, origins: list[str]) -> Summary:
        """Analyze one scope. ``origins`` aligns with ``jaxpr.invars``."""
        origin: dict = {}
        for v, o in zip(jaxpr.invars, origins):
            origin[v] = o
        for v in jaxpr.constvars:
            origin[v] = "const"

        issue_of: dict = {}      # var -> (machine, local: bool); local means
        # the issue tag is in THIS scope (dead-issue applies); propagated
        # issue values (a callee's issued output, e.g. a scan's final carry)
        # may be legitimately dropped — the epilogue/backward decides
        waited: set = set()      # vars consumed by a wait (incl. via callees)
        direct_waited: set = set()   # waited by a tag eqn in THIS scope
        used: set = set()        # vars consumed by anything that matters
        sink_events: list = []   # (var, name)

        def var_origin(v):
            if not _is_var(v):
                return "const"
            if v in issue_of:
                return "issue"
            return origin.get(v, "local")

        for idx, eqn in enumerate(jaxpr.eqns):
            prim = eqn.primitive.name
            where = f"{path}/{prim}[{idx}]"

            if prim == TAG_PRIMITIVE:
                v = eqn.invars[0]
                role = eqn.params["role"]
                machine = eqn.params["machine"]
                name = eqn.params.get("name", "")
                if role == "issue":
                    if _is_var(v):
                        used.add(v)
                    issue_of[eqn.outvars[0]] = (machine, True)
                elif role == "wait":
                    if not _is_var(v):
                        continue
                    if v in direct_waited:
                        self.report.add("gather-double-wait", where,
                                        f"{machine} buffer waited twice in "
                                        f"this scope")
                    o = var_origin(v)
                    if v in issue_of:
                        im = issue_of[v][0]
                        if im not in COMPATIBLE.get(machine, (machine,)):
                            self.report.add(
                                "gather-wait-without-issue", where,
                                f"{machine} wait consumes a value issued by "
                                f"the {im} machine")
                    elif o == "local":
                        self.report.add(
                            "gather-wait-without-issue", where,
                            f"{machine} wait consumes a locally-computed "
                            f"value that no issue produced")
                    waited.add(v)
                    direct_waited.add(v)
                    used.add(v)
                else:  # sink
                    sink_events.append((v, name))
                    if _is_var(v):
                        used.add(v)
                continue

            subs = _sub_jaxprs(eqn)
            if not subs:
                for v in eqn.invars:
                    if _is_var(v):
                        used.add(v)
                continue

            # call-like eqn: analyze each sub-jaxpr with mapped origins
            if prim == "scan":
                self._scan(eqn, subs[0], where, origin, issue_of, waited,
                           used, var_origin)
                continue

            for sub in subs:
                off = len(eqn.invars) - len(sub.invars)
                if off < 0:   # unmappable; analyze opaquely
                    self.walk(sub, where, ["boundary"] * len(sub.invars))
                    for v in eqn.invars:
                        if _is_var(v):
                            used.add(v)
                    continue
                sub_origins = [var_origin(eqn.invars[off + i])
                               for i in range(len(sub.invars))]
                s = self.walk(sub, where, sub_origins)
                for i in s.used_in:
                    v = eqn.invars[off + i]
                    if _is_var(v):
                        used.add(v)
                # a callee waiting our var marks it waited (rotation rule),
                # but is NOT a double-wait candidate: under remat the
                # backward scope legitimately re-waits the recomputed
                # forward's buffer
                for i in s.waited_in:
                    v = eqn.invars[off + i]
                    if _is_var(v):
                        waited.add(v)
                for v in eqn.invars[:off]:   # unmapped prefix (cond pred, ...)
                    if _is_var(v):
                        used.add(v)
                if len(sub.outvars) == len(eqn.outvars):
                    for j in s.issued_out:
                        issue_of.setdefault(eqn.outvars[j], ("gather", False))
                for pos, name in s.sink_in:
                    if pos is not None and pos + off >= 0:
                        sink_events.append((eqn.invars[off + pos], name))
                    else:
                        sink_events.append((None, name))

        # ---- scope-level rules ------------------------------------------
        escaped = set(v for v in jaxpr.outvars if _is_var(v))
        for v, (machine, local) in issue_of.items():
            if local and v not in used and v not in escaped:
                self.report.add(
                    "gather-dead-issue", path,
                    f"{machine} issue result is never consumed and never "
                    f"escapes this scope — the collective's bytes are "
                    f"dropped")

        # ---- summary for the caller -------------------------------------
        summ = Summary()
        pos_of = {v: i for i, v in enumerate(jaxpr.invars) if _is_var(v)}
        for v in waited:
            if v in pos_of:
                summ.waited_in.add(pos_of[v])
        for v in used:
            if v in pos_of:
                summ.used_in.add(pos_of[v])
        for j, v in enumerate(jaxpr.outvars):
            if _is_var(v) and v in issue_of:
                summ.issued_out.add(j)
        for v, name in sink_events:
            summ.sink_in.append((pos_of.get(v) if v is not None else None,
                                 name))
        return summ

    # -- scan: rotation + sink rules --------------------------------------

    def _scan(self, eqn, body, where, origin, issue_of, waited, used,
              var_origin):
        nc = eqn.params.get("num_consts", 0)
        ncar = eqn.params.get("num_carry", 0)
        n_in = len(body.invars)
        kinds = (["const"] * nc + ["carry"] * ncar
                 + ["xs"] * (n_in - nc - ncar))
        s = self.walk(body, where, kinds)

        # rotation safety: a carry slot whose carry-out is a fresh issue must
        # have its carried-in value consumed inside the body
        body_issue_out = s.issued_out
        for i in range(ncar):
            if i in body_issue_out and (nc + i) not in s.used_in:
                self.report.add(
                    "buffer-overwrite-before-wait", f"{where}:carry[{i}]",
                    "rotation slot re-issued while the carried buffer is "
                    "never consumed — prefetched weights overwritten before "
                    "their wait")

        # streaming sinks must ride the xs, once per leaf per step
        names = Counter()
        for pos, name in s.sink_in:
            kind = kinds[pos] if pos is not None and pos < len(kinds) \
                else "local"
            if kind != "xs":
                self.report.add(
                    "sink-not-from-xs", where,
                    f"streaming sink {name!r} consumed in a scan body does "
                    f"not ride the scan xs (origin: {kind})")
            names[name] += 1
        for name, k in names.items():
            if k > 1:
                self.report.add(
                    "sink-multiplicity", where,
                    f"streaming sink {name!r} consumed {k} times in one "
                    f"scan step — its gradient row would be double-counted")

        # caller-side bookkeeping: the scan consumes its operands; the final
        # carry of an issued slot is a live issue value for the caller
        for v in eqn.invars:
            if _is_var(v):
                used.add(v)
        for i in body_issue_out:
            if i < len(eqn.outvars):
                issue_of.setdefault(eqn.outvars[i], ("gather", False))
        for i in s.waited_in:
            v = eqn.invars[i]
            if _is_var(v):
                waited.add(v)


def analyze_jaxpr(closed_jaxpr, *, label: str = "step") -> Report:
    """Run the Layer-1 schedule checks on a closed jaxpr."""
    report = Report()
    jaxpr = _as_open(closed_jaxpr)
    _Walker(report).walk(jaxpr, label, ["boundary"] * len(jaxpr.invars))
    # census: tag event counts, cheap sanity anchors for the golden report
    counts = _count_tags(jaxpr)
    for k, v in counts.items():
        report.census[f"tags/{k}"] = v
    return report


def _count_tags(jaxpr, counts: Counter | None = None) -> Counter:
    counts = counts if counts is not None else Counter()
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == TAG_PRIMITIVE:
            counts[f"{eqn.params['machine']}/{eqn.params['role']}"] += 1
        for sub in _sub_jaxprs(eqn):
            _count_tags(sub, counts)
    return counts
