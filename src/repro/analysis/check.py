"""Comm-contract verifier CLI: trace + compile one train step and prove the
schedule, dtype-tier, and determinism contracts on it.

    python -m repro.analysis.check --model qwen2-0.5b --scheme zero_topo \
        [--overlap] [--stream-grads] [--impl jnp] [--n-microbatch 2]

Two passes over the same configuration (separate engines, because the tag
primitive must not contaminate the compiled-HLO pass through jit caches):

  1. the step is traced under ``tags.tagging()`` and the jaxpr walked by
     ``dataflow.analyze_jaxpr`` (Layer 1: issue/wait/rotation/sink rules);
  2. a fresh engine's step is compiled and the HLO checked by
     ``contracts.check_hlo`` (Layer 2: dtype-tier policy, determinism
     census, cost-model crosscheck against ``topo/cost.phase_volumes``).

``--grid`` runs the CI matrix (overlap x stream-grads) in one process and
emits ``BENCH_contracts.json`` (collective counts per tier/dtype class) to
``$REPRO_BENCH_DIR`` for the bench-gate leg. Exits non-zero on any finding.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def _build(args, overlap: bool, stream: bool):
    """One (engine, step, abstract inputs) for the given schedule knobs."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..core.engine import TrainHparams, ZeroEngine
    from ..launch.mesh import make_test_mesh, scheme_config
    from ..models.registry import build_model, get_arch

    mesh = make_test_mesh(shape=tuple(args.mesh), axes=tuple(args.axes))
    cfg = scheme_config(args.scheme, mesh, quant_block=args.quant_block,
                        overlap=overlap, stream_grads=stream,
                        **({"impl": args.impl} if args.impl else {}))
    arch = get_arch(args.model)
    if args.reduced:
        arch = arch.reduced(n_layers=args.n_layers, d_model=args.d_model,
                            vocab=args.vocab)
    model = build_model(arch)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=8, warmup_steps=0,
                                  n_microbatch=args.n_microbatch))
    data_axes = tuple(args.axes)
    step = eng.make_train_step(model.loss_fn(), {"tokens": P(data_axes)})
    rows = max(args.n_microbatch, 1) * len(jax.devices())
    batch = {"tokens": jax.ShapeDtypeStruct(
        (rows, args.seq), jnp.int32,
        sharding=NamedSharding(mesh, P(data_axes)))}
    return mesh, cfg, eng, step, batch


def check_one(args, overlap: bool, stream: bool):
    """Run Layers 1+2 on one configuration; returns the merged Report."""
    import jax

    from ..core.partition import GATHER_Q, MATMUL, PLAIN
    from . import contracts, dataflow, tags

    label = (f"{args.model}/{args.scheme}"
             f"/overlap={overlap}/stream={stream}")

    # Layer 1: tagged trace (its own engine: tags change the jaxpr)
    mesh, cfg, eng, step, batch = _build(args, overlap, stream)
    with tags.tagging():
        jx = jax.make_jaxpr(step)(eng.abstract_state(), batch)
    report = dataflow.analyze_jaxpr(jx, label=label)

    # Layer 2: untagged compile of a fresh engine
    mesh, cfg, eng, step, batch = _build(args, overlap, stream)
    text = step.lower(eng.abstract_state(), batch).compile().as_text()
    psi_q = sum(eng._pad[n] * (s.stack or 1) for n, s in eng.specs.items()
                if s.kind in (MATMUL, GATHER_Q))
    # fp weight gathers up to the combined size of every PLAIN leaf are
    # legitimate (XLA's all-gather combiner may fuse them into one tuple)
    plain_max = sum(eng._pad[n] for n, s in eng.specs.items()
                    if s.kind == PLAIN)
    report.extend(contracts.check_hlo(
        text, cfg, mesh, n_microbatch=args.n_microbatch, psi=psi_q,
        plain_max_elems=plain_max, label=label))
    return report


def _bench_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) \
        / "BENCH_contracts.json"


def build_parser() -> argparse.ArgumentParser:
    """The verifier CLI surface (rendered into docs/CLI.md by
    ``repro.launch.cli_reference``)."""
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.check",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="qwen2-0.5b")
    ap.add_argument("--scheme", default="zero_topo")
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--stream-grads", action="store_true")
    ap.add_argument("--impl", default=None,
                    help="kernel impl (jnp | pallas | pallas_interpret)")
    ap.add_argument("--n-microbatch", type=int, default=2)
    ap.add_argument("--quant-block", type=int, default=64)
    ap.add_argument("--mesh", type=lambda s: [int(x) for x in s.split(",")],
                    default=[2, 2, 2])
    ap.add_argument("--axes", type=lambda s: s.split(","),
                    default=["data", "node", "gcd"])
    ap.add_argument("--seq", type=int, default=33)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the arch to CI size (default on)")
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--grid", action="store_true",
                    help="run the overlap x stream-grads matrix and emit "
                         "BENCH_contracts.json")
    ap.add_argument("--emit-bench", action="store_true",
                    help="also emit BENCH_contracts.json in single-run mode")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    n_dev = 1
    for d in args.mesh:
        n_dev *= d
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    combos = [(o, s) for o in (False, True) for s in (False, True)] \
        if args.grid else [(args.overlap, args.stream_grads)]
    bench = {}
    failed = False
    for overlap, stream in combos:
        rep = check_one(args, overlap, stream)
        key = f"overlap={overlap}/stream={stream}"
        print(f"[{key}] {rep.render()}")
        bench[key] = dict(sorted(rep.census.items()))
        failed = failed or not rep.ok
    if args.grid or args.emit_bench:
        path = _bench_path()
        path.write_text(json.dumps(
            dict(model=args.model, scheme=args.scheme,
                 n_microbatch=args.n_microbatch, census=bench),
            indent=2, sort_keys=True))
        print(f"wrote {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
