"""Finding/Report containers shared by all three analysis layers.

A ``Finding`` is one contract violation: a rule id (stable, documented in
DESIGN.md §9), a human-readable location (source file:line, HLO instruction,
or jaxpr scope path), and a message. A ``Report`` aggregates findings plus
the census counters the CI baseline gates on.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    rule: str        # stable rule id, e.g. "gather-wait-without-issue"
    where: str       # location: "file.py:123", "hlo:all-reduce.5", "scan[0]"
    message: str

    def __str__(self) -> str:
        return f"{self.where}: [{self.rule}] {self.message}"


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    census: dict[str, int] = field(default_factory=dict)

    def add(self, rule: str, where: str, message: str) -> None:
        self.findings.append(Finding(rule, where, message))

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        for k, v in other.census.items():
            self.census[k] = self.census.get(k, 0) + v

    @property
    def ok(self) -> bool:
        return not self.findings

    def rules(self) -> set[str]:
        return {f.rule for f in self.findings}

    def render(self) -> str:
        if self.ok:
            return "OK: all contracts hold"
        lines = [f"{len(self.findings)} contract violation(s):"]
        lines += [f"  {f}" for f in self.findings]
        return "\n".join(lines)
