"""Training loop: data -> sharded batches -> engine train_step -> logs/ckpt.

The loop is deliberately thin — all distribution logic lives in
``ZeroEngine.make_train_step`` — but it is the piece a real run launches:
deterministic data, periodic eval, checkpointing, throughput accounting and
a modeled-TFLOPS report (6·N·D / step-time; on CPU wall-time is meaningless,
on TPU this is the paper's TFLOPS-per-GPU metric).

Trace mode (``TraceConfig``, DESIGN.md §10): the loop swaps the monolithic
step for the phased one (``obs.phased.PhasedStep`` — same math, fenced per
phase), streams a per-step JSONL metrics record (``obs.metrics``), stamps
per-rank heartbeats (``obs.heartbeat``) and can export the collected spans
as a Chrome/Perfetto trace. With ``trace=None`` nothing here changes: the
untouched monolithic step runs, which is what keeps the bitwise CI
contracts trivially intact.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax

from ..core.engine import TrainHparams, ZeroEngine, host_scalar
from ..data.pipeline import BatchSpec, SyntheticTokens, shard_batch, spec_for
from ..models.config import ArchConfig, ShapeConfig
from ..models.registry import ModelDef, batch_axes
from ..obs import heartbeat as obs_heartbeat
from ..obs import metrics as obs_metrics
from ..obs.spans import SpanRecorder, TraceConfig, write_chrome_trace
from . import checkpoint


def _host_int(x) -> int:
    """Scalar fetch that works on multi-process (replicated) arrays too."""
    return int(host_scalar(x))


@dataclass
class TrainLog:
    steps: list[int] = field(default_factory=list)
    losses: list[float] = field(default_factory=list)
    grad_norms: list[float] = field(default_factory=list)
    step_times: list[float] = field(default_factory=list)
    lrs: list[float] = field(default_factory=list)
    tokens: list[float] = field(default_factory=list)
    tokens_per_s: list[float] = field(default_factory=list)
    tflops_per_gpu: list[float] = field(default_factory=list)
    meta: dict = field(default_factory=dict)   # scheme/overlap/mesh, for A/Bs

    def record(self, step, metrics, dt, *, tokens_per_s: float = 0.0,
               tflops_per_gpu: float = 0.0):
        """Persist the FULL metrics dict the step emits, not just
        loss/gnorm — lr and token counts are what make two logs comparable
        after the fact."""
        self.steps.append(_host_int(step))
        self.losses.append(float(metrics["loss"]))
        self.grad_norms.append(float(metrics["grad_norm"]))
        self.step_times.append(dt)
        self.lrs.append(float(metrics.get("lr", 0.0)))
        self.tokens.append(float(metrics.get("tokens", 0.0)))
        self.tokens_per_s.append(tokens_per_s)
        self.tflops_per_gpu.append(tflops_per_gpu)

    def aggregates(self) -> dict:
        """Run summary. The first recorded step's dt includes trace+compile
        time, so every throughput/dt aggregate EXCLUDES it (a one-step run
        has nothing else to offer and keeps its only sample). Loss/gnorm
        means keep all steps."""
        if not self.steps:
            return {}
        timed = slice(1, None) if len(self.steps) > 1 else slice(None)

        def mean(xs):
            return sum(xs) / len(xs) if xs else 0.0

        return dict(
            n_steps=len(self.steps),
            n_timed_steps=len(self.step_times[timed]),
            loss_mean=mean(self.losses),
            grad_norm_mean=mean(self.grad_norms),
            dt_s_mean=mean(self.step_times[timed]),
            tokens_per_s_mean=mean(self.tokens_per_s[timed]),
            tflops_per_gpu_mean=mean(self.tflops_per_gpu[timed]),
        )

    def save(self, path):
        payload = dict(self.__dict__)
        payload["aggregates"] = self.aggregates()
        Path(path).write_text(json.dumps(payload))


class Trainer:
    def __init__(self, model: ModelDef, engine: ZeroEngine, mesh,
                 shape: ShapeConfig, *, seed: int = 0,
                 data=None, trace: TraceConfig | None = None):
        self.model = model
        self.engine = engine
        self.mesh = mesh
        self.shape = shape
        self.trace = trace
        self.baxes = batch_axes(
            mesh, shape.global_batch,
            candidates=tuple(a for a in mesh.axis_names if a != "pod"))
        shapes = model.train_batch_shapes(shape)
        self.bspecs = model.batch_pspecs(shapes, self.baxes)
        self.step_fn = engine.make_train_step(model.loss_fn(), self.bspecs)
        self.data = data or SyntheticTokens(spec_for(model.arch, shape),
                                            seed=seed)
        self.log = TrainLog(meta=dict(
            arch=model.arch.name, scheme=engine.cfg.name,
            overlap=engine.cfg.overlap, mesh=dict(mesh.shape),
            traced=trace is not None))

    def _shard_batch(self, np_batch):
        # process-aware: each process feeds only its addressable shards from
        # the deterministic global batch (pipeline.shard_batch)
        return shard_batch(np_batch, self.mesh, self.bspecs)

    def run(self, state, n_steps: int, *, log_every: int = 10,
            ckpt_dir: str | None = None, ckpt_every: int = 0,
            print_fn=print):
        n_params = self.engine.param_count()
        n_dev = int(self.mesh.devices.size)
        tokens_per_step = self.shape.global_batch * self.shape.seq_len
        mem_pred = self.engine.memory_report()["total"]
        rank, n_ranks = jax.process_index(), jax.process_count()

        trace = self.trace
        rec = writer = phased = None
        if trace is not None:
            from ..obs.phased import PhasedStep
            rec = SpanRecorder()
            phased = PhasedStep(self.engine, self.model.loss_fn(),
                                self.bspecs)
            if trace.metrics_path:
                writer = obs_metrics.MetricsWriter(
                    trace.metrics_path, rank=rank, n_ranks=n_ranks)

        it = iter(self.data)
        for i in range(n_steps):
            batch = self._shard_batch(next(it))
            if trace is not None and trace.heartbeat_dir:
                obs_heartbeat.stamp(trace.heartbeat_dir, rank, i)
            t0 = time.time()
            if phased is not None:
                rec.step = i
                state, metrics = phased(state, batch, rec)
                dt = time.time() - t0    # segments are fenced: dt is wall
                if trace.probe_every and i % trace.probe_every == 0:
                    phased.run_probes(state, batch, rec)
            else:
                state, metrics = self.step_fn(state, batch)
                jax.tree.map(lambda x: x.block_until_ready(), metrics)
                dt = time.time() - t0
            # metrics are cluster-global (psum over all axes inside the
            # step); this fetch works on every process of a multi-host run
            metrics = self.engine.metrics_to_host(metrics)
            toks = metrics.get("tokens") or float(tokens_per_step)
            tps = toks / dt if dt > 0 else 0.0
            tfl = obs_metrics.tflops_per_gpu(n_params, toks, dt, n_dev)
            self.log.record(state["step"], metrics, dt,
                            tokens_per_s=tps, tflops_per_gpu=tfl)
            if writer is not None:
                phase = phased.phase_seconds(rec, i)
                writer.write(dict(
                    step=_host_int(state["step"]), rank=rank,
                    loss=metrics["loss"], grad_norm=metrics["grad_norm"],
                    lr=metrics["lr"], tokens=toks, dt_s=dt,
                    tokens_per_s=tps, tflops_per_gpu=tfl,
                    phase_ms={k: v * 1e3 for k, v in phase.items()},
                    overlap_efficiency=phased.overlap_efficiency(rec, i),
                    memory_hw_bytes=obs_metrics.memory_high_water(),
                    memory_pred_bytes=mem_pred,
                ))
            if log_every and i % log_every == 0:
                tflops = 6.0 * n_params * tokens_per_step / dt / 1e12
                print_fn(f"step {_host_int(state['step']):5d} "
                         f"loss {metrics['loss']:.4f} "
                         f"gnorm {metrics['grad_norm']:.3f} "
                         f"lr {metrics['lr']:.2e} "
                         f"{dt:.2f}s/step  model-TFLOPS(total) {tflops:.2f}")
            if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                checkpoint.save(state, ckpt_dir, _host_int(state["step"]),
                                scheme=self.engine.scheme_fingerprint())
        if trace is not None:
            if trace.heartbeat_dir:
                obs_heartbeat.stamp(trace.heartbeat_dir, rank, n_steps)
            if trace.chrome_trace:
                write_chrome_trace(rec.chrome_events(rank=rank),
                                   trace.chrome_trace)
        if writer is not None:
            writer.close()
        self._last_recorder = rec
        return state

    def restore(self, ckpt_dir, step: int | None = None, *,
                reshard: bool = True):
        """Restore a checkpoint into this trainer's engine layout.

        ``reshard=True`` (default): a checkpoint written under a different
        mesh/process layout or partition scheme is resharded onto this
        engine through the partition formulas (checkpoint.py, DESIGN.md
        §11) — this is what makes ``--resume`` elastic. ``reshard=False``
        restores strictly, failing loudly (checkpoint.SchemeMismatch /
        MeshMismatch) on any layout difference.
        """
        step = checkpoint.latest_step(ckpt_dir) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        return checkpoint.restore(ckpt_dir, step,
                                  self.engine.state_shardings(),
                                  expect_scheme=self.engine.scheme_fingerprint(),
                                  reshard=reshard)
