"""Training loop: data -> sharded batches -> engine train_step -> logs/ckpt.

The loop is deliberately thin — all distribution logic lives in
``ZeroEngine.make_train_step`` — but it is the piece a real run launches:
deterministic data, periodic eval, checkpointing, throughput accounting and
a modeled-TFLOPS report (6·N·D / step-time; on CPU wall-time is meaningless,
on TPU this is the paper's TFLOPS-per-GPU metric).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path

import jax

from ..core.engine import TrainHparams, ZeroEngine, host_scalar
from ..data.pipeline import BatchSpec, SyntheticTokens, shard_batch, spec_for
from ..models.config import ArchConfig, ShapeConfig
from ..models.registry import ModelDef, batch_axes
from . import checkpoint


def _host_int(x) -> int:
    """Scalar fetch that works on multi-process (replicated) arrays too."""
    return int(host_scalar(x))


@dataclass
class TrainLog:
    steps: list[int] = field(default_factory=list)
    losses: list[float] = field(default_factory=list)
    grad_norms: list[float] = field(default_factory=list)
    step_times: list[float] = field(default_factory=list)
    meta: dict = field(default_factory=dict)   # scheme/overlap/mesh, for A/Bs

    def record(self, step, metrics, dt):
        self.steps.append(_host_int(step))
        self.losses.append(float(metrics["loss"]))
        self.grad_norms.append(float(metrics["grad_norm"]))
        self.step_times.append(dt)

    def save(self, path):
        Path(path).write_text(json.dumps(self.__dict__))


class Trainer:
    def __init__(self, model: ModelDef, engine: ZeroEngine, mesh,
                 shape: ShapeConfig, *, seed: int = 0,
                 data=None):
        self.model = model
        self.engine = engine
        self.mesh = mesh
        self.shape = shape
        self.baxes = batch_axes(
            mesh, shape.global_batch,
            candidates=tuple(a for a in mesh.axis_names if a != "pod"))
        shapes = model.train_batch_shapes(shape)
        self.bspecs = model.batch_pspecs(shapes, self.baxes)
        self.step_fn = engine.make_train_step(model.loss_fn(), self.bspecs)
        self.data = data or SyntheticTokens(spec_for(model.arch, shape),
                                            seed=seed)
        self.log = TrainLog(meta=dict(
            arch=model.arch.name, scheme=engine.cfg.name,
            overlap=engine.cfg.overlap, mesh=dict(mesh.shape)))

    def _shard_batch(self, np_batch):
        # process-aware: each process feeds only its addressable shards from
        # the deterministic global batch (pipeline.shard_batch)
        return shard_batch(np_batch, self.mesh, self.bspecs)

    def run(self, state, n_steps: int, *, log_every: int = 10,
            ckpt_dir: str | None = None, ckpt_every: int = 0,
            print_fn=print):
        n_params = self.engine.param_count()
        tokens_per_step = self.shape.global_batch * self.shape.seq_len
        it = iter(self.data)
        for i in range(n_steps):
            batch = self._shard_batch(next(it))
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            jax.tree.map(lambda x: x.block_until_ready(), metrics)
            dt = time.time() - t0
            # metrics are cluster-global (psum over all axes inside the
            # step); this fetch works on every process of a multi-host run
            metrics = self.engine.metrics_to_host(metrics)
            self.log.record(state["step"], metrics, dt)
            if log_every and i % log_every == 0:
                tflops = 6.0 * n_params * tokens_per_step / dt / 1e12
                print_fn(f"step {_host_int(state['step']):5d} "
                         f"loss {metrics['loss']:.4f} "
                         f"gnorm {metrics['grad_norm']:.3f} "
                         f"lr {metrics['lr']:.2e} "
                         f"{dt:.2f}s/step  model-TFLOPS(total) {tflops:.2f}")
            if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                checkpoint.save(state, ckpt_dir, _host_int(state["step"]),
                                scheme=self.engine.scheme_fingerprint())
        return state

    def restore(self, ckpt_dir, step: int | None = None):
        """Restore a checkpoint into this trainer's engine layout.

        Fails loudly (checkpoint.SchemeMismatch) if the checkpoint was
        written under a different scheme/mesh/padding than this engine.
        """
        step = checkpoint.latest_step(ckpt_dir) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        return checkpoint.restore(ckpt_dir, step,
                                  self.engine.state_shardings(),
                                  expect_scheme=self.engine.scheme_fingerprint())
