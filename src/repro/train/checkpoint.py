"""Sharded numpy checkpointing, single- and multi-process.

Single-process (the historical format, unchanged on disk): every leaf of the
training state is gathered to host and saved as one ``.npy``; layout + step
metadata in ``meta.json``. Restore re-places shards with the engine's
NamedShardings.

Multi-process (``jax.process_count() > 1``): gathering would need a
cross-host collective per leaf and a full copy of the state on every host —
instead each process writes exactly its *addressable* shards
(``leaf_0007.p002.npy`` = process 2's local shards of leaf 7, stacked in
local-device order) and process 0 writes ``meta.json``. Restore hands each
process its own file back via ``jax.make_array_from_single_device_arrays``
— no cross-process traffic in either direction.

Both formats record the writing run's mesh layout; restoring onto a
different device/process count raises ``MeshMismatch`` naming both layouts
(the per-process format physically cannot be re-placed onto a different
layout, and the global format would otherwise die much later in an opaque
reshape inside the first train step). Scheme-level layout identity
(partitioning degrees, padding) is covered by the separate
``SchemeMismatch`` check, same spirit.

Simple, dependency-free, and round-trip tested — a real deployment would
swap in async/multi-host Orbax behind the same two functions.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(state, prefix=""):
    out = {}
    for k, v in state.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "/"))
        else:
            out[key] = v
    return out


def _unflatten(flat):
    out: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def _to_disk_dtype(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "V":        # ml_dtypes (bfloat16, fp8): raw bits
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _from_disk_dtype(arr: np.ndarray, want: str | None) -> np.ndarray:
    if want and str(arr.dtype) != want:
        import ml_dtypes  # packaged with jax
        return arr.view(np.dtype(getattr(ml_dtypes, want, want)))
    return arr


# -- mesh layout identity ----------------------------------------------------

def _state_mesh(flat: dict):
    """The mesh a flat dict of arrays OR shardings lives on (None for
    host/numpy states)."""
    for v in flat.values():
        if getattr(v, "mesh", None) is not None:     # a NamedSharding
            return v.mesh
        sh = getattr(v, "sharding", None)            # a device array
        if sh is not None and getattr(sh, "mesh", None) is not None:
            return sh.mesh
    return None


def mesh_layout(mesh) -> dict:
    """JSON-serializable identity of a mesh's device/process layout."""
    local = sum(1 for d in np.asarray(mesh.devices).ravel()
                if getattr(d, "process_index", 0) == jax.process_index())
    return dict(axes=list(mesh.axis_names),
                shape=[int(mesh.shape[a]) for a in mesh.axis_names],
                n_devices=int(mesh.size),
                process_count=int(jax.process_count()),
                local_devices=int(local))


class MeshMismatch(ValueError):
    """Checkpoint device/process layout does not match the restoring mesh."""


def _fmt_layout(d: dict) -> str:
    return (f"{dict(zip(d.get('axes', []), d.get('shape', [])))} "
            f"({d.get('n_devices')} devices, {d.get('process_count')} "
            f"process(es) x {d.get('local_devices')} local)")


def _check_mesh(saved: dict | None, live: dict, where: str,
                strict_shape: bool = False):
    if saved is None:
        return           # legacy checkpoint without mesh metadata
    mismatch = (saved.get("n_devices") != live["n_devices"]
                or saved.get("process_count") != live["process_count"]
                or saved.get("local_devices") != live["local_devices"]
                or (strict_shape and (saved.get("axes") != live["axes"]
                                      or saved.get("shape") != live["shape"])))
    if mismatch:
        raise MeshMismatch(
            f"{where} was written on a different mesh layout:\n"
            f"  checkpoint: {_fmt_layout(saved)}\n"
            f"  restoring : {_fmt_layout(live)}\n"
            "Shard files are laid out per device/process, so they cannot be "
            "re-placed across layouts. Relaunch with the checkpoint's "
            "process/device count, or re-shard the checkpoint explicitly "
            "(restore on the writing layout, then save on the new one).")


def _barrier(tag: str):
    if jax.process_count() == 1:
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(tag)


# -- save --------------------------------------------------------------------

def save(state, ckpt_dir, step: int, scheme: dict | None = None):
    """``scheme``: the writing engine's ``scheme_fingerprint()`` — recorded
    in meta.json so a restore under a different partitioning fails loudly
    instead of silently re-placing shards in the wrong layout. The mesh
    layout is recorded unconditionally (read off the state's shardings)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    flat = _flatten(state)
    mesh = _state_mesh(flat)
    multiprocess = jax.process_count() > 1
    if multiprocess and mesh is None:
        raise ValueError("multi-process save needs a device-backed state "
                         "(host arrays carry no shard placement)")
    d.mkdir(parents=True, exist_ok=True)

    names, dtypes, shapes = {}, {}, {}
    pid = jax.process_index()
    for i, (k, v) in enumerate(sorted(flat.items())):
        base = f"leaf_{i:04d}"
        if not multiprocess:
            arr = np.asarray(jax.device_get(v))
            dtypes[k] = str(arr.dtype)
            shapes[k] = list(arr.shape)
            np.save(d / f"{base}.npy", _to_disk_dtype(arr))
            names[k] = f"{base}.npy"
            continue
        # per-process: this process's addressable shards, local-device order
        shards = sorted(v.addressable_shards, key=lambda s: s.device.id)
        stack = np.stack([np.asarray(s.data) for s in shards])
        dtypes[k] = str(stack.dtype)
        shapes[k] = list(v.shape)
        np.save(d / f"{base}.p{pid:03d}.npy", _to_disk_dtype(stack))
        names[k] = base      # per-process files share the base name

    if pid == 0:
        meta = dict(step=step, names=names, dtypes=dtypes,
                    global_shapes=shapes,
                    format="per_process" if multiprocess else "global")
        if mesh is not None:
            meta["mesh"] = mesh_layout(mesh)
        if scheme is not None:
            meta["scheme"] = scheme
        (d / "meta.json").write_text(json.dumps(meta))
    _barrier(f"ckpt_save_{step}")
    return str(d)


# -- scheme guard (layout identity below the mesh: degrees, padding) ---------

class SchemeMismatch(ValueError):
    """Checkpoint layout does not match the restoring engine's scheme."""


def _check_scheme(saved: dict | None, expect: dict, where: str):
    # normalize through JSON so tuples/lists and int/float compare equal
    expect = json.loads(json.dumps(expect))
    if saved is None:
        raise SchemeMismatch(
            f"{where} has no scheme metadata (written before scheme "
            f"recording, or by an external tool); refusing to restore into "
            f"an engine expecting {expect['scheme']!r}. Re-save the "
            f"checkpoint with a scheme fingerprint, or restore with "
            f"expect_scheme=None to skip the check at your own risk.")
    if saved != expect:
        diffs = []
        for k in sorted(set(saved) | set(expect)):
            if saved.get(k) != expect.get(k):
                diffs.append(f"  {k}: checkpoint={saved.get(k)!r} "
                             f"engine={expect.get(k)!r}")
        raise SchemeMismatch(
            f"{where} was written under a different partitioning scheme — "
            f"restoring it here would silently place shards in the wrong "
            f"layout. Mismatched fields:\n" + "\n".join(diffs) +
            "\nRebuild the engine with the checkpoint's scheme/mesh, or "
            "re-shard the checkpoint explicitly.")


def latest_step(ckpt_dir) -> int | None:
    steps = sorted(int(p.name.split("_")[1]) for p in Path(ckpt_dir).glob("step_*"))
    return steps[-1] if steps else None


# -- restore -----------------------------------------------------------------

def _restore_leaf_global(d: Path, fname: str, k: str, meta: dict, sh):
    arr = _from_disk_dtype(np.load(d / fname),
                           meta.get("dtypes", {}).get(k))
    if sh is None:
        return jax.numpy.asarray(arr)
    if jax.process_count() > 1:
        # device_put of a host array would try to place non-addressable
        # shards; the callback form feeds each local shard from its slice
        return jax.make_array_from_callback(arr.shape, sh,
                                            lambda idx, a=arr: a[idx])
    return jax.device_put(arr, sh)


def _restore_leaf_per_process(d: Path, base: str, k: str, meta: dict, sh):
    if sh is None:
        raise ValueError(f"per-process checkpoint leaf {k!r} has no "
                         "sharding in the restore request")
    pid = jax.process_index()
    path = d / f"{base}.p{pid:03d}.npy"
    if not path.exists():
        raise MeshMismatch(
            f"{path} missing: this process has no shard file — the "
            f"checkpoint was written by a different process layout "
            f"({_fmt_layout(meta.get('mesh', {}))})")
    stack = _from_disk_dtype(np.load(path), meta.get("dtypes", {}).get(k))
    mesh = sh.mesh
    local = sorted((dev for dev in np.asarray(mesh.devices).ravel()
                    if dev.process_index == pid), key=lambda dev: dev.id)
    if len(local) != stack.shape[0]:
        raise MeshMismatch(
            f"{path} holds {stack.shape[0]} shards but this process owns "
            f"{len(local)} devices of the restoring mesh "
            f"({_fmt_layout(mesh_layout(mesh))})")
    shape = tuple(meta["global_shapes"][k])
    bufs = [jax.device_put(stack[j], dev) for j, dev in enumerate(local)]
    return jax.make_array_from_single_device_arrays(shape, sh, bufs)


def restore(ckpt_dir, step: int, shardings=None, expect_scheme: dict | None = None):
    """``expect_scheme``: the restoring engine's ``scheme_fingerprint()``;
    when given, the saved fingerprint must match exactly or restore raises
    ``SchemeMismatch`` with the differing fields. The mesh layout check
    (``MeshMismatch``) runs whenever ``shardings`` are given and the
    checkpoint recorded its mesh."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    if expect_scheme is not None:
        _check_scheme(meta.get("scheme"), expect_scheme, str(d))
    fmt = meta.get("format", "global")
    sh_flat = _flatten(shardings) if shardings else {}
    live_mesh = _state_mesh(sh_flat) if sh_flat else None
    if live_mesh is not None:
        _check_mesh(meta.get("mesh"), mesh_layout(live_mesh), str(d),
                    strict_shape=(fmt == "per_process"))
    elif fmt == "per_process":
        raise ValueError(f"{d} is a per-process checkpoint; restore needs "
                         "the engine's shardings to re-place the shards")

    flat = {}
    for k, fname in meta["names"].items():
        sh = sh_flat.get(k)
        if fmt == "per_process":
            flat[k] = _restore_leaf_per_process(d, fname, k, meta, sh)
        else:
            flat[k] = _restore_leaf_global(d, fname, k, meta, sh)
    _barrier(f"ckpt_restore_{step}")
    return _unflatten(flat)
