"""Sharded numpy checkpointing.

Each leaf of the training state is saved as one ``.npy`` (gathered to host);
layout + step metadata in ``meta.json``. Restore re-places shards with the
engine's NamedShardings. Simple, dependency-free, and round-trip tested —
a real deployment would swap in async/multi-host Orbax behind the same two
functions.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(state, prefix=""):
    out = {}
    for k, v in state.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "/"))
        else:
            out[key] = v
    return out


def _unflatten(flat):
    out: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def save(state, ckpt_dir, step: int, scheme: dict | None = None):
    """``scheme``: the writing engine's ``scheme_fingerprint()`` — recorded
    in meta.json so a restore under a different partitioning fails loudly
    instead of silently re-placing shards in the wrong layout."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    names = {}
    dtypes = {}
    for i, (k, v) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(v))
        dtypes[k] = str(arr.dtype)
        if arr.dtype.kind == "V":        # ml_dtypes (bfloat16, fp8): raw bits
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        np.save(d / f"leaf_{i:04d}.npy", arr)
        names[k] = f"leaf_{i:04d}.npy"
    meta = dict(step=step, names=names, dtypes=dtypes)
    if scheme is not None:
        meta["scheme"] = scheme
    (d / "meta.json").write_text(json.dumps(meta))
    return str(d)


class SchemeMismatch(ValueError):
    """Checkpoint layout does not match the restoring engine's scheme."""


def _check_scheme(saved: dict | None, expect: dict, where: str):
    # normalize through JSON so tuples/lists and int/float compare equal
    expect = json.loads(json.dumps(expect))
    if saved is None:
        raise SchemeMismatch(
            f"{where} has no scheme metadata (written before scheme "
            f"recording, or by an external tool); refusing to restore into "
            f"an engine expecting {expect['scheme']!r}. Re-save the "
            f"checkpoint with a scheme fingerprint, or restore with "
            f"expect_scheme=None to skip the check at your own risk.")
    if saved != expect:
        diffs = []
        for k in sorted(set(saved) | set(expect)):
            if saved.get(k) != expect.get(k):
                diffs.append(f"  {k}: checkpoint={saved.get(k)!r} "
                             f"engine={expect.get(k)!r}")
        raise SchemeMismatch(
            f"{where} was written under a different partitioning scheme — "
            f"restoring it here would silently place shards in the wrong "
            f"layout. Mismatched fields:\n" + "\n".join(diffs) +
            "\nRebuild the engine with the checkpoint's scheme/mesh, or "
            "re-shard the checkpoint explicitly.")


def latest_step(ckpt_dir) -> int | None:
    steps = sorted(int(p.name.split("_")[1]) for p in Path(ckpt_dir).glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, shardings=None, expect_scheme: dict | None = None):
    """``expect_scheme``: the restoring engine's ``scheme_fingerprint()``;
    when given, the saved fingerprint must match exactly or restore raises
    ``SchemeMismatch`` with the differing fields."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    if expect_scheme is not None:
        _check_scheme(meta.get("scheme"), expect_scheme, str(d))
    flat = {}
    sh_flat = _flatten(shardings) if shardings else {}
    import ml_dtypes  # packaged with jax

    for k, fname in meta["names"].items():
        arr = np.load(d / fname)
        want = meta.get("dtypes", {}).get(k)
        if want and str(arr.dtype) != want:
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        if k in sh_flat:
            flat[k] = jax.device_put(arr, sh_flat[k])
        else:
            flat[k] = jax.numpy.asarray(arr)
    return _unflatten(flat)
