"""Sharded numpy checkpointing, single- and multi-process.

Single-process (the historical format, unchanged on disk): every leaf of the
training state is gathered to host and saved as one ``.npy``; layout + step
metadata in ``meta.json``. Restore re-places shards with the engine's
NamedShardings.

Multi-process (``jax.process_count() > 1``): gathering would need a
cross-host collective per leaf and a full copy of the state on every host —
instead each process writes exactly its *addressable* shards
(``leaf_0007.p002.npy`` = process 2's local shards of leaf 7, stacked in
local-device order) and process 0 writes ``meta.json``. Restore hands each
process its own file back via ``jax.make_array_from_single_device_arrays``
— no cross-process traffic in either direction.

Both formats record the writing run's mesh layout; restoring onto a
different device/process count raises ``MeshMismatch`` naming both layouts
(the per-process format physically cannot be re-placed *directly* onto a
different layout, and the global format would otherwise die much later in
an opaque reshape inside the first train step). Scheme-level layout
identity (partitioning degrees, padding) is covered by the separate
``SchemeMismatch`` check, same spirit.

Elastic restore (DESIGN.md §11): ``restore(..., reshard=True)`` demotes
both mismatches from errors to work. Each leaf is routed through the
partition formulas recorded in the checkpoint's scheme fingerprint
(core/partition.py): the per-process shard files are reassembled into the
global logical array using the v1 ``device_map`` (device-id -> mesh coords
/ owning process), the alignment padding is resized to the restoring
engine's padded sizes (the padding is exactly zero throughout training, so
this is truncate-zeros / re-pad-zeros with a refusal if real data would be
dropped), and the global array is re-placed under the live engine's
NamedShardings. This is what lets a run killed on one process/device
layout resume on another (``Trainer.restore`` / ``--resume`` default it
on). v0 checkpoints (no ``version`` field) restore unchanged on their
writing layout; per-process v0 files lack the device map and therefore
cannot cross layouts.

Simple, dependency-free, and round-trip tested — a real deployment would
swap in async/multi-host Orbax behind the same two functions.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

# meta.json format versions:
#   v0 (no "version" field) — seed era: names/dtypes/shapes/mesh/scheme.
#   v1 — adds "version" and "device_map" (device-id -> mesh coords and
#        owning process), which is what makes per-process shard files
#        reassemblable on a *different* process layout (reshard=True).
# Readers accept every version <= FORMAT_VERSION; newer files fail loudly
# naming both versions instead of misreading fields.
FORMAT_VERSION = 1


def _check_version(meta: dict, where: str):
    v = int(meta.get("version", 0))
    if v > FORMAT_VERSION:
        raise ValueError(
            f"{where} is checkpoint format v{v}, but this build reads "
            f"v{FORMAT_VERSION} and older. Upgrade the reader (or re-save "
            f"the checkpoint with a v{FORMAT_VERSION} writer).")


def _flatten(state, prefix=""):
    out = {}
    for k, v in state.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "/"))
        else:
            out[key] = v
    return out


def _unflatten(flat):
    out: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def _to_disk_dtype(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind == "V":        # ml_dtypes (bfloat16, fp8): raw bits
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _from_disk_dtype(arr: np.ndarray, want: str | None) -> np.ndarray:
    if want and str(arr.dtype) != want:
        import ml_dtypes  # packaged with jax
        return arr.view(np.dtype(getattr(ml_dtypes, want, want)))
    return arr


# -- mesh layout identity ----------------------------------------------------

def _state_mesh(flat: dict):
    """The mesh a flat dict of arrays OR shardings lives on (None for
    host/numpy states)."""
    for v in flat.values():
        if getattr(v, "mesh", None) is not None:     # a NamedSharding
            return v.mesh
        sh = getattr(v, "sharding", None)            # a device array
        if sh is not None and getattr(sh, "mesh", None) is not None:
            return sh.mesh
    return None


def mesh_layout(mesh) -> dict:
    """JSON-serializable identity of a mesh's device/process layout."""
    local = sum(1 for d in np.asarray(mesh.devices).ravel()
                if getattr(d, "process_index", 0) == jax.process_index())
    return dict(axes=list(mesh.axis_names),
                shape=[int(mesh.shape[a]) for a in mesh.axis_names],
                n_devices=int(mesh.size),
                process_count=int(jax.process_count()),
                local_devices=int(local))


def _device_map(mesh) -> dict:
    """v1 meta: explicit device-id -> mesh coords and owning process.

    ``jax.make_mesh`` may permute devices for locality, so row-major order
    over ``mesh.devices`` is NOT implied by the axis sizes — resharding a
    per-process checkpoint needs the writing run's actual placement."""
    grid = np.asarray(mesh.devices)
    coords = {str(d.id): [int(c) for c in idx]
              for idx, d in np.ndenumerate(grid)}
    procs = {str(d.id): int(getattr(d, "process_index", 0))
             for d in grid.ravel()}
    return dict(coords=coords, process=procs)


class MeshMismatch(ValueError):
    """Checkpoint device/process layout does not match the restoring mesh."""


def _fmt_layout(d: dict) -> str:
    return (f"{dict(zip(d.get('axes', []), d.get('shape', [])))} "
            f"({d.get('n_devices')} devices, {d.get('process_count')} "
            f"process(es) x {d.get('local_devices')} local)")


def _layout_differs(saved: dict | None, live: dict,
                    strict_shape: bool = False) -> bool:
    if saved is None:
        return False     # legacy checkpoint without mesh metadata
    return (saved.get("n_devices") != live["n_devices"]
            or saved.get("process_count") != live["process_count"]
            or saved.get("local_devices") != live["local_devices"]
            or (strict_shape and (saved.get("axes") != live["axes"]
                                  or saved.get("shape") != live["shape"])))


def _check_mesh(saved: dict | None, live: dict, where: str,
                strict_shape: bool = False):
    if _layout_differs(saved, live, strict_shape):
        raise MeshMismatch(
            f"{where} was written on a different mesh layout:\n"
            f"  checkpoint: {_fmt_layout(saved)}\n"
            f"  restoring : {_fmt_layout(live)}\n"
            "Shard files are laid out per device/process, so they cannot be "
            "re-placed directly across layouts. Restore with reshard=True "
            "(the Trainer/--resume default) to route each leaf through the "
            "partition formulas onto this mesh, or relaunch with the "
            "checkpoint's process/device count.")


def _barrier(tag: str):
    if jax.process_count() == 1:
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(tag)


# -- save --------------------------------------------------------------------

def save(state, ckpt_dir, step: int, scheme: dict | None = None):
    """``scheme``: the writing engine's ``scheme_fingerprint()`` — recorded
    in meta.json so a restore under a different partitioning fails loudly
    instead of silently re-placing shards in the wrong layout. The mesh
    layout is recorded unconditionally (read off the state's shardings)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    flat = _flatten(state)
    mesh = _state_mesh(flat)
    multiprocess = jax.process_count() > 1
    if multiprocess and mesh is None:
        raise ValueError("multi-process save needs a device-backed state "
                         "(host arrays carry no shard placement)")
    d.mkdir(parents=True, exist_ok=True)

    names, dtypes, shapes = {}, {}, {}
    pid = jax.process_index()
    for i, (k, v) in enumerate(sorted(flat.items())):
        base = f"leaf_{i:04d}"
        if not multiprocess:
            arr = np.asarray(jax.device_get(v))
            dtypes[k] = str(arr.dtype)
            shapes[k] = list(arr.shape)
            np.save(d / f"{base}.npy", _to_disk_dtype(arr))
            names[k] = f"{base}.npy"
            continue
        # per-process: this process's addressable shards, local-device order
        shards = sorted(v.addressable_shards, key=lambda s: s.device.id)
        stack = np.stack([np.asarray(s.data) for s in shards])
        dtypes[k] = str(stack.dtype)
        shapes[k] = list(v.shape)
        np.save(d / f"{base}.p{pid:03d}.npy", _to_disk_dtype(stack))
        names[k] = base      # per-process files share the base name

    if pid == 0:
        meta = dict(version=FORMAT_VERSION, step=step, names=names,
                    dtypes=dtypes, global_shapes=shapes,
                    format="per_process" if multiprocess else "global")
        if mesh is not None:
            meta["mesh"] = mesh_layout(mesh)
            meta["device_map"] = _device_map(mesh)
        if scheme is not None:
            meta["scheme"] = scheme
        (d / "meta.json").write_text(json.dumps(meta))
    _barrier(f"ckpt_save_{step}")
    return str(d)


# -- scheme guard (layout identity below the mesh: degrees, padding) ---------

class SchemeMismatch(ValueError):
    """Checkpoint layout does not match the restoring engine's scheme."""


def _check_scheme(saved: dict | None, expect: dict, where: str):
    # normalize through JSON so tuples/lists and int/float compare equal
    expect = json.loads(json.dumps(expect))
    if saved is None:
        raise SchemeMismatch(
            f"{where} has no scheme metadata (written before scheme "
            f"recording, or by an external tool); refusing to restore into "
            f"an engine expecting {expect['scheme']!r}. Re-save the "
            f"checkpoint with a scheme fingerprint, or restore with "
            f"expect_scheme=None to skip the check at your own risk.")
    if saved != expect:
        diffs = []
        for k in sorted(set(saved) | set(expect)):
            if saved.get(k) != expect.get(k):
                diffs.append(f"  {k}: checkpoint={saved.get(k)!r} "
                             f"engine={expect.get(k)!r}")
        raise SchemeMismatch(
            f"{where} was written under a different partitioning scheme — "
            f"restoring it here would silently place shards in the wrong "
            f"layout. Mismatched fields:\n" + "\n".join(diffs) +
            "\nRebuild the engine with the checkpoint's scheme/mesh, or "
            "re-shard the checkpoint explicitly.")


def latest_step(ckpt_dir) -> int | None:
    steps = sorted(int(p.name.split("_")[1]) for p in Path(ckpt_dir).glob("step_*"))
    return steps[-1] if steps else None


# -- restore -----------------------------------------------------------------

def _place_global(arr: np.ndarray, sh):
    """Place a host-global array under a NamedSharding (or leave it host)."""
    if sh is None:
        return jax.numpy.asarray(arr)
    if jax.process_count() > 1:
        # device_put of a host array would try to place non-addressable
        # shards; the callback form feeds each local shard from its slice
        return jax.make_array_from_callback(arr.shape, sh,
                                            lambda idx, a=arr: a[idx])
    return jax.device_put(arr, sh)


def _restore_leaf_global(d: Path, fname: str, k: str, meta: dict, sh):
    arr = _from_disk_dtype(np.load(d / fname),
                           meta.get("dtypes", {}).get(k))
    return _place_global(arr, sh)


def _restore_leaf_per_process(d: Path, base: str, k: str, meta: dict, sh):
    if sh is None:
        raise ValueError(f"per-process checkpoint leaf {k!r} has no "
                         "sharding in the restore request")
    pid = jax.process_index()
    path = d / f"{base}.p{pid:03d}.npy"
    if not path.exists():
        raise MeshMismatch(
            f"{path} missing: this process has no shard file — the "
            f"checkpoint was written by a different process layout "
            f"({_fmt_layout(meta.get('mesh', {}))})")
    stack = _from_disk_dtype(np.load(path), meta.get("dtypes", {}).get(k))
    mesh = sh.mesh
    local = sorted((dev for dev in np.asarray(mesh.devices).ravel()
                    if dev.process_index == pid), key=lambda dev: dev.id)
    if len(local) != stack.shape[0]:
        raise MeshMismatch(
            f"{path} holds {stack.shape[0]} shards but this process owns "
            f"{len(local)} devices of the restoring mesh "
            f"({_fmt_layout(mesh_layout(mesh))})")
    shape = tuple(meta["global_shapes"][k])
    bufs = [jax.device_put(stack[j], dev) for j, dev in enumerate(local)]
    return jax.make_array_from_single_device_arrays(shape, sh, bufs)


# -- elastic restore: reshard any checkpoint onto any mesh (DESIGN.md §11) ---

# flat state categories -> which partition-axis group the leaf's LAST dim is
# sharded over (ZeroEngine.state_shardings: primaries P(..., weight), os-shard
# leaves P(..., weight+extra_grad+replica), step replicated)
_OS_CATS = ("master", "opt_m", "opt_v")


def _category_axes(key: str, scheme: dict) -> list[str]:
    """Mesh axes (major -> minor) the saved leaf was sharded over, from the
    WRITING engine's scheme fingerprint."""
    cat = key.split("/", 1)[0]
    ax = scheme["axes"]
    if cat == "primaries":
        return list(ax["weight"])
    if cat in _OS_CATS:
        return list(ax["weight"]) + list(ax["extra_grad"]) + list(ax["replica"])
    return []            # step and anything unknown: replicated


def _assemble_global(d: Path, base: str, k: str, meta: dict) -> np.ndarray:
    """Reassemble one leaf's global array from per-process shard files.

    Every shard's position is computed from the v1 device map + the saved
    scheme's partition axes: device coords -> shard index along the last
    (flat padded) dim, major-to-minor over the category's axis tuple —
    exactly the PartitionSpec semantics the writer sharded under."""
    scheme, dmap = meta.get("scheme"), meta.get("device_map")
    if scheme is None or dmap is None:
        raise MeshMismatch(
            f"{d / base}: per-process checkpoint predates format "
            f"v{FORMAT_VERSION} (no scheme/device_map in meta.json) — it "
            "cannot be resharded across layouts. Restore it on the writing "
            f"layout ({_fmt_layout(meta.get('mesh', {}))}) and re-save.")
    mesh_meta = meta["mesh"]
    sizes = dict(zip(mesh_meta["axes"], mesh_meta["shape"]))
    axis_pos = {a: i for i, a in enumerate(mesh_meta["axes"])}
    axes = _category_axes(k, scheme)
    n_shards = int(np.prod([sizes[a] for a in axes])) if axes else 1

    by_proc: dict[int, list[int]] = {}
    for did, p in dmap["process"].items():
        by_proc.setdefault(int(p), []).append(int(did))
    chunks: list[np.ndarray | None] = [None] * n_shards
    for pid, ids in sorted(by_proc.items()):
        path = d / f"{base}.p{pid:03d}.npy"
        if not path.exists():
            raise MeshMismatch(
                f"{path} missing: resharding needs every writing process's "
                f"shard file visible on a shared filesystem "
                f"({_fmt_layout(mesh_meta)})")
        stack = np.load(path)
        ids = sorted(ids)            # save() stacks in device-id order
        if len(ids) != stack.shape[0]:
            raise MeshMismatch(
                f"{path} holds {stack.shape[0]} shards but the device map "
                f"assigns {len(ids)} devices to process {pid}")
        for row, did in enumerate(ids):
            coords = dmap["coords"][str(did)]
            idx = 0
            for a in axes:
                idx = idx * sizes[a] + int(coords[axis_pos[a]])
            if chunks[idx] is None:  # replicas of a shard are identical
                chunks[idx] = stack[row]
    missing = [i for i, c in enumerate(chunks) if c is None]
    if missing:
        raise MeshMismatch(f"{d / base}: shard indices {missing} missing "
                           "from the per-process files")
    g = chunks[0] if n_shards == 1 else np.concatenate(chunks, axis=-1)
    want = tuple(meta["global_shapes"][k])
    if g.shape != want:
        g = g.reshape(want)
    return _from_disk_dtype(g, meta.get("dtypes", {}).get(k))


def _target_shape(key: str, meta: dict, expect_scheme: dict | None) -> tuple:
    """Global shape this leaf must have under the RESTORING engine: same
    logical content, alignment padding resized to the live scheme's
    ``padded_sizes`` (core/partition.padded_flat_size)."""
    saved = tuple(meta["global_shapes"][key])
    cat, _, name = key.partition("/")
    if expect_scheme is None or cat not in ("primaries",) + _OS_CATS:
        return saved
    pad = expect_scheme.get("padded_sizes", {}).get(name)
    if pad is None:
        return saved
    return saved[:-1] + (int(pad),)


def _fit_padded(arr: np.ndarray, k: str, want: tuple) -> np.ndarray:
    """Resize the flat padded dim. Alignment padding is exactly zero for
    the whole training state (zero-init beyond the logical slice, zero
    grads there, decay of zero stays zero), so growing re-pads zeros and
    shrinking truncates — refusing if the truncated tail holds real data."""
    if arr.shape == want:
        return arr
    if arr.ndim != len(want) or arr.shape[:-1] != want[:-1]:
        raise ValueError(
            f"{k}: checkpoint leaf shape {arr.shape} cannot be resharded to "
            f"{want} — only the padded flat dim may differ (is this the "
            "same model?)")
    keep = min(arr.shape[-1], want[-1])
    tail = arr[..., keep:]
    if tail.size:
        bits = tail.view({1: np.uint8, 2: np.uint16, 4: np.uint32,
                          8: np.uint64}[tail.dtype.itemsize])
        if np.any(bits):
            raise ValueError(
                f"{k}: truncating the padded dim {arr.shape[-1]} -> "
                f"{want[-1]} would drop nonzero data — the checkpoint's "
                "padding is not clean (not written by this engine?)")
    out = np.zeros(want, dtype=arr.dtype)
    out[..., :keep] = arr[..., :keep]
    return out


def _check_leaf_names(meta: dict, expect_scheme: dict | None, where: str):
    pads = (expect_scheme or {}).get("padded_sizes")
    if not pads:
        return
    saved = {k.split("/", 1)[1] for k in meta["names"]
             if k.startswith("primaries/")}
    if saved and saved != set(pads):
        missing = sorted(set(pads) - saved)[:4]
        extra = sorted(saved - set(pads))[:4]
        raise SchemeMismatch(
            f"{where} holds a different model's leaves — resharding maps "
            f"layouts, not architectures. Engine-only: {missing}; "
            f"checkpoint-only: {extra}")


def _reshard_leaf(d: Path, fname: str, k: str, meta: dict, sh,
                  expect_scheme: dict | None):
    if meta.get("format", "global") == "per_process":
        arr = _assemble_global(d, fname, k, meta)
    else:
        arr = _from_disk_dtype(np.load(d / fname),
                               meta.get("dtypes", {}).get(k))
    arr = _fit_padded(arr, k, _target_shape(k, meta, expect_scheme))
    return _place_global(arr, sh)


def restore(ckpt_dir, step: int, shardings=None,
            expect_scheme: dict | None = None, *, reshard: bool = False):
    """``expect_scheme``: the restoring engine's ``scheme_fingerprint()``;
    when given (and ``reshard=False``), the saved fingerprint must match
    exactly or restore raises ``SchemeMismatch`` with the differing fields.
    The mesh layout check (``MeshMismatch``) runs whenever ``shardings``
    are given and the checkpoint recorded its mesh.

    ``reshard=True`` demotes both checks: a checkpoint written under a
    different mesh/process layout or partition scheme is reassembled into
    global logical arrays (per-process files via the v1 device map), its
    alignment padding resized to the live scheme, and re-placed under the
    given shardings. When nothing differs the fast per-shard path runs
    unchanged, so ``reshard=True`` is safe as a default."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    _check_version(meta, str(d))
    fmt = meta.get("format", "global")
    sh_flat = _flatten(shardings) if shardings else {}
    live_mesh = _state_mesh(sh_flat) if sh_flat else None

    scheme_differs = False
    if expect_scheme is not None:
        if reshard:
            saved_scheme = meta.get("scheme")
            norm = json.loads(json.dumps(expect_scheme))
            scheme_differs = saved_scheme is not None and saved_scheme != norm
        else:
            _check_scheme(meta.get("scheme"), expect_scheme, str(d))

    layout_differs = False
    if live_mesh is not None:
        live = mesh_layout(live_mesh)
        if reshard:
            layout_differs = _layout_differs(
                meta.get("mesh"), live, strict_shape=(fmt == "per_process"))
        else:
            _check_mesh(meta.get("mesh"), live, str(d),
                        strict_shape=(fmt == "per_process"))
    elif fmt == "per_process":
        raise ValueError(f"{d} is a per-process checkpoint; restore needs "
                         "the engine's shardings to re-place the shards")

    shapes_differ = False
    if reshard and expect_scheme is not None:
        _check_leaf_names(meta, expect_scheme, str(d))
        shapes_differ = any(
            _target_shape(k, meta, expect_scheme)
            != tuple(meta["global_shapes"][k]) for k in meta["names"])

    flat = {}
    if reshard and (layout_differs or scheme_differs or shapes_differ):
        for k, fname in meta["names"].items():
            flat[k] = _reshard_leaf(d, fname, k, meta, sh_flat.get(k),
                                    expect_scheme)
    else:
        for k, fname in meta["names"].items():
            sh = sh_flat.get(k)
            if fmt == "per_process":
                flat[k] = _restore_leaf_per_process(d, fname, k, meta, sh)
            else:
                flat[k] = _restore_leaf_global(d, fname, k, meta, sh)
    _barrier(f"ckpt_restore_{step}")
    return _unflatten(flat)
