"""Sharded numpy checkpointing.

Each leaf of the training state is saved as one ``.npy`` (gathered to host);
layout + step metadata in ``meta.json``. Restore re-places shards with the
engine's NamedShardings. Simple, dependency-free, and round-trip tested —
a real deployment would swap in async/multi-host Orbax behind the same two
functions.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(state, prefix=""):
    out = {}
    for k, v in state.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "/"))
        else:
            out[key] = v
    return out


def _unflatten(flat):
    out: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def save(state, ckpt_dir, step: int):
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    names = {}
    dtypes = {}
    for i, (k, v) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(v))
        dtypes[k] = str(arr.dtype)
        if arr.dtype.kind == "V":        # ml_dtypes (bfloat16, fp8): raw bits
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        np.save(d / f"leaf_{i:04d}.npy", arr)
        names[k] = f"leaf_{i:04d}.npy"
    (d / "meta.json").write_text(json.dumps(dict(step=step, names=names,
                                                 dtypes=dtypes)))
    return str(d)


def latest_step(ckpt_dir) -> int | None:
    steps = sorted(int(p.name.split("_")[1]) for p in Path(ckpt_dir).glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, shardings=None):
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    flat = {}
    sh_flat = _flatten(shardings) if shardings else {}
    import ml_dtypes  # packaged with jax

    for k, fname in meta["names"].items():
        arr = np.load(d / fname)
        want = meta.get("dtypes", {}).get(k)
        if want and str(arr.dtype) != want:
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        if k in sh_flat:
            flat[k] = jax.device_put(arr, sh_flat[k])
        else:
            flat[k] = jax.numpy.asarray(arr)
    return _unflatten(flat)
