"""minicpm3-4b — dense with Multi-head Latent Attention (MLA), 62 layers.
[hf:openbmb/MiniCPM3-4B]"""
from ..models.config import ArchConfig, MLAConfig
from ..models.registry import register


@register
def minicpm3_4b() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab=73448,
        block_pattern=("mla",) * 62,
        mla=MLAConfig(q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32,
                      v_head=64),
        norm="rms", act="silu_glu",
        source="hf:openbmb/MiniCPM3-4B",
    )
