"""deepseek-7b — llama-arch dense MHA. [arXiv:2401.02954]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def deepseek_7b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab=102_400,
        rope_theta=10_000.0, norm="rms", act="silu_glu",
        source="arXiv:2401.02954",
    )
