"""gpt-neox-10b — the paper's second evaluation size (a 10B GPT-NeoX-style
config; the paper does not publish exact dims, we use 32L x 5120, a standard
~10.9B GPT shape). [paper §VI Figs 8/9]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def gpt_neox_10b() -> ArchConfig:
    return ArchConfig(
        name="gpt-neox-10b", family="dense",
        n_layers=32, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=20480, vocab=50_432,
        block_pattern=("neox",) * 32,
        parallel_residual=True, norm="ln", act="gelu",
        source="paper §VI (assumed dims)",
    )
