"""qwen2-0.5b — dense, GQA kv=2, QKV bias, tied embeddings.
[arXiv:2407.10671]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def qwen2_05b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151_936,
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0, norm="rms", act="silu_glu",
        source="arXiv:2407.10671",
    )
