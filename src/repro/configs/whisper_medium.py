"""whisper-medium — encoder-decoder, conv/mel frontend STUB (precomputed frame
embeddings are inputs), 24+24 layers. Positions are sinusoidal (adaptation:
the HF checkpoint uses learned decoder positions; synthetic stress shapes
exceed its 448-position table). [arXiv:2212.04356]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51_865,
        block_pattern=("dec",) * 24, enc_layers=24, n_frames=1500,
        norm="ln", act="gelu", qkv_bias=True,
        source="arXiv:2212.04356",
    )
