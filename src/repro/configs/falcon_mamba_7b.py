"""falcon-mamba-7b — attention-free Mamba-1 SSM, 64 layers, ssm_state=16.
[arXiv:2410.05355]"""
from ..models.config import ArchConfig, SSMConfig
from ..models.registry import register


@register
def falcon_mamba_7b() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, d_ff=0, vocab=65024,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        tie_embeddings=True, norm="rms",
        source="arXiv:2410.05355",
    )
