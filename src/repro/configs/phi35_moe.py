"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from ..models.config import ArchConfig, MoEConfig
from ..models.registry import register


@register
def phi35_moe() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400),
        rope_theta=10_000.0, norm="ln", act="silu_glu",
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )
