"""gpt-neox-20b — the paper's primary evaluation model (GPT-NeoX 20B:
44 layers, d=6144, 64 heads, parallel residual, LayerNorm, GELU MLP).
[Black et al. 2022, paper §VI]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def gpt_neox_20b() -> ArchConfig:
    return ArchConfig(
        name="gpt-neox-20b", family="dense",
        n_layers=44, d_model=6144, n_heads=64, n_kv_heads=64,
        d_ff=24576, vocab=50_432,
        block_pattern=("neox",) * 44,
        parallel_residual=True, norm="ln", act="gelu",
        source="arXiv:2204.06745 (paper Figs 7/10)",
    )
