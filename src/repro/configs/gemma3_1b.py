"""gemma3-1b — dense, GQA kv=1, 5:1 local:global sliding-window pattern,
head_dim=256, 262k vocab (tied embeddings). [hf:google/gemma-3-1b-pt]"""
from ..models.config import ArchConfig
from ..models.registry import register


def _pattern(n_layers: int) -> tuple[str, ...]:
    # 5 local (SWA-512) then 1 global per group of 6 (layers 5,11,17,23 global)
    return tuple("attn_global" if (i + 1) % 6 == 0 else "attn_local"
                 for i in range(n_layers))


@register
def gemma3_1b() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab=262_144,
        block_pattern=_pattern(26), sliding_window=512,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        tie_embeddings=True, embed_scale=True, norm="rms", act="gelu_glu",
        source="hf:google/gemma-3-1b-pt",
    )
