"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave (attention at layer
index 4 of each 8-layer Jamba block), MoE (16e top-2) every other layer.
Attention layers use no positional encoding (Jamba design).
[arXiv:2403.19887]"""
from ..models.config import ArchConfig, MoEConfig, SSMConfig
from ..models.registry import register


def _pattern(n_layers: int = 32) -> tuple[str, ...]:
    out = []
    for i in range(n_layers):
        mixer = "attn" if i % 8 == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        out.append(f"{mixer}_{ffn}")
    return tuple(out)


@register
def jamba_52b() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536,
        block_pattern=_pattern(32),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        norm="rms", act="silu_glu",
        source="arXiv:2403.19887",
    )
