"""mixtral-8x7b — 8-expert top-2 MoE, GQA kv=8, sliding-window 4096.
[arXiv:2401.04088]"""
from ..models.config import ArchConfig, MoEConfig
from ..models.registry import register


@register
def mixtral_8x7b() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336),
        sliding_window=4096,
        rope_theta=1_000_000.0, norm="rms", act="silu_glu",
        source="arXiv:2401.04088",
    )
