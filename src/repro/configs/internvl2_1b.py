"""internvl2-1b — VLM: InternViT frontend (STUB: precomputed patch embeddings
are inputs) + Qwen2-0.5B-style language decoder. [arXiv:2404.16821]"""
from ..models.config import ArchConfig
from ..models.registry import register


@register
def internvl2_1b() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151_655,
        qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0, norm="rms", act="silu_glu",
        n_patches=256,
        source="arXiv:2404.16821",
    )
