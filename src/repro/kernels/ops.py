"""Jit'd public wrappers around the quantization kernels.

The engine quantizes *flat 1-D parameter shards* (DeepSpeed-style flattened
storage); these wrappers own the (pad, reshape-to-blocks, kernel, unreshape)
plumbing and the implementation dispatch:

  impl="jnp"               pure-jnp oracle (default: inlines into the big
                           distributed XLA graph; what the CPU dry-run uses)
  impl="pallas"            compiled Pallas TPU kernel (the deploy target)
  impl="pallas_interpret"  Pallas kernel body interpreted on CPU (tests)

Set the process-wide default with ``set_default_impl`` (e.g. launcher sets
"pallas" on TPU backends).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from . import ref
from .quant_blockwise import dequantize_int8_pallas, quantize_int8_pallas
from .quant_int4 import dequantize_int4_pallas, quantize_int4_pallas

DEFAULT_BLOCK = 512
_DEFAULT_IMPL = "jnp"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("jnp", "pallas", "pallas_interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    assert x.ndim == 1 and x.size % block == 0, (x.shape, block)
    return x.reshape(-1, block)


def quantize_int8(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x (size % block == 0) -> (int8 same shape, f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int8_ref(b)
    else:
        q, s = quantize_int8_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int8(q, scales, block: int = DEFAULT_BLOCK, dtype=jnp.float32,
                    impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = _blocks(q, block)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int8_ref(qb, sb, dtype)
    else:
        out = dequantize_int8_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def quantize_int4(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x -> (uint8 packed (size//2,), f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int4_ref(b)
    else:
        q, s = quantize_int4_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int4(packed, scales, block: int = DEFAULT_BLOCK,
                    dtype=jnp.float32, impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = packed.reshape(-1, block // 2)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int4_ref(qb, sb, dtype)
    else:
        out = dequantize_int4_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


@functools.cache
def padded_size(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple
