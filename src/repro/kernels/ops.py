"""Jit'd public wrappers around the quantization kernels.

The engine quantizes *flat 1-D parameter shards* (DeepSpeed-style flattened
storage); these wrappers own the (pad, reshape-to-blocks, kernel, unreshape)
plumbing and the implementation dispatch:

  impl="jnp"               pure-jnp oracle (default: inlines into the big
                           distributed XLA graph; what the CPU dry-run uses)
  impl="pallas"            compiled Pallas TPU kernel (the deploy target)
  impl="pallas_interpret"  Pallas kernel body interpreted on CPU (tests)

Set the process-wide default with ``set_default_impl`` (e.g. launcher sets
"pallas" on TPU backends).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from . import ref
from .dequant_matmul import dequant_matmul_flat_pallas
from .quant_blockwise import (dequantize_int8_pallas,
                              dequantize_int8_sum_pallas,
                              quantize_int8_pallas)
from .quant_int4 import (dequantize_int4_pallas, dequantize_int4_sum_pallas,
                         quantize_int4_pallas)

DEFAULT_BLOCK = 512
_DEFAULT_IMPL = "jnp"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("jnp", "pallas", "pallas_interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    assert x.ndim == 1 and x.size % block == 0, (x.shape, block)
    return x.reshape(-1, block)


def quantize_int8(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x (size % block == 0) -> (int8 same shape, f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int8_ref(b)
    else:
        q, s = quantize_int8_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int8(q, scales, block: int = DEFAULT_BLOCK, dtype=jnp.float32,
                    impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = _blocks(q, block)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int8_ref(qb, sb, dtype)
    else:
        out = dequantize_int8_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def quantize_int4(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x -> (uint8 packed (size//2,), f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int4_ref(b)
    else:
        q, s = quantize_int4_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int4(packed, scales, block: int = DEFAULT_BLOCK,
                    dtype=jnp.float32, impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = packed.reshape(-1, block // 2)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int4_ref(qb, sb, dtype)
    else:
        out = dequantize_int4_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def dequantize_int4_sum(packed, scales, d: int, block: int = DEFAULT_BLOCK,
                        dtype=jnp.float32, impl: str | None = None):
    """Fused unpack + dequant + reduce of a2a-received INT4 chunks.

    packed: flat (d * n/2,) uint8 (d chunks, row-major); scales: flat
    (d * n/block,). Returns (n,) = sum over the d chunks, dequantized once
    — the receive-side half of the ZeRO++ quantized reduce-scatter in a
    single pass (no d dequantized copies round-tripping through HBM)."""
    impl = impl or _DEFAULT_IMPL
    qb = packed.reshape(d, -1, block // 2)
    sb = scales.reshape(d, -1, 1)
    if impl == "jnp":
        out = ref.dequantize_int4_sum_ref(qb, sb, dtype)
    else:
        out = dequantize_int4_sum_pallas(qb, sb, dtype,
                                         interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def dequantize_int8_sum(q, scales, d: int, block: int = DEFAULT_BLOCK,
                        dtype=jnp.float32, impl: str | None = None):
    """INT8 variant of ``dequantize_int4_sum`` (bits=8 gradient RS)."""
    impl = impl or _DEFAULT_IMPL
    qb = q.reshape(d, -1, block)
    sb = scales.reshape(d, -1, 1)
    if impl == "jnp":
        out = ref.dequantize_int8_sum_ref(qb, sb, dtype)
    else:
        out = dequantize_int8_sum_pallas(qb, sb, dtype,
                                         interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Fused dequant x matmul (flat-shard scale layout)
# ---------------------------------------------------------------------------

def matmul_fusable(shape: tuple[int, ...], block: int) -> bool:
    """Can a weight of logical ``shape`` feed the fused dequant matmul?

    Requires >= 2 dims and the last (column) dim to be a whole number of
    quantization blocks, so the flat blocks tile each row of the (K, N)
    view exactly. Non-fusable leaves fall back to dequant -> matmul."""
    return len(shape) >= 2 and shape[-1] % block == 0


@functools.cache
def _divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>= 1)."""
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _contraction_tile(c_len: int, block: int, transpose: bool) -> int:
    """Contraction tile (one accumulation step per tile).

    Along K (transpose=False) any divisor works; along N (transpose=True)
    the tile must stay a whole number of scale blocks. Capped near 512 so
    the K-blocked jnp oracle unrolls only a handful of dots and compiled
    tiles stay VMEM-sized."""
    if transpose:
        return block * _divisor_leq(c_len // block, max(1, 512 // block))
    return _divisor_leq(c_len, 512)


def dequant_matmul(x2, q_flat, scales, w_shape: tuple[int, int],
                   block: int = DEFAULT_BLOCK, *, transpose: bool = False,
                   dtype=jnp.bfloat16, impl: str | None = None):
    """y = x @ dequant(W) (or x @ dequant(W).T) without materializing W.

    ``q_flat``/``scales`` are the flat gathered INT8 buffer + per-block
    scales exactly as the collectives produce them (padded; only the first
    K*N / K*N//block entries are consumed). ``w_shape`` = (K, N) logical.
    x2: (M, K) (or (M, N) when transpose). Output rows are padded to the
    f32 sublane multiple internally and sliced back.

    impl="jnp" runs ``ref.dequant_matmul_flat_ref`` with the *same*
    contraction blocking and accumulation order as the kernel, so jnp and
    pallas_interpret results are bitwise identical (tests/test_kernels.py).
    """
    impl = impl or _DEFAULT_IMPL
    k, n = w_shape
    assert n % block == 0, (w_shape, block)
    q2 = q_flat.reshape(-1)[: k * n].reshape(k, n)
    s2 = scales.reshape(-1)[: (k * n) // block].reshape(k, n // block)
    m = x2.shape[0]
    m_pad = padded_size(max(m, 1), 8)
    if m_pad != m:
        x2 = jnp.pad(x2, ((0, m_pad - m), (0, 0)))
    bc = _contraction_tile(n if transpose else k, block, transpose)
    out_dim = k if transpose else n
    if impl == "jnp":
        out = ref.dequant_matmul_flat_ref(x2, q2, s2, block, bc=bc,
                                          transpose=transpose, dtype=dtype)
    elif impl == "pallas_interpret":
        # full M/out-dim extents: one grid tile per contraction step, the
        # exact blocking the jnp oracle mirrors (bitwise contract, §5)
        out = dequant_matmul_flat_pallas(
            x2, q2, s2, block=block, bm=m_pad, bo=out_dim, bc=bc,
            transpose=transpose, dtype=dtype, interpret=True)
    else:
        # compiled TPU: VMEM-sized tiles (the fused win is HBM traffic, so
        # the accumulation order may differ from the CPU oracle here — like
        # any other MXU-vs-CPU matmul)
        bm = _divisor_leq(m_pad, 256)
        if transpose:
            bo = _divisor_leq(out_dim, 512)
        else:
            bo = block * _divisor_leq(out_dim // block, max(1, 512 // block))
        out = dequant_matmul_flat_pallas(
            x2, q2, s2, block=block, bm=bm, bo=bo, bc=bc,
            transpose=transpose, dtype=dtype, interpret=False)
    return out[:m] if m_pad != m else out


@functools.cache
def padded_size(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple
