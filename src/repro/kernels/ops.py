"""Jit'd public wrappers around the quantization kernels.

The engine quantizes *flat 1-D parameter shards* (DeepSpeed-style flattened
storage); these wrappers own the (pad, reshape-to-blocks, kernel, unreshape)
plumbing and the implementation dispatch:

  impl="jnp"               pure-jnp oracle (default: inlines into the big
                           distributed XLA graph; what the CPU dry-run uses)
  impl="pallas"            compiled Pallas TPU kernel (the deploy target)
  impl="pallas_interpret"  Pallas kernel body interpreted on CPU (tests)

Set the process-wide default with ``set_default_impl`` (e.g. launcher sets
"pallas" on TPU backends).
"""
from __future__ import annotations

import collections
import functools
import warnings

import jax
import jax.numpy as jnp

from . import ref
from .dequant_matmul import dequant_matmul_flat_pallas, matmul_quant_pallas
from .flash_attention import flash_attention_pallas
from .quant_blockwise import (dequantize_int8_pallas,
                              dequantize_int8_sum_pallas,
                              quantize_int8_pallas)
from .quant_int4 import (dequantize_int4_pallas, dequantize_int4_sum_pallas,
                         quantize_int4_pallas)
from .selective_scan import selective_scan_pallas

DEFAULT_BLOCK = 512
_DEFAULT_IMPL = "jnp"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("jnp", "pallas", "pallas_interpret"), impl
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


# ---------------------------------------------------------------------------
# Dispatch / fallback accounting (trace-time, python-side)
# ---------------------------------------------------------------------------
#
# Every hot-path dispatch increments a counter; shape-gate rejections land in
# ``<kernel>/fallback/<reason>`` and additionally emit ONE structured warning
# per (kernel, reason), so a silently degraded run (e.g. a seq length that
# pushes attention off the Pallas path) is visible in logs and in the
# obs/metrics layer (repro.obs reads ``dispatch_counters()``).

_DISPATCH_COUNTS: collections.Counter = collections.Counter()
_WARNED_FALLBACKS: set = set()


def record_dispatch(kernel: str, impl: str) -> None:
    _DISPATCH_COUNTS[f"{kernel}/{impl}"] += 1


def record_fallback(kernel: str, reason: str) -> None:
    _DISPATCH_COUNTS[f"{kernel}/fallback/{reason}"] += 1
    key = (kernel, reason)
    if key not in _WARNED_FALLBACKS:
        _WARNED_FALLBACKS.add(key)
        warnings.warn(
            f"repro.kernels.ops: {kernel} fell back to the chunked jnp path "
            f"(reason: {reason}); the Pallas kernel will not be used for "
            "this call shape. Warned once per reason.",
            stacklevel=3)


def dispatch_counters() -> dict[str, int]:
    """Trace-time dispatch/fallback counts, keyed ``kernel/impl`` or
    ``kernel/fallback/reason`` (obs surfaces these; tests reset them)."""
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counters() -> None:
    _DISPATCH_COUNTS.clear()
    _WARNED_FALLBACKS.clear()


# ---------------------------------------------------------------------------
# Fusion isolation (the bitwise-impl-swap contract's other half)
# ---------------------------------------------------------------------------
#
# XLA:CPU contracts mul+add chains into FMAs per fusion cluster, and cluster
# boundaries are context-sensitive: in interpret mode a pallas_call lowers to
# ordinary HLO that INLINES into the surrounding model graph, so swapping an
# impl between the jnp oracle and the interpret-mode kernel can perturb
# fusion decisions (hence FMA contraction, hence ULPs) in code *outside* the
# kernel — loss can stay bitwise while every gradient drifts 1e-8.
#
# Two mechanisms keep the swap bitwise:
#  1. ``optimization_barrier`` on every dispatched region's inputs and
#     outputs pins the boundary against HLO-pass reordering. This is NOT
#     sufficient on its own: XLA:CPU expands the barriers before the passes
#     that pick fusion clusters, so a structurally different region still
#     shifts neighbouring clusters.
#  2. The real fusion barrier is a REAL WHILE LOOP: XLA fusion never
#     crosses control flow, so when both impls of a dispatched region lower
#     to a genuine (trip-count >= 2) loop consuming the same interface
#     arrays, the surrounding graph compiles identically no matter what is
#     inside. Interpret-mode pallas_call lowers its grid to a lax.while_loop
#     over grid points; each jnp oracle therefore runs its sequential
#     dimension as a matching lax.fori_loop / lax.scan with every op — input
#     casts, tile dequant, quantize epilogues — INSIDE the loop body, and no
#     layout ops (transposes/moveaxis) at the loop interface. Both halves of
#     that rule were root-caused empirically: a trip-count-1 grid gets
#     inlined by the while-loop simplifier and its "near-identical" HLO
#     flips neighbouring FMA contraction as surrounding code evolves, and a
#     time-major moveaxis at the scan oracle's interface fused into producer
#     clusters and drifted *their* output 1 ULP per step (loss bitwise,
#     every gradient 1e-8 off). ``_loop_split`` picks the >= 2-step
#     contraction blocking for dequant_matmul / matmul_quant; the scan walks
#     time; attention remains a single full-extent block whose inlined HLO
#     is exactly identical between impls (its oracle replays the kernel op
#     for op with no interface layout ops).


def _isolated(fn, args):
    """Run fn behind optimization_barriers (fusion isolation, see above)."""
    args = jax.lax.optimization_barrier(args)
    return jax.lax.optimization_barrier(fn(*args))


def _isolated_vjp(oracle, res, g):
    """jax.vjp of the oracle at the saved primals, fusion-isolated so the
    identical bwd subgraph compiles identically under every impl."""
    res = jax.lax.optimization_barrier(res)
    g = jax.lax.optimization_barrier(g)
    _, vjp = jax.vjp(oracle, *res)
    return jax.lax.optimization_barrier(vjp(g))


def _blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    assert x.ndim == 1 and x.size % block == 0, (x.shape, block)
    return x.reshape(-1, block)


def quantize_int8(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x (size % block == 0) -> (int8 same shape, f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int8_ref(b)
    else:
        q, s = quantize_int8_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int8(q, scales, block: int = DEFAULT_BLOCK, dtype=jnp.float32,
                    impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = _blocks(q, block)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int8_ref(qb, sb, dtype)
    else:
        out = dequantize_int8_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def quantize_int4(x, block: int = DEFAULT_BLOCK, impl: str | None = None):
    """1-D x -> (uint8 packed (size//2,), f32 scales (size//block,))."""
    impl = impl or _DEFAULT_IMPL
    b = _blocks(x, block)
    if impl == "jnp":
        q, s = ref.quantize_int4_ref(b)
    else:
        q, s = quantize_int4_pallas(b, interpret=(impl == "pallas_interpret"))
    return q.reshape(-1), s.reshape(-1)


def dequantize_int4(packed, scales, block: int = DEFAULT_BLOCK,
                    dtype=jnp.float32, impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    qb = packed.reshape(-1, block // 2)
    sb = scales.reshape(-1, 1)
    if impl == "jnp":
        out = ref.dequantize_int4_ref(qb, sb, dtype)
    else:
        out = dequantize_int4_pallas(qb, sb, dtype,
                                     interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def dequantize_int4_sum(packed, scales, d: int, block: int = DEFAULT_BLOCK,
                        dtype=jnp.float32, impl: str | None = None):
    """Fused unpack + dequant + reduce of a2a-received INT4 chunks.

    packed: flat (d * n/2,) uint8 (d chunks, row-major); scales: flat
    (d * n/block,). Returns (n,) = sum over the d chunks, dequantized once
    — the receive-side half of the ZeRO++ quantized reduce-scatter in a
    single pass (no d dequantized copies round-tripping through HBM)."""
    impl = impl or _DEFAULT_IMPL
    qb = packed.reshape(d, -1, block // 2)
    sb = scales.reshape(d, -1, 1)
    if impl == "jnp":
        out = ref.dequantize_int4_sum_ref(qb, sb, dtype)
    else:
        out = dequantize_int4_sum_pallas(qb, sb, dtype,
                                         interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


def dequantize_int8_sum(q, scales, d: int, block: int = DEFAULT_BLOCK,
                        dtype=jnp.float32, impl: str | None = None):
    """INT8 variant of ``dequantize_int4_sum`` (bits=8 gradient RS)."""
    impl = impl or _DEFAULT_IMPL
    qb = q.reshape(d, -1, block)
    sb = scales.reshape(d, -1, 1)
    if impl == "jnp":
        out = ref.dequantize_int8_sum_ref(qb, sb, dtype)
    else:
        out = dequantize_int8_sum_pallas(qb, sb, dtype,
                                         interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Fused dequant x matmul (flat-shard scale layout)
# ---------------------------------------------------------------------------

def matmul_fusable(shape: tuple[int, ...], block: int) -> bool:
    """Can a weight of logical ``shape`` feed the fused dequant matmul?

    Requires >= 2 dims and the last (column) dim to be a whole number of
    quantization blocks, so the flat blocks tile each row of the (K, N)
    view exactly. Non-fusable leaves fall back to dequant -> matmul."""
    return len(shape) >= 2 and shape[-1] % block == 0


@functools.cache
def _divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>= 1)."""
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _contraction_tile(c_len: int, block: int, transpose: bool) -> int:
    """Contraction tile for the *compiled* kernel (one accumulation step per
    tile). Along K (transpose=False) any divisor works; along N
    (transpose=True) the tile must stay a whole number of scale blocks.
    Capped near 512 so compiled tiles stay VMEM-sized.

    The bitwise pair (jnp / pallas_interpret) does NOT use this: it uses
    ``_loop_split`` so both legs lower to a real (>= 2 step) while loop."""
    if transpose:
        return block * _divisor_leq(c_len // block, max(1, 512 // block))
    return _divisor_leq(c_len, 512)


@functools.cache
def _loop_split(n: int, granule: int = 1) -> int:
    """Contraction step for the bitwise pair: the largest granule-aligned
    divisor of ``n`` that yields >= 2 accumulation steps, so both the jnp
    oracle's fori_loop and the interpret kernel's grid loop survive to the
    backend as real while loops (the fusion barrier the bitwise contract
    rests on — see the fusion-isolation note at the top of this module).
    Falls back to a single full-extent step when n == granule (nothing to
    split); n % granule must be 0."""
    units = n // granule
    for p in range(2, units + 1):
        if units % p == 0:
            return granule * (units // p)
    return n


def dequant_matmul(x2, q_flat, scales, w_shape: tuple[int, int],
                   block: int = DEFAULT_BLOCK, *, transpose: bool = False,
                   dtype=jnp.bfloat16, impl: str | None = None):
    """y = x @ dequant(W) (or x @ dequant(W).T) without materializing W.

    ``q_flat``/``scales`` are the flat gathered INT8 buffer + per-block
    scales exactly as the collectives produce them (padded; only the first
    K*N / K*N//block entries are consumed). ``w_shape`` = (K, N) logical.
    x2: (M, K) (or (M, N) when transpose). Output rows are padded to the
    f32 sublane multiple internally and sliced back.

    impl="jnp" runs ``ref.dequant_matmul_flat_ref`` with the *same*
    contraction blocking and accumulation order as the kernel, so jnp and
    pallas_interpret results are bitwise identical (tests/test_kernels.py).
    The bitwise pair splits the contraction into >= 2 steps (``_loop_split``)
    so both the oracle's fori_loop and the interpret grid loop reach the
    backend as real while loops with identical operands — an opaque fusion
    boundary the surrounding graph compiles identically around (see the
    fusion-isolation note at the top of this module for why that matters).
    """
    impl = impl or _DEFAULT_IMPL
    record_dispatch("dequant_matmul", impl)
    k, n = w_shape
    assert n % block == 0, (w_shape, block)
    q2 = q_flat.reshape(-1)[: k * n].reshape(k, n)
    s2 = scales.reshape(-1)[: (k * n) // block].reshape(k, n // block)
    m = x2.shape[0]
    m_pad = padded_size(max(m, 1), 8)
    if m_pad != m:
        x2 = jnp.pad(x2, ((0, m_pad - m), (0, 0)))
    c_len = n if transpose else k
    out_dim = k if transpose else n
    bc_pair = _loop_split(c_len, block if transpose else 1)
    if impl == "jnp":
        def run(x2, q2, s2):
            return ref.dequant_matmul_flat_ref(x2, q2, s2, block, bc=bc_pair,
                                               transpose=transpose,
                                               dtype=dtype)
    elif impl == "pallas_interpret":
        # full row/col extents, grid (1, 1, c_len // bc_pair): only the
        # sequential contraction dim is blocked, >= 2 steps so the grid
        # loop is a real while loop (bitwise contract, §5)
        def run(x2, q2, s2):
            return dequant_matmul_flat_pallas(
                x2, q2, s2, block=block, bm=m_pad, bo=out_dim, bc=bc_pair,
                transpose=transpose, dtype=dtype, interpret=True)
    else:
        # compiled TPU: VMEM-sized tiles (the fused win is HBM traffic, so
        # the accumulation order may differ from the CPU oracle here — like
        # any other MXU-vs-CPU matmul)
        bc = _contraction_tile(c_len, block, transpose)
        bm = _divisor_leq(m_pad, 256)
        if transpose:
            bo = _divisor_leq(out_dim, 512)
        else:
            bo = block * _divisor_leq(out_dim // block, max(1, 512 // block))

        def run(x2, q2, s2):
            return dequant_matmul_flat_pallas(
                x2, q2, s2, block=block, bm=bm, bo=bo, bc=bc,
                transpose=transpose, dtype=dtype, interpret=False)
    out = _isolated(run, (x2, q2, s2))
    return out[:m] if m_pad != m else out


@functools.cache
def padded_size(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Attention / selective scan (first-class hot-path dispatch, DESIGN.md §5)
# ---------------------------------------------------------------------------
#
# Both kernels are exposed as per-static-config ``jax.custom_vjp`` functions
# (cached so jit tracing caches stay warm): the forward primal dispatches on
# impl, the backward is ALWAYS ``jax.vjp`` of the jnp oracle at the saved
# primals. Because every impl shares the oracle backward and the oracle
# mirrors the interpret-mode kernel body op for op, impl="jnp" and
# impl="pallas_interpret" agree bitwise through fwd AND bwd. The compiled
# TPU path ("pallas") carries no bitwise contract — its tiles are chosen
# for the MXU, like any other accelerator matmul.
#
# Both the primal and the shared backward run behind the fusion-isolation
# barriers (``_isolated`` / ``_isolated_vjp``, see the top of this module):
# the surrounding model graph sees the same opaque boundary under every
# impl, and the bwd's fusion depends only on its own (identical) structure.


def attention_fusable(sq: int, sk: int, d: int, dv: int, *,
                      softmax_scale=None,
                      q_offset=0) -> tuple[bool, str | None]:
    """Can this attention call use the Pallas kernel path?

    Returns (ok, reason): reason names the rejection for the fallback
    warning/counter — "mla_dv_mismatch" (MLA heads with dv != d),
    "custom_scale" (non-default softmax scale), "traced_q_offset"
    (q_offset is a tracer, the kernel needs it static), "seq_unaligned"
    (seq lengths not tileable to the 128-aligned kernel grid)."""
    if dv != d:
        return False, "mla_dv_mismatch"
    if softmax_scale is not None:
        return False, "custom_scale"
    if not isinstance(q_offset, int):
        return False, "traced_q_offset"
    if sq < 8 or sk < 8 or sq % min(128, sq) or sk % min(128, sk):
        return False, "seq_unaligned"
    return True, None


@functools.cache
def _attention_fn(causal: bool, window: int, q_offset: int, impl: str):
    def oracle(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset)

    if impl == "jnp":
        prim = oracle
    elif impl == "pallas_interpret":
        def prim(q, k, v):
            bh, sq, _ = q.shape
            # full extents, grid (1,1,1): the bitwise configuration
            return flash_attention_pallas(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                bb=bh, bq=sq, bk=k.shape[1], interpret=True)
    else:
        def prim(q, k, v):
            sq, sk = q.shape[1], k.shape[1]
            return flash_attention_pallas(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                bb=1, bq=min(128, sq), bk=min(128, sk), interpret=False)

    @jax.custom_vjp
    def fn(q, k, v):
        return _isolated(prim, (q, k, v))

    def fwd(q, k, v):
        return _isolated(prim, (q, k, v)), (q, k, v)

    def bwd(res, g):
        return _isolated_vjp(oracle, res, g)

    fn.defvjp(fwd, bwd)
    return fn


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, impl: str | None = None):
    """q (BH, Sq, D); k, v (BH, Sk, D) -> (BH, Sq, D), softmax attention.

    Caller (models/layers.py) folds heads/GQA and checks
    ``attention_fusable`` first; this dispatch assumes a fusable shape."""
    impl = impl or _DEFAULT_IMPL
    record_dispatch("attention", impl)
    return _attention_fn(causal, window, q_offset, impl)(q, k, v)


@functools.cache
def _selective_scan_fn(bs: int, impl: str):
    def oracle(dt, x, b, c, a, h0):
        return ref.selective_scan_ref(dt, x, b, c, a, h0, bs=bs)

    if impl == "jnp":
        prim = oracle
    elif impl == "pallas_interpret":
        def prim(dt, x, b, c, a, h0):
            batch, _, d = dt.shape
            return selective_scan_pallas(dt, x, b, c, a, h0, bb=batch,
                                         bd=d, bs=bs, interpret=True)
    else:
        def prim(dt, x, b, c, a, h0):
            return selective_scan_pallas(dt, x, b, c, a, h0, bs=bs,
                                         interpret=False)

    @jax.custom_vjp
    def fn(dt, x, b, c, a, h0):
        return _isolated(prim, (dt, x, b, c, a, h0))

    def fwd(dt, x, b, c, a, h0):
        return _isolated(prim, (dt, x, b, c, a, h0)), (dt, x, b, c, a, h0)

    def bwd(res, g):
        return _isolated_vjp(oracle, res, g)

    fn.defvjp(fwd, bwd)
    return fn


def selective_scan(dt, x, b, c, a, h0, *, impl: str | None = None):
    """Mamba-1 selective scan: dt, x (B, S, D); b, c (B, S, N); a (D, N);
    h0 (B, D, N) -> (y (B, S, D) f32, h_last (B, D, N) f32).

    Always fusable (the kernel grid divides any B/S/D); the time-block
    size is derived from S identically for every impl."""
    impl = impl or _DEFAULT_IMPL
    s = dt.shape[1]
    bs = min(256, s)
    while s % bs:
        bs //= 2
    record_dispatch("selective_scan", impl)
    return _selective_scan_fn(bs, impl)(dt, x, b, c, a, h0)


# ---------------------------------------------------------------------------
# Fused matmul x quantize (the weight-grad -> reduce-scatter seam)
# ---------------------------------------------------------------------------


def matmul_quant(x2, g2, block: int = DEFAULT_BLOCK, *, bits: int = 8,
                 pad_to: int | None = None, impl: str | None = None):
    """Wire-format weight grad: C = x2.T @ g2, block-quantized in the
    matmul epilogue (no dense f32 C round-trip through HBM).

    x2 (M, K); g2 (M, N); N % block == 0. Returns flat (q, scales) in the
    exact layout ``quantize_int{8,4}(C.reshape(-1))`` produces — INT8 q is
    (K*N,) int8, INT4 q is (K*N//2,) packed uint8 — optionally padded to
    ``pad_to`` logical elements with exact zero blocks (q=0 / 0x88,
    scale=1), matching the quantize-of-zero-padding the unfused path
    ships. Not differentiable: it lives inside core/linear.py's custom
    backward. impl="jnp" mirrors the kernel's blocked accumulation order,
    so jnp and pallas_interpret agree bitwise (tests/test_kernels.py)."""
    impl = impl or _DEFAULT_IMPL
    m, kk = x2.shape
    n = g2.shape[1]
    assert n % block == 0, (g2.shape, block)
    record_dispatch("matmul_quant", impl)
    bc_pair = _loop_split(m)
    if impl == "jnp":
        # >= 2 contraction steps mirroring the interpret grid loop (same
        # rationale as dequant_matmul: both legs lower to a real while
        # loop with identical operands — the bitwise contract, §5)
        def run(x2, g2):
            return ref.matmul_quant_ref(x2, g2, block, bc=bc_pair, bits=bits)
    elif impl == "pallas_interpret":
        def run(x2, g2):
            return matmul_quant_pallas(x2, g2, block=block, bits=bits,
                                       bk=kk, bn=n, bc=bc_pair, interpret=True)
    else:
        bc = _divisor_leq(m, 512)
        if bc < 8:
            bc = m  # awkward M (prime-ish): one full-extent step
        bk = _divisor_leq(kk, 256)
        bn = block * _divisor_leq(n // block, max(1, 512 // block))

        def run(x2, g2):
            return matmul_quant_pallas(x2, g2, block=block, bits=bits,
                                       bk=bk, bn=bn, bc=bc, interpret=False)
    q, s = _isolated(run, (x2, g2))
    qf, sf = q.reshape(-1), s.reshape(-1)
    logical = kk * n
    if pad_to is not None and pad_to != logical:
        assert pad_to > logical and (pad_to - logical) % block == 0, \
            (pad_to, logical, block)
        pad = pad_to - logical
        if bits == 4:
            qf = jnp.concatenate(
                [qf, jnp.full((pad // 2,), 0x88, jnp.uint8)])
        else:
            qf = jnp.concatenate([qf, jnp.zeros((pad,), jnp.int8)])
        sf = jnp.concatenate([sf, jnp.ones((pad // block,), jnp.float32)])
    return qf, sf
