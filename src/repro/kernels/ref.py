"""Pure-jnp oracles for the quantization kernels.

Block-based quantization (Dettmers et al. 2022, as used by ZeRO++): a flat
tensor is split into contiguous blocks of ``block_size`` elements; each block
gets an independent symmetric scale ``max(|x|)/qmax`` so outliers only poison
their own block. These functions are the numerical ground truth the Pallas
kernels are validated against, and the implementation the distributed engine
inlines on backends where Pallas is unavailable.

All functions operate on 2-D ``(num_blocks, block_size)`` views; ``ops.py``
owns the flatten/pad plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
# Symmetric signed 4-bit: values in [-7, 7] (avoid -8 so negation is closed).
INT4_QMAX = 7.0


def _scales(blocks: jnp.ndarray, qmax: float) -> jnp.ndarray:
    absmax = jnp.max(jnp.abs(blocks.astype(jnp.float32)), axis=-1, keepdims=True)
    # Avoid 0-scale for all-zero blocks; dequant then yields exact zeros.
    return jnp.where(absmax == 0.0, 1.0, absmax / qmax)


def quantize_int8_ref(blocks: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(nb, bs) float -> ((nb, bs) int8, (nb, 1) f32 scales)."""
    scales = _scales(blocks, INT8_QMAX)
    q = jnp.clip(jnp.round(blocks.astype(jnp.float32) / scales), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scales


def dequantize_int8_ref(q: jnp.ndarray, scales: jnp.ndarray,
                        dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scales).astype(dtype)


def quantize_int4_ref(blocks: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(nb, bs) float -> ((nb, bs//2) uint8 packed, (nb, 1) f32 scales).

    Two signed nibbles per byte: element 2i in the low nibble, 2i+1 in the
    high nibble, offset-encoded by +8 so the byte is unsigned.
    """
    scales = _scales(blocks, INT4_QMAX)
    q = jnp.clip(jnp.round(blocks.astype(jnp.float32) / scales), -INT4_QMAX, INT4_QMAX)
    q = q.astype(jnp.int32) + 8  # [1, 15]
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scales


def dequantize_int4_ref(packed: jnp.ndarray, scales: jnp.ndarray,
                        dtype=jnp.float32) -> jnp.ndarray:
    p = packed.astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = ((p >> 4) & 0xF) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return (out.astype(jnp.float32) * scales).astype(dtype)


def dequant_matmul_ref(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                       dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the fused INT8-dequant matmul: x @ dequant(q).

    ``q``: (K, N) int8 quantized along K in blocks; ``scales``: (K//bs, N)
    per-(block, column) scales (2-D blocking, one scale row per K-block).
    """
    kb = q.shape[0] // scales.shape[0]
    w = q.astype(jnp.float32) * jnp.repeat(scales, kb, axis=0)
    return (x.astype(jnp.float32) @ w).astype(dtype)


def dequant_w_flat_ref(q: jnp.ndarray, scales: jnp.ndarray,
                       block: int) -> jnp.ndarray:
    """Dequantize a (K, N) int8 weight whose blocks follow the *flat*
    (row-major) shard layout: scale ``scales[k, c]`` covers columns
    ``[c*block, (c+1)*block)`` of row ``k`` (requires N % block == 0).
    ``scales``: (K, N // block) f32. Returns f32 (K, N)."""
    k, n = q.shape
    s = jnp.broadcast_to(scales[:, :, None], (k, n // block, block))
    return q.astype(jnp.float32) * s.reshape(k, n)


def dequant_matmul_flat_ref(x: jnp.ndarray, q: jnp.ndarray,
                            scales: jnp.ndarray, block: int, *,
                            bc: int, transpose: bool = False,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the flat-layout fused dequant matmul, with the *same*
    contraction blocking as the Pallas kernel (``bc`` elements per step,
    sequential f32 accumulation) so ``impl="jnp"`` and
    ``impl="pallas_interpret"`` are bitwise identical.

    transpose=False: x (M, K) @ dequant(q (K, N)) -> (M, N)
    transpose=True : x (M, N) @ dequant(q (K, N)).T -> (M, K)
    """
    w = dequant_w_flat_ref(q, scales, block)
    xf = x.astype(jnp.float32)
    c_len = q.shape[0] if not transpose else q.shape[1]
    out_dim = q.shape[1] if not transpose else q.shape[0]
    acc = jnp.zeros((x.shape[0], out_dim), jnp.float32)
    for step in range(c_len // bc):
        sl = slice(step * bc, (step + 1) * bc)
        if transpose:
            acc = acc + jax.lax.dot_general(
                xf[:, sl], w[:, sl], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            acc = acc + jnp.dot(xf[:, sl], w[sl, :],
                                preferred_element_type=jnp.float32)
    return acc.astype(dtype)


def dequantize_int8_sum_ref(q: jnp.ndarray, scales: jnp.ndarray,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Fused dequant + reduce over the leading (group) axis.

    ``q``: (d, nb, bs) int8, ``scales``: (d, nb, 1). Accumulation is a
    *sequential* f32 loop over d (matching the Pallas kernel's order) so the
    jnp and interpret impls agree bitwise. Returns (nb, bs)."""
    acc = dequantize_int8_ref(q[0], scales[0], jnp.float32)
    for j in range(1, q.shape[0]):
        acc = acc + dequantize_int8_ref(q[j], scales[j], jnp.float32)
    return acc.astype(dtype)


def dequantize_int4_sum_ref(packed: jnp.ndarray, scales: jnp.ndarray,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Fused unpack + dequant + reduce over the leading (group) axis.

    ``packed``: (d, nb, bs//2) uint8, ``scales``: (d, nb, 1).
    Returns (nb, bs) = sum_j dequant(packed[j]), sequential f32 order."""
    acc = dequantize_int4_ref(packed[0], scales[0], jnp.float32)
    for j in range(1, packed.shape[0]):
        acc = acc + dequantize_int4_ref(packed[j], scales[j], jnp.float32)
    return acc.astype(dtype)
