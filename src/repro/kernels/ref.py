"""Pure-jnp oracles for the quantization kernels.

Block-based quantization (Dettmers et al. 2022, as used by ZeRO++): a flat
tensor is split into contiguous blocks of ``block_size`` elements; each block
gets an independent symmetric scale ``max(|x|)/qmax`` so outliers only poison
their own block. These functions are the numerical ground truth the Pallas
kernels are validated against, and the implementation the distributed engine
inlines on backends where Pallas is unavailable.

All functions operate on 2-D ``(num_blocks, block_size)`` views; ``ops.py``
owns the flatten/pad plumbing.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
# Symmetric signed 4-bit: values in [-7, 7] (avoid -8 so negation is closed).
INT4_QMAX = 7.0
# Must equal kernels/flash_attention.NEG_INF (pinned by the bitwise tests).
NEG_INF = -1e30


def _scales(blocks: jnp.ndarray, qmax: float) -> jnp.ndarray:
    absmax = jnp.max(jnp.abs(blocks.astype(jnp.float32)), axis=-1, keepdims=True)
    # Avoid 0-scale for all-zero blocks; dequant then yields exact zeros.
    return jnp.where(absmax == 0.0, 1.0, absmax / qmax)


def quantize_int8_ref(blocks: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(nb, bs) float -> ((nb, bs) int8, (nb, 1) f32 scales)."""
    scales = _scales(blocks, INT8_QMAX)
    q = jnp.clip(jnp.round(blocks.astype(jnp.float32) / scales), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scales


def dequantize_int8_ref(q: jnp.ndarray, scales: jnp.ndarray,
                        dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scales).astype(dtype)


def quantize_int4_ref(blocks: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(nb, bs) float -> ((nb, bs//2) uint8 packed, (nb, 1) f32 scales).

    Two signed nibbles per byte: element 2i in the low nibble, 2i+1 in the
    high nibble, offset-encoded by +8 so the byte is unsigned.
    """
    scales = _scales(blocks, INT4_QMAX)
    q = jnp.clip(jnp.round(blocks.astype(jnp.float32) / scales), -INT4_QMAX, INT4_QMAX)
    q = q.astype(jnp.int32) + 8  # [1, 15]
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scales


def dequantize_int4_ref(packed: jnp.ndarray, scales: jnp.ndarray,
                        dtype=jnp.float32) -> jnp.ndarray:
    p = packed.astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = ((p >> 4) & 0xF) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return (out.astype(jnp.float32) * scales).astype(dtype)


def dequant_matmul_ref(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                       dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the fused INT8-dequant matmul: x @ dequant(q).

    ``q``: (K, N) int8 quantized along K in blocks; ``scales``: (K//bs, N)
    per-(block, column) scales (2-D blocking, one scale row per K-block).
    """
    kb = q.shape[0] // scales.shape[0]
    w = q.astype(jnp.float32) * jnp.repeat(scales, kb, axis=0)
    return (x.astype(jnp.float32) @ w).astype(dtype)


def dequant_w_flat_ref(q: jnp.ndarray, scales: jnp.ndarray,
                       block: int) -> jnp.ndarray:
    """Dequantize a (K, N) int8 weight whose blocks follow the *flat*
    (row-major) shard layout: scale ``scales[k, c]`` covers columns
    ``[c*block, (c+1)*block)`` of row ``k`` (requires N % block == 0).
    ``scales``: (K, N // block) f32. Returns f32 (K, N)."""
    k, n = q.shape
    s = jnp.broadcast_to(scales[:, :, None], (k, n // block, block))
    return q.astype(jnp.float32) * s.reshape(k, n)


def dequant_matmul_flat_ref(x: jnp.ndarray, q: jnp.ndarray,
                            scales: jnp.ndarray, block: int, *,
                            bc: int, transpose: bool = False,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the flat-layout fused dequant matmul, with the *same*
    contraction blocking as the Pallas kernel (``bc`` elements per step,
    sequential f32 accumulation) so ``impl="jnp"`` and
    ``impl="pallas_interpret"`` are bitwise identical.

    The contraction runs as a ``lax.fori_loop`` whose body replays the
    kernel body op for op — casts, tile dequant, and the dot all live
    *inside* the loop. The loop matters structurally, not just
    numerically: a real (trip-count >= 2) while loop is an XLA fusion
    barrier, so the surrounding graph compiles identically whichever impl
    sits inside it, whereas unrolled/inlined bodies fuse into neighbours
    and perturb their FMA contraction (kernels/ops.py, Fusion isolation).

    transpose=False: x (M, K) @ dequant(q (K, N)) -> (M, N)
    transpose=True : x (M, N) @ dequant(q (K, N)).T -> (M, K)
    (transpose=True needs bc % block == 0, like the kernel.)
    """
    c_len = q.shape[0] if not transpose else q.shape[1]
    out_dim = q.shape[1] if not transpose else q.shape[0]
    assert c_len % bc == 0, (q.shape, bc, transpose)

    def step(i, acc):
        x_t = jax.lax.dynamic_slice_in_dim(x, i * bc, bc, 1)
        if transpose:
            q_t = jax.lax.dynamic_slice_in_dim(q, i * bc, bc, 1)
            s_t = jax.lax.dynamic_slice_in_dim(
                scales, i * (bc // block), bc // block, 1)
        else:
            q_t = jax.lax.dynamic_slice_in_dim(q, i * bc, bc, 0)
            s_t = jax.lax.dynamic_slice_in_dim(scales, i * bc, bc, 0)
        xf = x_t.astype(jnp.float32)
        qf = q_t.astype(jnp.float32)
        r, c = q_t.shape
        s3 = jnp.broadcast_to(s_t[:, :, None], (r, c // block, block))
        w = qf * s3.reshape(r, c)
        if transpose:
            return acc + jax.lax.dot_general(
                xf, w, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        return acc + jnp.dot(xf, w, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, c_len // bc, step,
                            jnp.zeros((x.shape[0], out_dim), jnp.float32))
    return acc.astype(dtype)


def dequantize_int8_sum_ref(q: jnp.ndarray, scales: jnp.ndarray,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Fused dequant + reduce over the leading (group) axis.

    ``q``: (d, nb, bs) int8, ``scales``: (d, nb, 1). Accumulation is a
    *sequential* f32 loop over d (matching the Pallas kernel's order) so the
    jnp and interpret impls agree bitwise. Returns (nb, bs)."""
    acc = dequantize_int8_ref(q[0], scales[0], jnp.float32)
    for j in range(1, q.shape[0]):
        acc = acc + dequantize_int8_ref(q[j], scales[j], jnp.float32)
    return acc.astype(dtype)


def dequantize_int4_sum_ref(packed: jnp.ndarray, scales: jnp.ndarray,
                            dtype=jnp.float32) -> jnp.ndarray:
    """Fused unpack + dequant + reduce over the leading (group) axis.

    ``packed``: (d, nb, bs//2) uint8, ``scales``: (d, nb, 1).
    Returns (nb, bs) = sum_j dequant(packed[j]), sequential f32 order."""
    acc = dequantize_int4_ref(packed[0], scales[0], jnp.float32)
    for j in range(1, packed.shape[0]):
        acc = acc + dequantize_int4_ref(packed[j], scales[j], jnp.float32)
    return acc.astype(dtype)


# ---------------------------------------------------------------------------
# Attention / selective-scan oracles (mirror the Pallas kernel blocking)
# ---------------------------------------------------------------------------
#
# These are the impl="jnp" halves of the ops.py dispatch for the hot-path
# compute kernels. Each one replays the *interpret-mode* kernel configuration
# (full batch/row extents, grid only over the sequential KV / time dimension)
# with a python loop of identically-shaped jnp ops in the same order, so
# impl="jnp" and impl="pallas_interpret" agree bitwise through fwd and bwd
# (DESIGN.md §5; same contract as dequant_matmul_flat_ref above).


def _attn_body(q, k, v, mask, scale):
    """The kernel's _compute for one full-extent KV block, op for op.

    With the single-block configuration the running state starts at its
    init values (acc=0, m=-inf, l=0), so the rescale combines are exact
    (0*corr + x == x in every rounding mode) and no FMA-contraction
    ambiguity can split jnp from pallas_interpret."""
    qf = q.astype(jnp.float32) * scale                 # (bh, sq, d)
    kf = k.astype(jnp.float32)                         # (bh, sk, d)
    s = jax.lax.dot_general(qf, kf, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = jnp.full(s.shape[:2] + (1,), NEG_INF, jnp.float32)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l = jnp.zeros_like(m_prev) * corr + jnp.sum(p, axis=-1, keepdims=True)
    vf = v.astype(jnp.float32)
    acc = jnp.zeros(s.shape[:2] + (vf.shape[-1],), jnp.float32) * corr + \
        jax.lax.dot_general(p.astype(vf.dtype), vf,
                            (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0):
    """q (BH, Sq, D); k, v (BH, Sk, D) -> (BH, Sq, D).

    Masked softmax attention replaying the interpret-mode kernel call
    (full extents, grid (1,1,1)) with identical op shapes — the dot runs
    on the full (Sq, Sk) extent because CPU GEMM reduction order can vary
    with tile shape, so the oracle must not re-chunk rows. The kernel's
    static block-skip predicate is evaluated in python (an entirely
    masked-out call returns zeros, like the kernel's never-written acc)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    last_q = q_offset + sq - 1
    run = True
    if causal:
        run = run and (0 <= last_q)
    if window:
        run = run and (sk - 1 > q_offset - window)
    if not run:
        return jnp.zeros_like(q)
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
    mask = jnp.ones((sq, sk), jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    if window:
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    return jax.checkpoint(_attn_body, static_argnums=(4,))(
        q, k, v, mask, scale)


def _scan_block(h, af, dt_b, x_b, b_b, c_b):
    """One time-block of the Mamba recurrence (inputs batch-major (B, bs, ·),
    the kernel's native layout); per-step ops identical to the kernel's
    fori_loop body. Time steps are read with dynamic slices rather than a
    time-major ``lax.scan`` so no transpose appears at the interface — a
    ``moveaxis`` here would fuse into neighbouring producer/consumer fusions
    and perturb their FMA contraction on CPU, breaking the cross-impl
    bitwise contract outside this op (see kernels/ops.py, Fusion
    isolation)."""
    def step(t, carry):
        h, y = carry
        dtf = dt_b[:, t].astype(jnp.float32)           # (B, D)
        xf = x_b[:, t].astype(jnp.float32)
        bf = b_b[:, t].astype(jnp.float32)             # (B, N)
        cf = c_b[:, t].astype(jnp.float32)
        da = jnp.exp(dtf[..., None] * af[None])        # (B, D, N)
        dbx = (dtf * xf)[..., None] * bf[:, None, :]
        h = da * h + dbx
        yt = jnp.sum(h * cf[:, None, :], axis=-1)
        y = jax.lax.dynamic_update_slice_in_dim(y, yt[:, None], t, axis=1)
        return h, y
    y0 = jnp.zeros(dt_b.shape, jnp.float32)
    return jax.lax.fori_loop(0, dt_b.shape[1], step, (h, y0))


def selective_scan_ref(dt, x, b, c, a, h0, *, bs: int = 256):
    """dt, x (B, S, D); b, c (B, S, N); a (D, N); h0 (B, D, N) ->
    (y (B, S, D) f32, h_last (B, D, N) f32).

    Sequential recurrence in time order, chunked into ``bs``-step blocks
    (rematerialized for bwd memory). Blocking along B/D/S never reorders
    the arithmetic — per element it is the same multiply/add/N-reduction
    chain — so this is bitwise-equal to the kernel for *any* bb/bd/bs."""
    batch, s, d = dt.shape
    bs = min(bs, s)
    while s % bs:
        bs //= 2
    af = a.astype(jnp.float32)
    h = h0.astype(jnp.float32)
    blk = jax.checkpoint(_scan_block)
    ys = []
    for s_i in range(s // bs):
        sl = slice(s_i * bs, (s_i + 1) * bs)
        h, y = blk(h, af, dt[:, sl], x[:, sl], b[:, sl], c[:, sl])
        ys.append(y)
    y = ys[0] if len(ys) == 1 else jnp.concatenate(ys, axis=1)
    return y.astype(jnp.float32), h


def matmul_quant_ref(x, g, block: int, *, bc: int, bits: int = 8):
    """Fused grad-matmul + block-quantize oracle: C = x.T @ g, quantized.

    x (M, K); g (M, N) -> (q (K, N) int8 | (K, N//2) uint8 packed,
    scales (K, N//block) f32), N % block == 0. The contraction over M runs
    in ``bc``-row steps with sequential f32 accumulation (kernel order);
    the epilogue is the kernel's block-quantize on the row-major
    (·, block) view — the wire layout core/linear.py ships to the
    reduce-scatter.

    Structured as a single ``lax.fori_loop`` with the casts, the dot, and
    the quantize epilogue all *inside* the body (the epilogue re-runs on
    the running accumulator each step; only the last step's values
    survive). A real while loop is an XLA fusion barrier, which keeps the
    surrounding graph's compilation independent of which impl produced
    these bytes (kernels/ops.py, Fusion isolation)."""
    m, kk = x.shape
    n = g.shape[1]
    assert m % bc == 0 and n % block == 0, (x.shape, g.shape, bc, block)
    qmax = INT4_QMAX if bits == 4 else INT8_QMAX

    def step(i, carry):
        acc, _, _ = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, i * bc, bc, 0)
        g_t = jax.lax.dynamic_slice_in_dim(g, i * bc, bc, 0)
        acc = acc + jax.lax.dot_general(
            x_t.astype(jnp.float32), g_t.astype(jnp.float32),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        a3 = acc.reshape(kk, n // block, block)
        absmax = jnp.max(jnp.abs(a3), axis=-1, keepdims=True)
        # multiply by the reciprocal constant instead of dividing: XLA
        # folds `x / const` into `x * (1/const)` inside jit but not in
        # eager mode, so a literal division would round differently per
        # context and break the bitwise contract (the kernel epilogue
        # uses the same expression)
        scales = jnp.where(absmax == 0.0, 1.0, absmax * (1.0 / qmax))
        qv = jnp.clip(jnp.round(a3 / scales), -qmax, qmax)
        if bits == 4:
            pairs = (qv.astype(jnp.int32) + 8).reshape(kk, n // 2, 2)
            qb = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)
        else:
            qb = qv.reshape(kk, n).astype(jnp.int8)
        return acc, qb, scales.reshape(kk, n // block)

    q0 = (jnp.zeros((kk, n // 2), jnp.uint8) if bits == 4
          else jnp.zeros((kk, n), jnp.int8))
    _, qb, scales = jax.lax.fori_loop(
        0, m // bc, step,
        (jnp.zeros((kk, n), jnp.float32), q0,
         jnp.zeros((kk, n // block), jnp.float32)))
    return qb, scales
