"""Pallas TPU kernels: INT4 block quantize (nibble-packed) / dequantize.

Used for the all-to-all based gradient reduce-scatter (ZeRO++ §"quantized
gradients"): FP16/FP32 gradient blocks are quantized to 4 bits, packed two
nibbles per uint8, exchanged, and dequantized exactly once on the receiver.

TPU note: there is no native int4 vector type on the VPU, so packing is done
with uint8 integer arithmetic on even/odd element pairs. The (nb, bs) tile is
viewed as (..., bs//2, 2); low nibble = even element, high nibble = odd.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT4_QMAX = 7.0
ROWS_PER_TILE = 8


def _quant_int4_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / INT4_QMAX)
    q = jnp.clip(jnp.round(x / scale), -INT4_QMAX, INT4_QMAX).astype(jnp.int32) + 8
    r, c = x.shape
    q = q.reshape(r, c // 2, 2)
    packed = q[..., 0] | (q[..., 1] << 4)
    q_ref[...] = packed.astype(jnp.uint8)
    s_ref[...] = scale


def _dequant_int4_kernel(q_ref, s_ref, o_ref, *, dtype):
    p = q_ref[...].astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = ((p >> 4) & 0xF) - 8
    r, ch = p.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(r, ch * 2).astype(jnp.float32)
    o_ref[...] = (out * s_ref[...]).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int4_pallas(blocks: jnp.ndarray, *, interpret: bool = False):
    """(nb, bs) -> ((nb, bs//2) uint8 packed, (nb, 1) f32). bs % 256 == 0."""
    nb, bs = blocks.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        _quant_int4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, bs // 2), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs // 2), jnp.uint8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(blocks)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_int4_pallas(packed: jnp.ndarray, scales: jnp.ndarray,
                           dtype=jnp.float32, *, interpret: bool = False):
    nb, half = packed.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_int4_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, half), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, half * 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, half * 2), dtype),
        interpret=interpret,
    )(packed, scales)


def _dequant_int4_sum_kernel(q_ref, s_ref, o_ref, *, d, dtype):
    # fused unpack + dequant + reduce: one pass over the a2a-received chunks
    # (the unfused tail would write d dequantized copies back to HBM and
    # re-read them for the reduction)
    def chunk(j):
        p = q_ref[j].astype(jnp.int32)
        lo = (p & 0xF) - 8
        hi = ((p >> 4) & 0xF) - 8
        r, ch = p.shape
        out = jnp.stack([lo, hi], axis=-1).reshape(r, ch * 2).astype(jnp.float32)
        return out * s_ref[j]

    acc = chunk(0)
    for j in range(1, d):
        acc = acc + chunk(j)
    o_ref[...] = acc.astype(dtype)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_int4_sum_pallas(packed: jnp.ndarray, scales: jnp.ndarray,
                               dtype=jnp.float32, *, interpret: bool = False):
    """Fused unpack + dequant + reduce over the leading (group) axis.

    packed: (d, nb, bs//2) uint8; scales: (d, nb, 1) f32 -> (nb, bs)
    = sum_j dequant(packed[j]). Sequential f32 accumulation over j, same
    order as ``ref.dequantize_int4_sum_ref`` (bitwise in interpret mode)."""
    d, nb, half = packed.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_int4_sum_kernel, d=d, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, rows, half), lambda i: (0, i, 0)),
            pl.BlockSpec((d, rows, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, half * 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, half * 2), dtype),
        interpret=interpret,
    )(packed, scales)
