"""Pallas TPU selective-scan kernel (Mamba-1 recurrence; beyond-paper).

The jnp reference (models/ssm.py) materializes the discretized
(B, S, d_inner, d_state) tensors dA and dB·x in HBM — 2·N·itemsize times the
size of the actual inputs (N=16 state, f32: ~128 bytes/element-step), which
makes falcon-mamba train_4k the second-most memory-bound baseline in the
roofline table. The CUDA kernel the paper's ecosystem uses solves this with
a warp-sequential scan; the TPU adaptation instead keeps the running state
``h (bd, N)`` in VMEM scratch and walks the time dimension with a
``fori_loop`` of VPU vector ops, so HBM sees only dt/x/B/C in and y out.

Layout: grid (B, D/bd, S/bs), time innermost so the state scratch carries
across sequence blocks. dt comes pre-softplus'd + bias'd; A = -exp(A_log)
is passed dense (D, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref,
                 hout_ref, h_ref, *, bs, s_steps):
    s_i = pl.program_id(2)

    @pl.when(s_i == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    a = a_ref[...].astype(jnp.float32)                 # (bd, N)

    def step(t, _):
        dt = dt_ref[:, t].astype(jnp.float32)          # (bb, bd)
        xv = x_ref[:, t].astype(jnp.float32)           # (bb, bd)
        bv = b_ref[:, t].astype(jnp.float32)           # (bb, N)
        cv = c_ref[:, t].astype(jnp.float32)           # (bb, N)
        da = jnp.exp(dt[..., None] * a[None])          # (bb, bd, N)
        dbx = (dt * xv)[..., None] * bv[:, None, :]
        h = da * h_ref[...] + dbx
        h_ref[...] = h
        y_ref[:, t] = jnp.sum(h * cv[:, None, :], axis=-1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, step, 0)

    @pl.when(s_i == s_steps - 1)
    def _done():
        hout_ref[...] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "bd", "bs", "interpret"))
def selective_scan_pallas(dt, x, b, c, a, h0, *, bb: int = 1, bd: int = 512,
                          bs: int = 256, interpret: bool = False):
    """dt, x: (B, S, D); b, c: (B, S, N); a: (D, N); h0: (B, D, N).

    Returns (y (B, S, D) f32, h_last (B, D, N) f32). B % bb == 0; bd/bs are
    clamped to divisors of D/S. ``bb`` blocks the batch dim: compiled TPU
    runs bb=1 tiles, the interpret/bitwise configuration runs full extents
    (bb=B, bd=D) so the grid walks only the sequential time dimension —
    the blocking the jnp oracle (kernels/ref.selective_scan_ref) mirrors.
    """
    batch, s, d = dt.shape
    n = b.shape[-1]
    assert batch % bb == 0, (dt.shape, bb)
    bd = min(bd, d)
    while d % bd:
        bd //= 2
    bs = min(bs, s)
    while s % bs:
        bs //= 2
    grid = (batch // bb, d // bd, s // bs)
    kernel = functools.partial(_scan_kernel, bs=bs, s_steps=s // bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bd), lambda i, j, k: (i, k, j)),  # dt
            pl.BlockSpec((bb, bs, bd), lambda i, j, k: (i, k, j)),  # x
            pl.BlockSpec((bb, bs, n), lambda i, j, k: (i, k, 0)),   # B
            pl.BlockSpec((bb, bs, n), lambda i, j, k: (i, k, 0)),   # C
            pl.BlockSpec((bd, n), lambda i, j, k: (j, 0)),          # A
            pl.BlockSpec((bb, bd, n), lambda i, j, k: (i, j, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((bb, bs, bd), lambda i, j, k: (i, k, j)),  # y
            pl.BlockSpec((bb, bd, n), lambda i, j, k: (i, j, 0)),   # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, s, d), jnp.float32),
            jax.ShapeDtypeStruct((batch, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bd, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, b, c, a, h0)
