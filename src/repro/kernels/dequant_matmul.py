"""Pallas TPU kernel: fused INT8-dequant x matmul (beyond-paper optimization).

The paper dequantizes gathered weights to FP16 in HBM and then runs the
matmul, paying a full extra read+write of the weight matrix. On TPU the
dequant is essentially free if fused into the matmul's VMEM pipeline: each
(bk, bn) int8 weight tile is scaled to f32 *in VMEM* right before hitting the
MXU, so HBM only ever sees 1 byte/param. This kernel implements
``x @ dequant(q, scales)`` with K-blocked accumulation.

Tiling: grid (M/bm, N/bn, K/bk); the scale blocking along K must equal the
kernel's K tile (one scale row per K tile) so scaling is a broadcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_matmul_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32) * s_ref[...]  # (bk, bn) * (1, bn)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "dtype", "interpret"))
def dequant_matmul_pallas(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                          *, bm: int = 128, bn: int = 128, bk: int = 128,
                          dtype=jnp.float32, interpret: bool = False):
    """x: (M, K); q: (K, N) int8; scales: (K // bk, N) f32 -> (M, N).

    M % bm == K % bk == N % bn == 0 and scales.shape[0] == K // bk.
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scales.shape == (k // bk, n), (x.shape, q.shape, scales.shape)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scales)


# ---------------------------------------------------------------------------
# Flat-shard-layout variant: the hot-path kernel behind ``core/linear.py``.
#
# The ZeRO engine gathers weights as a *flat* INT8 shard with one f32 scale
# per ``block`` consecutive flat elements (DeepSpeed layout). Viewed as the
# logical (K, N) weight (row-major, N % block == 0), the scale for element
# (k, j) is ``scales[k, j // block]`` — scales block along columns *within*
# a row, not down a column. This kernel consumes that layout directly, so
# the gathered INT8 buffer feeds the MXU without ever materializing the
# dequantized weight in HBM, and emits bf16 (or any requested dtype).
#
# Both matmul orientations are supported because the backward pass needs
# g @ W.T against the re-gathered INT8 secondary partition:
#   transpose=False: x (M, K) @ dequant(q (K, N))    -> (M, N)
#   transpose=True : x (M, N) @ dequant(q (K, N)).T  -> (M, K)
# In both cases the q/scales tile layout is identical ((rows, cols) with
# scales (rows, cols//block)); only the grid index maps and the dot_general
# contraction dims differ.
# ---------------------------------------------------------------------------


def _dequant_mm_flat_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *,
                            block, k_steps, transpose):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    r, c = q.shape
    s = jnp.broadcast_to(s_ref[...][:, :, None], (r, c // block, block))
    w = q * s.reshape(r, c)
    if transpose:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bm", "bo", "bc",
                                             "transpose", "dtype",
                                             "interpret"))
def dequant_matmul_flat_pallas(x: jnp.ndarray, q: jnp.ndarray,
                               scales: jnp.ndarray, *, block: int,
                               bm: int, bo: int, bc: int,
                               transpose: bool = False,
                               dtype=jnp.bfloat16, interpret: bool = False):
    """Fused dequant x matmul on the flat-shard scale layout.

    ``q``: (K, N) int8, ``scales``: (K, N // block) f32 (see module note).
    transpose=False: x (M, K) -> (M, N);  transpose=True: x (M, N) -> (M, K).
    ``bm``/``bo``/``bc`` tile M / the output dim / the contraction dim.
    Scale tiles must stay block-aligned: bc % block == 0 when the
    contraction runs along N (transpose=True), bo % block == 0 otherwise.
    """
    k, n = q.shape
    m = x.shape[0]
    assert scales.shape == (k, n // block), (q.shape, scales.shape, block)
    c_len, out_dim = (n, k) if transpose else (k, n)
    assert x.shape == (m, c_len) and m % bm == 0 and out_dim % bo == 0 \
        and c_len % bc == 0, (x.shape, q.shape, bm, bo, bc)
    k_steps = c_len // bc
    grid = (m // bm, out_dim // bo, k_steps)
    if transpose:
        assert bc % block == 0, (bc, block)
        q_spec = pl.BlockSpec((bo, bc), lambda i, j, kk: (j, kk))
        s_spec = pl.BlockSpec((bo, bc // block), lambda i, j, kk: (j, kk))
    else:
        assert bo % block == 0, (bo, block)
        q_spec = pl.BlockSpec((bc, bo), lambda i, j, kk: (kk, j))
        s_spec = pl.BlockSpec((bc, bo // block), lambda i, j, kk: (kk, j))
    return pl.pallas_call(
        functools.partial(_dequant_mm_flat_kernel, block=block,
                          k_steps=k_steps, transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i, j, kk: (i, kk)),
            q_spec,
            s_spec,
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, out_dim), dtype),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32)],
        interpret=interpret,
    )(x, q, scales)


# ---------------------------------------------------------------------------
# Fused matmul x quantize: the weight-grad producer (beyond-paper).
#
# The unfused gradient path materializes the dense f32 dW = x.T @ g in HBM,
# then re-reads it to block-quantize for the a2a reduce-scatter — a full
# extra write+read of 4 bytes/param on the hottest backward seam. Here the
# quantize runs in the matmul's epilogue instead: the f32 accumulator tile
# is still in VMEM when the last contraction step finishes, so HBM only
# ever sees the INT8 (or packed INT4) wire bytes + per-block scales that
# the collective actually ships. Scale blocks follow the flat shard layout
# (scales[k, j // block], N % block == 0), i.e. the output *is* the wire
# format core/linear.py previously produced via quantize_int{8,4}.
# ---------------------------------------------------------------------------

INT8_QMAX = 127.0
INT4_QMAX = 7.0


def _matmul_quant_kernel(x_ref, g_ref, q_ref, s_ref, acc_ref, *,
                         block, bits, m_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                 # (bc, bk)
    g = g_ref[...].astype(jnp.float32)                 # (bc, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, g, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == m_steps - 1)
    def _done():
        acc = acc_ref[...]
        r, c = acc.shape
        qmax = INT4_QMAX if bits == 4 else INT8_QMAX
        a3 = acc.reshape(r, c // block, block)
        absmax = jnp.max(jnp.abs(a3), axis=-1, keepdims=True)
        # reciprocal-multiply, not division: jit folds `/const` into
        # `*(1/const)` but eager does not — ref.matmul_quant_ref matches
        scales = jnp.where(absmax == 0.0, 1.0, absmax * (1.0 / qmax))
        qv = jnp.clip(jnp.round(a3 / scales), -qmax, qmax)
        s_ref[...] = scales.reshape(r, c // block)
        if bits == 4:
            pairs = (qv.astype(jnp.int32) + 8).reshape(r, c // 2, 2)
            q_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)
        else:
            q_ref[...] = qv.reshape(r, c).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "bits", "bk", "bn",
                                             "bc", "interpret"))
def matmul_quant_pallas(x: jnp.ndarray, g: jnp.ndarray, *, block: int,
                        bits: int = 8, bk: int, bn: int, bc: int,
                        interpret: bool = False):
    """Fused C = x.T @ g + block-quantize epilogue.

    x: (M, K); g: (M, N); N % block == 0, M % bc == 0. Returns
    (q (K, N) int8 | (K, N//2) uint8, scales (K, N//block) f32) in the
    flat-shard wire layout. Grid (K/bk, N/bn, M/bc) with the contraction
    innermost; the epilogue quantizes each output tile at the last step,
    mirrored op-for-op by ref.matmul_quant_ref (bitwise with bk=K, bn=N).
    ``bn`` must stay a whole number of scale blocks (and even for INT4).
    """
    m, k = x.shape
    m2, n = g.shape
    assert m == m2 and n % block == 0, (x.shape, g.shape, block)
    assert k % bk == 0 and n % bn == 0 and m % bc == 0 and bn % block == 0, \
        (x.shape, g.shape, bk, bn, bc, block)
    m_steps = m // bc
    grid = (k // bk, n // bn, m_steps)
    if bits == 4:
        q_shape = jax.ShapeDtypeStruct((k, n // 2), jnp.uint8)
        q_spec = pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (i, j))
    else:
        q_shape = jax.ShapeDtypeStruct((k, n), jnp.int8)
        q_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (i, j))
    return pl.pallas_call(
        functools.partial(_matmul_quant_kernel, block=block, bits=bits,
                          m_steps=m_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bk), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bc, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            q_spec,
            pl.BlockSpec((bk, bn // block), lambda i, j, kk: (i, j)),
        ],
        out_shape=[q_shape,
                   jax.ShapeDtypeStruct((k, n // block), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g)
