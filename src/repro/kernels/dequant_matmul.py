"""Pallas TPU kernel: fused INT8-dequant x matmul (beyond-paper optimization).

The paper dequantizes gathered weights to FP16 in HBM and then runs the
matmul, paying a full extra read+write of the weight matrix. On TPU the
dequant is essentially free if fused into the matmul's VMEM pipeline: each
(bk, bn) int8 weight tile is scaled to f32 *in VMEM* right before hitting the
MXU, so HBM only ever sees 1 byte/param. This kernel implements
``x @ dequant(q, scales)`` with K-blocked accumulation.

Tiling: grid (M/bm, N/bn, K/bk); the scale blocking along K must equal the
kernel's K tile (one scale row per K tile) so scaling is a broadcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_matmul_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32) * s_ref[...]  # (bk, bn) * (1, bn)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "dtype", "interpret"))
def dequant_matmul_pallas(x: jnp.ndarray, q: jnp.ndarray, scales: jnp.ndarray,
                          *, bm: int = 128, bn: int = 128, bk: int = 128,
                          dtype=jnp.float32, interpret: bool = False):
    """x: (M, K); q: (K, N) int8; scales: (K // bk, N) f32 -> (M, N).

    M % bm == K % bk == N % bn == 0 and scales.shape[0] == K // bk.
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scales.shape == (k // bk, n), (x.shape, q.shape, scales.shape)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scales)
