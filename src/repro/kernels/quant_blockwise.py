"""Pallas TPU kernels: INT8 block-based quantize / dequantize.

TPU adaptation of the ZeRO++ CUDA quantization kernels. The GPU version
assigns one warp per block and uses warp shuffles for the absmax reduction;
on TPU the natural unit is a VMEM tile processed by the VPU, so we tile the
``(num_blocks, block_size)`` view into ``(ROWS_PER_TILE, block_size)`` VMEM
blocks and let each grid step reduce its rows vectorized. ``block_size`` is
kept a multiple of 128 (lane width); the row tile is ``gcd(nb, 8)`` —
8 sublanes when the block count allows, degrading (never truncating) for
odd block counts so every row is written.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_QMAX = 127.0
ROWS_PER_TILE = 8


def _quant_int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / INT8_QMAX)
    q = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_int8_kernel(q_ref, s_ref, o_ref, *, dtype):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8_pallas(blocks: jnp.ndarray, *, interpret: bool = False):
    """(nb, bs) -> ((nb, bs) int8, (nb, 1) f32). bs % 128 == 0; the row tile
    is gcd(nb, 8) so every block row is covered for any nb."""
    nb, bs = blocks.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        _quant_int8_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, bs), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(blocks)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_int8_pallas(q: jnp.ndarray, scales: jnp.ndarray,
                           dtype=jnp.float32, *, interpret: bool = False):
    nb, bs = q.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_int8_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, bs), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), dtype),
        interpret=interpret,
    )(q, scales)


def _dequant_int8_sum_kernel(q_ref, s_ref, o_ref, *, d, dtype):
    # unrolled sequential accumulation over the (static, small) group axis:
    # one pass over the received chunks instead of d dequant round-trips
    # through HBM followed by a separate reduction
    acc = q_ref[0].astype(jnp.float32) * s_ref[0]
    for j in range(1, d):
        acc = acc + q_ref[j].astype(jnp.float32) * s_ref[j]
    o_ref[...] = acc.astype(dtype)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_int8_sum_pallas(q: jnp.ndarray, scales: jnp.ndarray,
                               dtype=jnp.float32, *, interpret: bool = False):
    """Fused dequant + reduce of a2a-received chunks (ZeRO++ grad RS tail).

    q: (d, nb, bs) int8; scales: (d, nb, 1) f32 -> (nb, bs)
    = sum_j dequant(q[j]). Sequential f32 accumulation over j, same order
    as ``ref.dequantize_int8_sum_ref`` (bitwise in interpret mode)."""
    d, nb, bs = q.shape
    rows = math.gcd(nb, ROWS_PER_TILE)
    grid = (nb // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_int8_sum_kernel, d=d, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, rows, bs), lambda i: (0, i, 0)),
            pl.BlockSpec((d, rows, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), dtype),
        interpret=interpret,
    )(q, scales)
