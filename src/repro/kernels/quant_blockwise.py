"""Pallas TPU kernels: INT8 block-based quantize / dequantize.

TPU adaptation of the ZeRO++ CUDA quantization kernels. The GPU version
assigns one warp per block and uses warp shuffles for the absmax reduction;
on TPU the natural unit is a VMEM tile processed by the VPU, so we tile the
``(num_blocks, block_size)`` view into ``(ROWS_PER_TILE, block_size)`` VMEM
blocks and let each grid step reduce its rows vectorized. ``block_size`` is
kept a multiple of 128 (lane width) and rows a multiple of 8 (sublanes) so
tiles are layout-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_QMAX = 127.0
ROWS_PER_TILE = 8


def _quant_int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / INT8_QMAX)
    q = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_int8_kernel(q_ref, s_ref, o_ref, *, dtype):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8_pallas(blocks: jnp.ndarray, *, interpret: bool = False):
    """(nb, bs) -> ((nb, bs) int8, (nb, 1) f32). nb % 8 == 0, bs % 128 == 0."""
    nb, bs = blocks.shape
    rows = min(ROWS_PER_TILE, nb)
    grid = (nb // rows,)
    return pl.pallas_call(
        _quant_int8_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, bs), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(blocks)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_int8_pallas(q: jnp.ndarray, scales: jnp.ndarray,
                           dtype=jnp.float32, *, interpret: bool = False):
    nb, bs = q.shape
    rows = min(ROWS_PER_TILE, nb)
    grid = (nb // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_int8_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, bs), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), dtype),
        interpret=interpret,
    )(q, scales)
