"""Pallas TPU flash attention (beyond-paper optimization, see EXPERIMENTS.md
§Perf).

The jnp reference attention (models/layers.flash_attention) streams its
(bq, bk) probability tiles through HBM — on the CPU dry-run census this is
the dominant memory-term contributor for every train/prefill shape. This
kernel keeps the running-softmax state (acc, m, l) in VMEM scratch across the
KV-block grid dimension, so HBM traffic collapses to q + k + v + out.

Layout: inputs are (BH, S, D) with heads folded into the leading dim (GQA
k/v are repeated by the ops.py wrapper — on TPU the repeat is a broadcast
the compiler keeps virtual). Grid: (BH, num_q_blocks, num_kv_blocks), KV
innermost so scratch carries across it. Causal and sliding-window masking
are applied from absolute positions (q_offset supports decode/sequence-
parallel callers). MXU-aligned tiles: bq, bk multiples of 128 recommended;
D padded to a lane multiple by the wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, q_offset, bq, bk, kv_steps):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_i = pl.program_id(1)
    q_pos = q_offset + q_i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # skip kv blocks that are entirely masked out (causal / window)
    first_q = q_offset + q_i * bq
    last_q = first_q + bq - 1
    first_k = kv_i * bk
    last_k = first_k + bk - 1
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, first_k <= last_q)
    if window:
        run = jnp.logical_and(run, last_k > first_q - window)

    @pl.when(run)
    def _compute():
        # scale is folded into q *before* the dot: a post-dot multiply would
        # sit next to the `s - m_new` subtract and XLA is free to contract
        # mul+add chains into FMAs differently per context, breaking the
        # jnp-vs-interpret bitwise contract
        q = q_ref[...].astype(jnp.float32) * scale    # (bb, bq, d)
        k = k_ref[...].astype(jnp.float32)            # (bb, bk, d)
        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == kv_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "bb", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           q_offset: int = 0, bb: int = 1, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q (BH, Sq, D); k, v (BH, Sk, D) -> (BH, Sq, D).

    BH % bb == Sq % bq == Sk % bk == 0 (ops.py gates); D lane-aligned.
    ``bb`` blocks the folded batch*heads dim: compiled TPU runs bb=1 tiles
    with the multi-block online softmax; the interpret/bitwise configuration
    runs FULL extents (bb=BH, bq=Sq, bk=Sk, grid (1,1,1)) — with a single
    KV block the zero-initialized rescale combines (`acc*corr + pv`,
    `l*corr + Σp`) are exact regardless of FMA contraction, which is what
    lets the jnp oracle (kernels/ref.flash_attention_ref) mirror the body
    bitwise. Multi-block accumulation is validated by allclose tests only.
    """
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    assert bh % bb == 0 and sq % bq == 0 and sk % bk == 0, \
        (q.shape, k.shape, bb, bq, bk)
    scale = 1.0 / math.sqrt(d)
    grid = (bh // bb, sq // bq, sk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, kv_steps=sk // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((bb, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((bb, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bb, bq, d), jnp.float32),
            pltpu.VMEM((bb, bq, 1), jnp.float32),
            pltpu.VMEM((bb, bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
