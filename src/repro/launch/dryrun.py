"""Multi-pod dry-run (deliverable (e)): lower + compile every
(architecture x input shape x mesh x scheme) combination with
ShapeDtypeStruct stand-ins — no device allocation — and record
memory_analysis / cost_analysis / the loop-aware collective census.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch gemma3-1b --shape train_4k --mesh prod --scheme zero_topo

    --arch all --shape all --mesh prod,prod_mp   # the full 40-combo sweep

Exit code != 0 if any combination fails to lower/compile: failures here
(sharding mismatch, OOM at compile, unsupported collective) are bugs.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.engine import TrainHparams, ZeroEngine
from ..models.config import SHAPES, shape_supported
from ..models.registry import (batch_axes, build_model, data_axes, get_arch,
                               list_archs)
from ..serve.engine import ServeEngine, make_serve_config
from . import hlo, roofline
from .distributed import add_cli_args, from_args, initialize
from .mesh import make_production_mesh, make_topo_mesh, scheme_config

MESHES = {
    "prod": lambda: make_production_mesh(),
    "prod_mp": lambda: make_production_mesh(multi_pod=True),
    "topo": lambda: make_topo_mesh(),
    "topo_mp": lambda: make_topo_mesh(multi_pod=True),
}


def train_batch_candidates(mesh):
    """Batch-shard axes for training: every non-pod axis (ZeRO = pure DP),
    pod last (replicated unless batch demands it)."""
    non_pod = tuple(a for a in mesh.axis_names if a != "pod")
    return non_pod


def lower_combo(arch_name: str, shape_name: str, mesh_name: str,
                scheme: str, quant_block: int = 2048,
                serve_mode: str = "zero", engine_opts: dict | None = None):
    import dataclasses
    mesh = MESHES[mesh_name]()
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    model = build_model(arch)
    planner_kw = {}
    if scheme == "auto":
        planner_kw = dict(psi=model.param_count(), n_layers=arch.n_layers)
    cfg = scheme_config(scheme, mesh, quant_block=quant_block, **planner_kw)
    if engine_opts:
        cfg = dataclasses.replace(cfg, **engine_opts)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())

    if shape.kind == "train":
        baxes = batch_axes(mesh, shape.global_batch,
                           candidates=train_batch_candidates(mesh))
        shapes = model.train_batch_shapes(shape)
        bspecs = model.batch_pspecs(shapes, baxes)
        batch_sds = model.batch_sds(shapes, mesh, baxes)
        step = eng.make_train_step(model.loss_fn(), bspecs)
        with mesh:
            lowered = step.lower(eng.abstract_state(), batch_sds)
    else:
        sp = "sp" in serve_mode
        if "resident" in serve_mode:
            from ..serve.resident import ResidentServeEngine
            se = ResidentServeEngine(model, eng, mesh, shape)
            prims = se.abstract_params()
        else:
            se = ServeEngine(model, eng, mesh, shape)
            prims = eng.abstract_primaries()
        if shape.kind == "prefill":
            step = se.make_prefill(seq_parallel=sp)
            with mesh:
                lowered = step.lower(prims, se.prefill_inputs_sds())
        else:
            step = se.make_decode()
            caches, batch = se.decode_inputs_sds()
            with mesh:
                lowered = step.lower(prims, caches, batch)
    return eng, lowered, mesh, arch, shape


def compare_phases(eng, arch, shape, mesh, metrics_path, topology: str = ""):
    """Predicted-vs-measured per-phase table (DESIGN.md §10).

    Predicted: ``topo.cost.phase_breakdown`` for THIS combo's config on
    ``--topology`` (default: the live mesh's synthetic Topology). Measured:
    the last ``phase_ms`` record in a ``--metrics-jsonl`` stream from a
    traced run (all rank lanes merged). The two need not share a mesh —
    the point is eyeballing where the model and a real trace diverge.

    ``--topology`` is applied as an overlay: its link bandwidths replace
    the same-named axes of the mesh's synthetic topology, so a calibration
    file from a differently-shaped mesh (obs.calibrate on the 8-device test
    mesh, say) still prices the axes it actually measured.
    """
    from ..obs import metrics as obs_metrics
    from ..topo import cost as tcost
    from ..topo.model import Topology, calibrated, load_topology
    topo = Topology.from_mesh(mesh)
    if topology:
        src = load_topology(topology)
        known = {l.name: l.bandwidth for l in src.links}
        topo = calibrated(
            topo, {l.name: known[l.name] for l in topo.links
                   if l.name in known},
            name=f"{topo.name}<-{src.name}")
    n_mb = max(eng.hp.n_microbatch, 1)
    wl = tcost.Workload(
        psi=float(eng.param_count()), n_layers=arch.n_layers,
        tokens_per_device_mb=shape.global_batch * shape.seq_len
        // mesh.size // n_mb,
        n_microbatch=n_mb, stream_grads=eng.cfg.stream_grads)
    pred = tcost.phase_breakdown(eng.cfg, topo, wl)
    measured = obs_metrics.last_phase_ms(obs_metrics.read_lanes(metrics_path))
    rows = {}
    lines = [f"{'phase':<16}{'predicted_ms':>14}{'measured_ms':>14}"]
    for ph in tcost.PHASES:
        p = pred[ph]["seconds"] * 1e3
        m = measured.get(ph)
        rows[ph] = dict(predicted_ms=p, measured_ms=m)
        lines.append(f"{ph:<16}{p:>14.3f}" +
                     (f"{m:>14.2f}" if m is not None else f"{'--':>14}"))
    return rows, "\n".join(lines)


def compare_serve_phases(eng, arch, shape, mesh, metrics_path,
                         topology: str = "", resident: bool = True):
    """Predicted-vs-measured for one serving decode step (DESIGN.md §12).

    Predicted: ``topo.cost.serve_step_cost`` for this combo's residency
    layout on ``--topology`` (overlay semantics as ``compare_phases``).
    Measured: the last serve ``phase_ms`` record from a continuous-batching
    run's ``--metrics-jsonl`` stream (repro.launch.serve) — the scheduler's
    ``serve_decode`` span is the decode step, ``serve_admit`` the admission
    work; the per-layer comm phases are predicted-only (they live inside
    the compiled step and are not separately spanned)."""
    from ..obs import metrics as obs_metrics
    from ..topo import cost as tcost
    from ..topo.model import Topology, calibrated, load_topology
    from ..topo.planner import serve_workload_for_model
    topo = Topology.from_mesh(mesh)
    if topology:
        src = load_topology(topology)
        known = {l.name: l.bandwidth for l in src.links}
        topo = calibrated(
            topo, {l.name: known[l.name] for l in topo.links
                   if l.name in known},
            name=f"{topo.name}<-{src.name}")
    wl = serve_workload_for_model(
        arch.name, n_slots=shape.global_batch, context=shape.seq_len,
        max_len=shape.seq_len, quant_block=eng.cfg.quant_block)
    res_axes = tuple(eng.cfg.axes.secondary or ())
    pred = tcost.serve_step_cost(topo, wl, res_axes, resident=resident)
    measured = obs_metrics.last_phase_ms(
        obs_metrics.read_lanes(metrics_path))
    rows = {}
    lines = [f"{'phase':<16}{'predicted_ms':>14}{'measured_ms':>14}"]
    preds = dict(pred.comm_s)
    preds["serve_decode"] = pred.step_s()
    preds["serve_admit"] = None
    for ph in tcost.SERVE_PHASES + ("serve_decode", "serve_admit"):
        p = preds[ph]
        m = measured.get(ph)
        rows[ph] = dict(
            predicted_ms=None if p is None else p * 1e3, measured_ms=m)
        lines.append(
            f"{ph:<16}" +
            (f"{p * 1e3:>14.3f}" if p is not None else f"{'--':>14}") +
            (f"{m:>14.2f}" if m is not None else f"{'--':>14}"))
    return rows, "\n".join(lines)


def run_combo(arch_name, shape_name, mesh_name, scheme, outdir: Path,
              quant_block: int = 2048, save_hlo: bool = False,
              serve_mode: str = "zero", engine_opts: dict | None = None,
              tag: str = "", compare: str = "", topology: str = ""):
    t0 = time.time()
    eng, lowered, mesh, arch, shape = lower_combo(
        arch_name, shape_name, mesh_name, scheme, quant_block, serve_mode,
        engine_opts)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    txt = compiled.as_text()
    census = hlo.analyze(txt).summary()

    n_params = eng.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    rl = roofline.build(
        census, n_chips=mesh.size, n_params=n_params,
        n_active_params=roofline.active_params(arch, n_params),
        tokens=tokens, kind=shape.kind)

    rec = dict(
        arch=arch_name, shape=shape_name, mesh=mesh_name, scheme=scheme,
        serve_mode=serve_mode, n_chips=mesh.size, n_params=n_params,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
        ),
        cost_analysis=dict(flops=float(cost.get("flops", -1)),
                           bytes_accessed=float(cost.get("bytes accessed", -1))),
        census=census,
        roofline=rl.summary(),
    )
    if compare and shape.kind == "train":
        rows, table = compare_phases(eng, arch, shape, mesh, compare,
                                     topology)
        rec["phase_compare"] = rows
        print(table, flush=True)
    elif compare and shape.kind == "decode":
        rows, table = compare_serve_phases(
            eng, arch, shape, mesh, compare, topology,
            resident="resident" in serve_mode)
        rec["phase_compare"] = rows
        print(table, flush=True)
    outdir.mkdir(parents=True, exist_ok=True)
    name = f"{arch_name}__{shape_name}__{mesh_name}__{scheme}"
    if serve_mode != "zero":
        name += f"__{serve_mode}"
    if tag:
        name += f"__{tag}"
    (outdir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (outdir / f"{name}.hlo.txt").write_text(txt)
    print(f"OK  {name}  lower={t_lower:.0f}s compile={t_compile:.0f}s "
          f"bottleneck={rl.bottleneck} "
          f"terms(c/m/x)={rl.compute_s:.3f}/{rl.memory_s:.3f}/"
          f"{rl.collective_s:.3f}s", flush=True)
    return rec


def build_parser() -> argparse.ArgumentParser:
    """The dry-run CLI surface (rendered into docs/CLI.md by
    ``repro.launch.cli_reference``)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.dryrun",
        description="lower + compile (arch x shape x mesh x scheme) combos "
                    "on 512 fake devices, no allocation")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="prod")
    ap.add_argument("--scheme", default="zero_topo",
                    help="comma-separated presets, or 'auto' for the "
                         "topology planner's choice on each mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quant-block", type=int, default=2048)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-mode", default="zero",
                    choices=["zero", "resident", "zero_sp", "resident_sp"])
    ap.add_argument("--cross-replica", default="",
                    choices=["", "allreduce", "reduce_scatter"])
    ap.add_argument("--quant-update", action="store_true")
    ap.add_argument("--stream-grads", action="store_true",
                    help="lower the streaming gradient path (DESIGN.md §8)")
    ap.add_argument("--kernel-impl", default="",
                    choices=["", "jnp", "pallas", "pallas_interpret"],
                    help="quantization-kernel implementation to lower with "
                         "(DESIGN.md §5); empty inherits the process default")
    ap.add_argument("--tag", default="")
    ap.add_argument("--compare", default="",
                    help="metrics JSONL from a traced run (--metrics-jsonl): "
                         "print a predicted-vs-measured per-phase column for "
                         "each train combo (DESIGN.md §10); serve JSONL from "
                         "repro.launch.serve does the same for decode "
                         "combos (DESIGN.md §12)")
    ap.add_argument("--topology", default="",
                    help="topology preset or JSON (e.g. obs.calibrate "
                         "output) pricing --compare's predicted column; "
                         "default: the live mesh's synthetic topology")
    add_cli_args(ap)
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()
    # 512 fake devices, forced only now (not at import: jax reads XLA_FLAGS
    # at backend initialization, and the first device touch is the mesh
    # construction below — importing this module must stay side-effect free
    # so cli_reference can render the parser)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    # multi-process dry-run: each process forces its share of the 512 fake
    # devices; rendezvous before the first device access
    dcfg = from_args(args)
    if dcfg.is_distributed:
        if 512 % dcfg.num_processes:
            ap.error(f"the 512-device dry-run meshes are not divisible by "
                     f"{dcfg.num_processes} processes ({dcfg.source})")
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                                   f"{512 // dcfg.num_processes}")
    initialize(dcfg)
    engine_opts = {}
    if args.cross_replica:
        engine_opts["cross_replica"] = args.cross_replica
    if args.quant_update:
        engine_opts["quantize_update_gather"] = True
    if args.stream_grads:
        engine_opts["stream_grads"] = True
    if args.kernel_impl:
        engine_opts["impl"] = args.kernel_impl

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    # default "all" = the 10 assigned archs (paper's neox models via explicit)
    if args.arch == "all":
        archs = [a for a in archs if not a.startswith("gpt-neox")]
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    schemes = args.scheme.split(",")
    outdir = Path(args.out)

    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_supported(get_arch(arch), SHAPES[shape]):
                print(f"SKIP {arch} {shape} (sub-quadratic attention "
                      f"required; see DESIGN.md)", flush=True)
                continue
            for mesh in meshes:
                for scheme in schemes:
                    try:
                        run_combo(arch, shape, mesh, scheme, outdir,
                                  args.quant_block, args.save_hlo,
                                  args.serve_mode, engine_opts or None,
                                  args.tag, args.compare, args.topology)
                    except Exception as e:
                        failures.append((arch, shape, mesh, scheme, str(e)))
                        print(f"FAIL {arch} {shape} {mesh} {scheme}: "
                              f"{type(e).__name__}: {e}", flush=True)
                        traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall combinations lowered + compiled")


if __name__ == "__main__":
    main()
