"""Three-term roofline model from the compiled dry-run (deliverable (g)).

Target hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s per ICI link. The compiled SPMD module is the *per-device* program,
so the loop-aware HLO census (``hlo.analyze``) directly yields per-chip
FLOPs / HBM bytes / collective wire bytes:

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per training step; the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
DCI_BW = 6.25e9              # B/s per chip across the pod boundary (modeled)
HOP_LAT = 1e-6               # per ring hop (latency term: count*(d-1)*alpha)
POD = 256                    # chips per pod: group span >= POD crosses DCI


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_chip: float
    hlo_flops_per_chip: float

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops_per_chip / max(self.hlo_flops_per_chip, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        return self.model_flops_per_chip / (self.step_time_s * PEAK_FLOPS) \
            if self.step_time_s else 0.0

    def summary(self) -> dict:
        return dict(compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, bottleneck=self.bottleneck,
                    step_time_s=self.step_time_s,
                    model_flops_per_chip=self.model_flops_per_chip,
                    hlo_flops_per_chip=self.hlo_flops_per_chip,
                    useful_flop_ratio=self.useful_flop_ratio,
                    mfu_bound=self.mfu_bound)


def model_flops(n_params: int, n_active_params: int, tokens: int,
                kind: str) -> float:
    """6·N·D training FLOPs (fwd 2ND + bwd 4ND); 2·N·D for inference."""
    n = n_active_params or n_params
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * tokens


def collective_seconds(hlo_summary: dict) -> float:
    """Tier-aware: pod-crossing groups at DCI bandwidth, plus the ring
    hop-latency term — the term the paper's constant-group-size design
    pins down (collectives of d=2/8 cost ~zero latency at any scale)."""
    groups = hlo_summary.get("groups")
    if not groups:
        return hlo_summary["total_wire_bytes"] / ICI_BW
    total = 0.0
    for key, (wire, count) in groups.items():
        _, d, span = key.split("|")
        bw = DCI_BW if int(span) >= POD else ICI_BW
        total += wire / bw + count * (int(d) - 1) * HOP_LAT
    return total


def build(hlo_summary: dict, *, n_chips: int, n_params: int,
          n_active_params: int, tokens: int, kind: str) -> Roofline:
    mf = model_flops(n_params, n_active_params, tokens, kind) / n_chips
    return Roofline(
        compute_s=hlo_summary["flops"] / PEAK_FLOPS,
        memory_s=hlo_summary["hbm_bytes"] / HBM_BW,
        collective_s=collective_seconds(hlo_summary),
        model_flops_per_chip=mf,
        hlo_flops_per_chip=hlo_summary["flops"],
    )


def active_params(arch, total_params: int) -> int:
    """MoE: count only top-k of the expert FFN params as active."""
    if not arch.moe.n_experts:
        return total_params
    e, k = arch.moe.n_experts, arch.moe.top_k
    expert_per_layer = 3 * arch.d_model * arch.moe.d_ff * e
    n_moe_layers = sum(1 for p in arch.pattern if "moe" in p)
    expert_total = expert_per_layer * n_moe_layers
    return total_params - expert_total + expert_total * k // e
