"""Mesh construction for the production pod(s) and the paper-faithful
3-level topo mesh.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before the first jax call).

Axis-to-bandwidth-tier mapping (DESIGN.md §2):

  production mesh (16, 16) ("data", "model"):
      "model"  — the intra tier (short ICI paths): weight + gradient shards
      "data"   — the inter tier: optimizer sharding + replica sync
  multi-pod (2, 16, 16) ("pod", "data", "model"): "pod" is DCI (slowest) and
      joins the inter tier (deeper optimizer sharding, batch replicated).

  topo mesh (data, repl, node, gcd) = (16, 2, 4, 2): the paper's 3 levels —
      "gcd" (2)        = the MI250X GCD pair       -> primary weight shards
      "node"x"gcd" (8) = the Frontier node         -> gradient shards + secondary
      "data"x"repl"    = inter-node                -> optimizer shards
"""
from __future__ import annotations

from ..compat import make_mesh as _mk


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_topo_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 2, 4, 2) if multi_pod else (16, 2, 4, 2)
    axes = (("pod",) if multi_pod else ()) + ("data", "repl", "node", "gcd")
    return _mk(shape, axes if multi_pod else ("data", "repl", "node", "gcd"))


def make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd")):
    """Small fake-device mesh for CPU tests (8 devices)."""
    return _mk(shape, axes)


def process_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that cross a process boundary.

    ``jax.devices()`` is process-major, so with the leading axes sized to a
    multiple of the process count these are exactly the leading (inter) axes;
    any other arrangement means intra-tier collectives would go over the
    slowest links, which ``zero_tiers`` rejects.
    """
    import numpy as np
    devs = np.asarray(mesh.devices)
    pidx = np.reshape([getattr(d, "process_index", 0)
                       for d in devs.ravel()], devs.shape)
    if (pidx == pidx.flat[0]).all():
        return ()
    spanning = []
    for k, name in enumerate(mesh.axis_names):
        first = np.take(pidx, [0], axis=k)
        if not (pidx == first).all():
            spanning.append(name)
    return tuple(spanning)


def zero_tiers(mesh) -> dict[str, tuple[str, ...]]:
    """Map a mesh's axes onto the (l0, intra, inter) bandwidth tiers.

    On a multi-process mesh the process boundary MUST fall inside the inter
    tier: the primary weight gather and the secondary partition live on the
    intra axes precisely because those are the fast in-node links, and a
    process boundary there would silently run them over the network.
    """
    names = set(mesh.axis_names)
    if {"node", "gcd"} <= names:
        intra = ("node", "gcd")
        l0 = ("gcd",)
    elif "model" in names:
        intra = ("model",)
        l0 = ("model",)
    else:  # single-axis test meshes
        intra = (mesh.axis_names[-1],)
        l0 = intra
    inter = tuple(a for a in mesh.axis_names if a not in intra)
    crossing = tuple(a for a in process_axes(mesh) if a not in inter)
    if crossing:
        raise ValueError(
            f"process boundary crosses intra-tier axes {crossing} of mesh "
            f"{dict(mesh.shape)}: a multi-process launch must keep whole "
            f"intra groups (axes {intra}) inside one process — lower the "
            f"per-process device count or reorder the mesh so only the "
            f"leading axes {inter} span processes")
    return dict(l0=l0, intra=intra, inter=inter)


def scheme_config(scheme: str, mesh, *, psi=None, n_layers=None,
                  memory_budget=None, **over):
    """Build the ZeroConfig for `scheme` on `mesh`.

    ``scheme="auto"`` runs the topology-aware planner (repro.topo) against
    the live mesh and returns its top-ranked config; ``psi``/``n_layers``
    describe the workload (defaulting to the paper's 20B/44-layer model) and
    ``memory_budget`` bounds per-device state bytes. Any remaining keyword
    overrides (quant_block, overlap, compute_dtype, ...) apply to the chosen
    config exactly as they would to a preset.
    """
    if scheme == "auto":
        import dataclasses

        from ..topo import plan_for_mesh
        # stream_grads changes the pricing regime (overlappable grad RS,
        # os-layout grad memory), not just the engine: hand it to the
        # planner so the budget search admits what streaming actually fits
        stream = bool(over.pop("stream_grads", False))
        cfg = plan_for_mesh(mesh, psi=psi, n_layers=n_layers,
                            memory_budget=memory_budget,
                            stream_grads=stream, top_k=1)[0].cfg
        return dataclasses.replace(cfg, **over) if over else cfg
    from ..core.partition import preset
    tiers = zero_tiers(mesh)
    return preset(scheme, intra_axes=tiers["intra"], inter_axes=tiers["inter"],
                  l0_axes=tiers["l0"], axis_sizes=dict(mesh.shape), **over)
