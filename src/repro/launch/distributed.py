"""Cross-process execution: ``jax.distributed.initialize`` wiring.

The paper's inter tier (Slingshot between Frontier nodes) is a *process*
boundary on real hardware — one training process per node (or per GCD).
This module is the single place that boundary is crossed:

* ``DistConfig`` — coordinator address + process rank/count, resolved from
  (in priority order) explicit CLI flags, SLURM, OpenMPI, or the
  ``REPRO_*`` env vars. Absent all of those, the run is single-process and
  ``initialize`` is a no-op, so every existing entry point keeps working
  unchanged.
* ``initialize(dcfg)`` — selects the CPU collectives backend (gloo; real
  GPU/TPU clusters bring their own), then calls
  ``jax.distributed.initialize``. Must run before the first device access.
* ``add_cli_args`` / ``from_args`` — the ``--coordinator`` /
  ``--num-processes`` / ``--process-id`` flags shared by
  ``launch/train.py`` and ``launch/dryrun.py``.

Mesh construction stays in ``launch/mesh.py``; the contract between the two
is that ``jax.devices()`` is process-major (all of process 0's devices, then
process 1's, ...) so the *leading* mesh axes span processes — pinning the
process boundary to the inter tier (``mesh.process_axes`` verifies it).
"""
from __future__ import annotations

import os
from dataclasses import dataclass


@dataclass(frozen=True)
class DistConfig:
    """One process's view of the cluster. ``num_processes == 1`` means the
    ordinary single-process mode (no distributed runtime is started)."""
    coordinator: str | None = None
    num_processes: int = 1
    process_id: int = 0
    source: str = "single"     # single | flags | slurm | ompi | env

    def __post_init__(self):
        assert self.num_processes >= 1, self
        assert 0 <= self.process_id < self.num_processes, self
        if self.num_processes > 1:
            assert self.coordinator, \
                f"multi-process launch needs a coordinator address: {self}"

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1


def _from_slurm() -> DistConfig | None:
    """srun sets the full rank layout; coordinator = first node of the job.

    SLURM_STEP_NODELIST can be a compressed range expression; we only need
    the first hostname, which scontrol would expand — but to stay
    dependency-free we take the simple prefix (exact for the common
    ``host[1-4]``-style lists srun emits, and overridable via
    REPRO_COORDINATOR when it is not).
    """
    if "SLURM_PROCID" not in os.environ or "SLURM_NTASKS" not in os.environ:
        return None
    n = int(os.environ["SLURM_NTASKS"])
    if n == 1:
        return None
    host = os.environ.get("REPRO_COORDINATOR")
    if not host:
        nodelist = os.environ.get("SLURM_STEP_NODELIST",
                                  os.environ.get("SLURM_NODELIST", ""))
        first = nodelist.split(",")[0]
        if "[" in first:      # "frontier[00123-00170]" -> "frontier00123"
            prefix, rng = first.split("[", 1)
            first = prefix + rng.split("-")[0].split(",")[0].rstrip("]")
        host = f"{first}:{_DEFAULT_PORT}" if first else None
    if not host:
        return None
    return DistConfig(host, n, int(os.environ["SLURM_PROCID"]), "slurm")


def _from_ompi() -> DistConfig | None:
    """mpirun/mpiexec (OpenMPI): world size/rank from the OMPI env."""
    if "OMPI_COMM_WORLD_RANK" not in os.environ:
        return None
    n = int(os.environ.get("OMPI_COMM_WORLD_SIZE", "1"))
    if n == 1:
        return None
    host = os.environ.get("REPRO_COORDINATOR")
    if not host:
        return None     # OpenMPI does not expose rank 0's hostname portably
    return DistConfig(host, n, int(os.environ["OMPI_COMM_WORLD_RANK"]), "ompi")


def _from_env() -> DistConfig | None:
    """Manual launch: REPRO_COORDINATOR / REPRO_NUM_PROCESSES /
    REPRO_PROCESS_ID (the two-terminal quickstart in the README)."""
    n = int(os.environ.get("REPRO_NUM_PROCESSES", "1"))
    if n == 1:
        return None
    return DistConfig(os.environ.get("REPRO_COORDINATOR"), n,
                      int(os.environ.get("REPRO_PROCESS_ID", "0")), "env")


_DEFAULT_PORT = 12621


def detect(coordinator: str | None = None, num_processes: int | None = None,
           process_id: int | None = None) -> DistConfig:
    """Resolve the cluster layout: explicit args > SLURM > OpenMPI > env.

    Explicit args must come as a complete set (coordinator + count + id);
    a partial set is an error rather than a silent fallback.
    """
    explicit = [coordinator, num_processes, process_id]
    if any(v is not None for v in explicit):
        if any(v is None for v in explicit):
            raise ValueError(
                "--coordinator, --num-processes and --process-id must be "
                f"given together (got {explicit})")
        return DistConfig(coordinator, num_processes, process_id, "flags")
    for probe in (_from_slurm, _from_ompi, _from_env):
        dcfg = probe()
        if dcfg is not None:
            return dcfg
    return DistConfig()


_INITIALIZED: DistConfig | None = None


def initialize(dcfg: DistConfig | None = None, *,
               local_devices: int | None = None) -> DistConfig:
    """Start the distributed runtime for this process (idempotent).

    Call before the first jax device access. ``local_devices`` forces the
    fake-CPU device count *per process* (tests/CI; a real launch inherits
    the visible accelerators). Single-process configs return immediately —
    the whole module is then dead weight, by design.
    """
    global _INITIALIZED
    dcfg = dcfg or detect()
    if _INITIALIZED is not None:
        assert _INITIALIZED == dcfg, (_INITIALIZED, dcfg)
        return dcfg
    if local_devices:
        _force_local_devices(local_devices, dcfg)
    if not dcfg.is_distributed:
        _INITIALIZED = dcfg
        return dcfg

    from ..compat import enable_cpu_collectives
    import jax
    # The backend can't be probed here — jax.default_backend() would
    # instantiate the runtime before jax.distributed gets to. Select gloo
    # unconditionally: it only affects the CPU client, and a CPU cluster
    # without it forms fine but deadlocks on the first collective.
    if not enable_cpu_collectives() and _looks_like_cpu():
        raise RuntimeError(
            "this JAX version has no cross-process CPU collectives backend "
            "(jax_cpu_collectives_implementation); multi-process CPU runs "
            "need a newer jax")
    jax.distributed.initialize(coordinator_address=dcfg.coordinator,
                               num_processes=dcfg.num_processes,
                               process_id=dcfg.process_id)
    assert jax.process_count() == dcfg.num_processes, \
        (jax.process_count(), dcfg)
    _INITIALIZED = dcfg
    return dcfg


def _force_local_devices(n: int, dcfg: DistConfig) -> None:
    """Pin this process's fake-CPU device count to its share of the mesh.

    A pre-set XLA_FLAGS with a *different* forced count would silently give
    every process the global count (8 local x 2 procs = 16 global devices,
    then a hung or mis-built mesh), so a conflicting value is an error in
    distributed mode rather than something to quietly keep or override —
    the env was set deliberately and we can't know what else relies on it.
    Single-process, the pre-set env wins (the historical behavior).
    """
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (flags + " " if flags else "") + \
            f"--xla_force_host_platform_device_count={n}"
        return
    if dcfg.is_distributed and int(m.group(1)) != n:
        raise RuntimeError(
            f"XLA_FLAGS forces {m.group(1)} host devices but this "
            f"{dcfg.num_processes}-process launch needs {n} per process "
            f"(the per-process share of the global mesh). Unset XLA_FLAGS "
            f"or set --xla_force_host_platform_device_count={n}.")


def _looks_like_cpu() -> bool:
    """Env-only CPU heuristic (safe to evaluate pre-initialize)."""
    return bool(os.environ.get("JAX_PLATFORMS", "").startswith("cpu")
                or "xla_force_host_platform_device_count"
                in os.environ.get("XLA_FLAGS", ""))


def process_count() -> int:
    import jax
    return jax.process_count()


def process_index() -> int:
    import jax
    return jax.process_index()


# -- rank heartbeat / stall detection (trace mode) ---------------------------

@dataclass(frozen=True)
class Heartbeat:
    """Per-rank stall detector bound to the live process layout.

    Every collective is a barrier: one slow or dead rank hangs the whole
    cluster with no indication of *which*. In trace mode each process calls
    ``stamp(step)`` before every step (an atomic per-rank file write,
    ``obs.heartbeat``); any process can then call ``report()`` to classify
    every expected rank — ``dead`` (never stamped), ``stalled`` (stamp too
    old), ``behind`` (step trails the cluster max) — instead of the run
    hanging silently. tests/test_multiprocess.py's delayed-rank scenario
    pins the detection.
    """
    directory: str
    rank: int
    n_ranks: int

    def stamp(self, step: int):
        from ..obs import heartbeat as hb
        return hb.stamp(self.directory, self.rank, step)

    def report(self, *, stall_s: float = 30.0) -> dict:
        from ..obs import heartbeat as hb
        return hb.straggler_report(self.directory, self.n_ranks,
                                   stall_s=stall_s)

    def format_report(self, *, stall_s: float = 30.0) -> str:
        from ..obs import heartbeat as hb
        return hb.format_report(self.report(stall_s=stall_s))


def heartbeat(directory) -> Heartbeat:
    """Heartbeat handle for this process (requires a live jax runtime —
    rank/count come from ``jax.process_index``/``process_count``)."""
    return Heartbeat(str(directory), process_index(), process_count())


# -- CLI wiring (launch/train.py, launch/dryrun.py) --------------------------

def add_cli_args(ap) -> None:
    g = ap.add_argument_group(
        "distributed", "multi-process launch (omit all three to autodetect "
        "SLURM / OpenMPI / REPRO_* env, or run single-process)")
    g.add_argument("--coordinator", default=None,
                   help="rank 0 address, host:port")
    g.add_argument("--num-processes", type=int, default=None)
    g.add_argument("--process-id", type=int, default=None)


def from_args(args) -> DistConfig:
    return detect(args.coordinator, args.num_processes, args.process_id)
