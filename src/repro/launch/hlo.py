"""Static analysis of compiled HLO: loop-aware FLOPs, HBM-byte and
collective-byte census.

XLA's ``cost_analysis()`` counts each while-loop body **once**, so for
scanned-layer models it underestimates FLOPs and bytes by ~n_layers, and it
reports no collective traffic at all. This module parses the compiled HLO
text into its computation graph, propagates execution multipliers through
``calls=`` / ``body=`` edges (while bodies multiply by their
``known_trip_count``), and aggregates:

  * dot/convolution FLOPs (2 * prod(out) * prod(contracting dims)),
  * an HBM-traffic estimate: sum of operand + output bytes of every fusion /
    dot / copy / collective at the top level of each computation (fusions
    internalize their elementwise chains, mirroring what a TPU would keep in
    VMEM),
  * collective wire bytes per op type with ring-algorithm formulas
    (paper Tables VII/VIII):
        all-gather          V_out * (d-1)/d
        reduce-scatter      V_out * (d-1)
        all-reduce          2 * V * (d-1)/d
        all-to-all          V * (d-1)/d
        collective-permute  V
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\((?:[^()]|\([^)]*\))*\)|\w+\[[\d,]*\]\S*)\s+([\w\-]+)\(")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|true_computation|"
                      r"false_computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _group_stride(line: str) -> int:
    """Device-id stride within a replica group (1 = minor-axis/contiguous).

    Used to classify which mesh tier a collective crosses: on the production
    meshes, stride >= 256 means the group spans the pod (DCI) boundary."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip() != ""]
        if len(ids) >= 2:
            return abs(ids[1] - ids[0])
        return 1
    # iota format: [G,D]<=[dims]T(perm) — groups of D over a transposed grid
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?",
                  line)
    if m:
        dims = [int(x) for x in m.group(3).split(",")]
        if not m.group(4):
            return 1                       # contiguous reshape
        perm = [int(x) for x in m.group(5).split(",")]
        # the fastest-varying dim within a group is perm[-1] of the iota
        # grid; its stride in device-id space is the product of dims after it
        fastest = perm[-1]
        stride = 1
        for i in range(fastest + 1, len(dims)):
            stride *= dims[i]
        return stride
    return 1


def _group_span(line: str, d: int) -> int:
    """max(id) - min(id) within one replica group (tier classification:
    span >= pod size means the collective crosses the DCI boundary)."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip() != ""]
        if ids:
            return max(ids) - min(ids)
    return _group_stride(line) * (d - 1)


_OPERAND_RE = re.compile(r"\(\s*%?([\w.\-]+)")


def _dot_flops(line: str, out_type: str, symtab: dict[str, str]) -> float:
    """2 * prod(out) * prod(lhs contracting dims).

    Compiled HLO does not repeat operand types at the call site, so the lhs
    shape is resolved through the module-wide symbol table.
    """
    out_elems, _ = _shape_elems_bytes(out_type)
    call = line.split("(", 1)[1]
    # operand types inline (lowered StableHLO-ish) or via symtab (compiled)
    operand_shapes = _SHAPE_RE.findall(call.split("metadata")[0])
    lhs_dims: list[int] = []
    if operand_shapes:
        lhs_dims = [int(x) for x in operand_shapes[0][1].split(",") if x]
    else:
        m0 = _OPERAND_RE.search("(" + call)
        if m0 and m0.group(1) in symtab:
            shapes = _SHAPE_RE.findall(symtab[m0.group(1)])
            if shapes:
                lhs_dims = [int(x) for x in shapes[0][1].split(",") if x]
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", line)
    k = 1
    if m and lhs_dims:
        for i in m.group(1).split(","):
            if i.strip():
                k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def _operand_bytes(line: str, opcode: str, symtab: dict[str, str],
                   billing: dict[str, int] | None = None) -> int:
    """Sum operand sizes of a call: inline types if present, else symtab.

    ``billing`` (fusions): per-operand byte overrides keyed by position —
    a fusion parameter consumed only through dynamic-slice reads only the
    slice, not the whole (e.g. layer-stacked) array.
    """
    try:
        call = line.split(opcode + "(", 1)[1]
        args = call.split(")", 1)[0]
    except IndexError:
        return 0
    inline = _SHAPE_RE.findall(args)
    if inline and billing is None:
        return _shape_elems_bytes(args)[1]
    total = 0
    for pos, name in enumerate(re.findall(r"%([\w.\-]+)", args)):
        if billing is not None and pos in billing:
            total += billing[pos]
        elif name in symtab:
            total += _shape_elems_bytes(symtab[name])[1]
    return total


# ops inside a fusion that make it read more input elements than it writes
_EXPANDING = {"reduce", "reduce-window", "dot", "convolution", "gather",
              "scatter", "sort", "select-and-scatter"}


def _fusion_billing(comp: list["Instr"], out_type: str) -> dict[int, int]:
    """Byte billing overrides for fusion parameters.

    kLoop fusions compute output elements lazily, so a fusion whose body is a
    pure elementwise/layout chain (incl. slices) reads at most
    out_elems * operand_itemsize per operand — NOT the full operand (XLA
    slices-of-big-arrays would otherwise be billed d times by d consumers).
    Fusions containing reducing/gathering ops read their operands in full.
    A parameter consumed only by (dynamic-)slice ops contributes the slice
    bytes; one consumed only as a dynamic-update-slice buffer contributes
    the update bytes (in-place write).
    """
    local = {i.name: i for i in comp}
    out_elems, _ = _shape_elems_bytes(out_type)
    expanding = any(i.opcode in _EXPANDING for i in comp)
    params: dict[str, tuple[int, str]] = {}     # name -> (index, type)
    for i in comp:
        if i.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                params[i.name] = (int(m.group(1)), i.out_type)
    uses: dict[str, list[tuple["Instr", int]]] = {p: [] for p in params}
    for i in comp:
        if i.opcode == "parameter":
            continue
        try:
            args = i.line.split(i.opcode + "(", 1)[1].split(")", 1)[0]
        except IndexError:
            continue
        for argpos, nm in enumerate(re.findall(r"%([\w.\-]+)", args)):
            if nm in uses:
                uses[nm].append((i, argpos))
    billing: dict[int, int] = {}
    for pname, ulist in uses.items():
        idx, ptype = params[pname]
        pelems, pbytes = _shape_elems_bytes(ptype)
        if not ulist:
            billing[idx] = 0
            continue
        if all(u.opcode in ("dynamic-slice", "slice") for u, _ in ulist):
            billing[idx] = sum(
                _shape_elems_bytes(u.out_type)[1] for u, _ in ulist)
        elif all(u.opcode == "dynamic-update-slice" and ap == 0
                 for u, ap in ulist):
            b = 0
            for u, _ in ulist:
                args = u.line.split(u.opcode + "(", 1)[1].split(")", 1)[0]
                names = re.findall(r"%([\w.\-]+)", args)
                if len(names) > 1 and names[1] in local:
                    b += _shape_elems_bytes(local[names[1]].out_type)[1]
            billing[idx] = b
        elif not expanding and pelems:
            itemsize = max(pbytes // pelems, 1)
            billing[idx] = min(pbytes, out_elems * itemsize)
    return billing


@dataclass
class Instr:
    opcode: str
    out_type: str
    line: str
    name: str = ""


@dataclass
class CollectiveRecord:
    """One collective instruction, kept verbatim for contract checking
    (analysis/contracts.py): the base opcode, the output type (dtype census),
    the full HLO line (replica groups), and the loop-trip multiplier."""
    opcode: str
    out_type: str
    line: str
    name: str
    mult: float
    wire: float       # ring wire bytes per execution (before mult)


@dataclass
class HLOAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))
    counts: dict = field(default_factory=lambda: defaultdict(float))
    groups: dict = field(default_factory=dict)   # (op,d,span) -> [wire,count]
    records: list = field(default_factory=list)  # [CollectiveRecord]
    unknown_loops: int = 0

    def add_group(self, op: str, d: int, span: int, wire: float, m: float):
        key = f"{op}|{d}|{span}"
        w, c = self.groups.get(key, (0.0, 0.0))
        self.groups[key] = (w + wire * m, c + m)

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def summary(self) -> dict:
        return dict(flops=float(self.flops), hbm_bytes=float(self.hbm_bytes),
                    wire_bytes={k: float(v) for k, v in self.wire_bytes.items()},
                    total_wire_bytes=self.total_wire_bytes,
                    collective_counts={k: float(v) for k, v in self.counts.items()
                                       if k in COLLECTIVES},
                    groups={k: [float(w), float(c)]
                            for k, (w, c) in self.groups.items()},
                    unknown_loops=self.unknown_loops)


# ops whose operand+output traffic we bill as HBM bytes (top-level only;
# everything else is either fused or negligible bookkeeping)
_HBM_OPS = {"fusion", "dot", "convolution", "copy", "transpose", "reshape",
            "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
            "gather", "scatter", "concatenate", "pad", "broadcast",
            "slice", "select-and-scatter", "reduce-window", "iota",
            "convert", "bitcast-convert", "rng-bit-generator"} | set(COLLECTIVES)


def parse_hlo(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.rstrip()
        # computation headers: "%name (params) -> type {" at zero indent
        if (stripped.endswith("{") and "->" in stripped
                and not line.startswith(" ")):
            h = _COMP_HDR_RE.match(stripped)
            if h:
                name = h.group(1)
                cur = comps.setdefault(name, [])
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instr(m.group(3), m.group(2), stripped, m.group(1)))
    return comps


def analyze(text: str) -> HLOAnalysis:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        # fall back: treat whole text as one computation
        comps["__entry__"] = [i for v in comps.values() for i in v]

    symtab: dict[str, str] = {}
    for v in comps.values():
        for ins in v:
            symtab[ins.name] = ins.out_type

    billing_cache: dict[str, dict[int, int]] = {}

    def fusion_billing(line: str, out_type: str):
        m = re.search(r"calls=%?([\w.\-]+)", line)
        if not m or m.group(1) not in comps:
            return None, None
        cname = m.group(1)
        if cname not in billing_cache:
            billing_cache[cname] = _fusion_billing(comps[cname], out_type)
        comp = comps[cname]
        out_override = None
        for ins in comp:
            if ins.line.lstrip().startswith("ROOT") and \
                    ins.opcode == "dynamic-update-slice":
                args = ins.line.split("dynamic-update-slice(", 1)[1] \
                    .split(")", 1)[0]
                names = re.findall(r"%([\w.\-]+)", args)
                local = {i.name: i for i in comp}
                if len(names) > 1 and names[1] in local:
                    out_override = _shape_elems_bytes(
                        local[names[1]].out_type)[1]
        return billing_cache[cname], out_override

    out = HLOAnalysis()

    def visit(comp: list[Instr], m: float, depth=0, in_fusion=False):
        if depth > 50:
            return
        for ins in comp:
            line = ins.line
            op = ins.opcode
            base = op.replace("-start", "") if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            # recurse into callees
            callees = _CALL_RE.findall(line)
            br = _BRANCHES_RE.search(line)
            if br:
                callees += [c.strip().lstrip("%") for c in br.group(1).split(",")]
            child_m = m
            if op == "while":
                t = _TRIP_RE.search(line)
                if t:
                    child_m = m * int(t.group(1))
                else:
                    out.unknown_loops += 1
            for cname in callees:
                if cname in comps:
                    visit(comps[cname], child_m, depth + 1,
                          in_fusion or op == "fusion")
            # aggregate this instruction
            _, out_b = _shape_elems_bytes(ins.out_type)
            if in_fusion:
                # fused ops live in VMEM/registers: count their dot FLOPs
                # but no HBM traffic and no collectives (can't occur fused).
                if base in ("dot", "convolution"):
                    out.flops += _dot_flops(line, ins.out_type, symtab) * m
                continue
            if base in COLLECTIVES:
                v = out_b
                if base == "collective-permute":
                    wire = float(v)
                    d, span = 2, 1
                else:
                    d = _group_size(line)
                    if d <= 1:
                        continue
                    wire = {"all-gather": v * (d - 1) / d,
                            "all-reduce": 2.0 * v * (d - 1) / d,
                            "reduce-scatter": float(v) * (d - 1),
                            "all-to-all": v * (d - 1) / d}[base]
                    span = _group_span(line, d)
                out.wire_bytes[base] += wire * m
                out.counts[base] += m
                out.add_group(base, d, span, wire, m)
                out.records.append(CollectiveRecord(
                    base, ins.out_type, line, ins.name, m, wire))
            if base in ("dot", "convolution"):
                out.flops += _dot_flops(line, ins.out_type, symtab) * m
            if base in _HBM_OPS:
                if base in ("slice", "dynamic-slice"):
                    # reads only the sliced region
                    out.hbm_bytes += 2 * out_b * m
                    continue
                if base == "dynamic-update-slice":
                    # in-place: write (and read) only the updated region
                    call = line.split(op + "(", 1)[1].split(")", 1)[0]
                    names = re.findall(r"%([\w.\-]+)", call)
                    upd = _shape_elems_bytes(symtab.get(names[1], ""))[1] \
                        if len(names) > 1 else out_b
                    out.hbm_bytes += 2 * upd * m
                    continue
                billing = None
                if op == "fusion":
                    billing, out_override = fusion_billing(line, ins.out_type)
                    if out_override is not None:
                        out_b = out_override
                operand_b = _operand_bytes(line, op, symtab, billing)
                out.hbm_bytes += (out_b + operand_b) * m

    visit(comps["__entry__"], 1.0)
    return out
