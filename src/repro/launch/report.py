"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON records.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path


def load(d: Path) -> list[dict]:
    out = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        parts = f.stem.split("__")
        r["variant"] = "+".join(parts[4:]) if len(parts) > 4 else "baseline"
        out.append(r)
    return out


def fmt_bytes(b: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(b) >= div:
            return f"{b / div:.2f}{unit}"
    return f"{b:.0f}B"


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | scheme | variant | chips | params "
             "| temp bytes/dev"
             " | arg bytes/dev | HLO GFLOPs/dev | wire bytes/dev | collectives"
             " (ag/ar/rs/a2a) | compile s |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        c = r["census"]["collective_counts"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['scheme']} "
            f"| {r.get('variant', 'baseline')} "
            f"| {r['n_chips']} | {r['n_params'] / 1e9:.2f}B "
            f"| {fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {fmt_bytes(r['memory']['argument_bytes'])} "
            f"| {r['census']['flops'] / 1e9:,.0f} "
            f"| {fmt_bytes(r['census']['total_wire_bytes'])} "
            f"| {cc} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | scheme | variant | compute s | memory s | "
             "collective s |"
             " bottleneck | useful-FLOP ratio | MFU bound | what would move "
             "the dominant term |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['scheme']} "
            f"| {r.get('variant', 'baseline')} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | **{rl['bottleneck']}** "
            f"| {rl['useful_flop_ratio']:.2f} | {rl['mfu_bound'] * 100:.1f}% "
            f"| {advice(r)} |")
    return "\n".join(lines)


def advice(r: dict) -> str:
    rl = r["roofline"]
    b = rl["bottleneck"]
    if b == "memory":
        if r["arch"].startswith("falcon") or "jamba" in r["arch"]:
            return "fuse selective-scan into a VMEM-resident Pallas kernel"
        return "fuse attention/dequant chains (Pallas flash kernel)"
    if b == "collective":
        if r["shape"].startswith(("decode", "long")):
            return "resident tensor-parallel weights for serving (gather " \
                   "activations, not parameters)"
        return "deepen quantization / shrink gather group (topo tiers)"
    return "compute-bound: overlap remaining collectives, raise batch"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/report.md")
    args = ap.parse_args()
    recs = load(Path(args.dir))
    prod = [r for r in recs if r["mesh"] == "prod"]
    mp = [r for r in recs if r["mesh"] == "prod_mp"]
    other = [r for r in recs if r["mesh"] not in ("prod", "prod_mp")]

    parts = ["## §Dry-run (single pod: 16x16 = 256 chips)", "",
             dryrun_table(prod), "",
             "## §Dry-run (multi-pod: 2x16x16 = 512 chips)", "",
             dryrun_table(mp), ""]
    if other:
        parts += ["## §Dry-run (other meshes/schemes)", "",
                  dryrun_table(other), ""]
    parts += ["## §Roofline (single pod, per chip; v5e: 197 TF bf16, "
              "819 GB/s HBM, 50 GB/s ICI)", "", roofline_table(prod), ""]
    out = "\n".join(parts)
    Path(args.out).write_text(out)
    print(f"wrote {args.out} ({len(recs)} records)")

    # quick bottleneck summary
    byb = defaultdict(list)
    for r in prod:
        byb[r["roofline"]["bottleneck"]].append(
            (r["arch"], r["shape"],
             r["roofline"]["step_time_s"]))
    for b, lst in byb.items():
        worst = max(lst, key=lambda t: t[2])
        print(f"{b:10s}: {len(lst)} combos; worst {worst[0]} {worst[1]} "
              f"({worst[2]:.2f}s)")


if __name__ == "__main__":
    main()
