"""Generated CLI reference: one markdown table per entry-point parser.

Every user-facing CLI keeps its argparse surface in a ``build_parser()``
function (side-effect-free import), and this module renders all of them
into ``docs/CLI.md`` — one source of truth instead of flags scattered
across READMEs and docstrings.

    PYTHONPATH=src python -m repro.launch.cli_reference --write   # regen
    PYTHONPATH=src python -m repro.launch.cli_reference --check   # CI/test

``--check`` exits non-zero if the checked-in file drifts from the parsers
(``tests/test_cli_reference.py`` runs the same comparison), so a new flag
cannot land without its docs.
"""
from __future__ import annotations

import argparse
import re
from importlib import import_module
from pathlib import Path

# (module, build_parser attr) in the order they appear in the reference.
# Each module must import without touching the jax backend or os.environ.
TOOLS = (
    "repro.launch.train",
    "repro.launch.dryrun",
    "repro.launch.serve",
    "repro.topo.planner",
    "repro.analysis.check",
    "repro.obs.calibrate",
)

HEADER = """\
# CLI reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate: PYTHONPATH=src python -m repro.launch.cli_reference --write
     Drift-checked by tests/test_cli_reference.py and --check. -->

Every tool below exposes its parser as ``build_parser()`` in the named
module; this file is rendered from those parsers, so it cannot drift from
``--help`` (a test compares the two). Defaults shown as ``off`` are
``store_true`` switches.
"""


def _escape(text: str) -> str:
    return re.sub(r"\s+", " ", text or "").replace("|", "\\|").strip()


def _fmt_default(action) -> str:
    d = action.default
    if isinstance(d, bool):
        return "on" if d else "off"
    if d is None or d == argparse.SUPPRESS:
        return ""
    if isinstance(d, (list, tuple)):
        return "`" + ",".join(str(x) for x in d) + "`"
    if d == "":
        return ""
    return f"`{d}`"


def _row(action) -> str:
    flags = ", ".join(f"`{s}`" for s in action.option_strings) \
        or f"`{action.dest}`"
    desc = _escape(action.help or "")
    if action.choices is not None:
        ch = "one of: " + ", ".join(f"`{c}`" for c in action.choices)
        desc = f"{desc} ({ch})" if desc else ch
    return f"| {flags} | {_fmt_default(action)} | {desc} |"


def render_tool(module: str) -> str:
    ap = import_module(module).build_parser()
    lines = [f"## `python -m {module}`", ""]
    if ap.description:
        lines += [_escape(ap.description), ""]
    lines += ["| flag | default | description |", "| --- | --- | --- |"]
    for action in ap._actions:
        if isinstance(action, argparse._HelpAction):
            continue
        lines.append(_row(action))
    return "\n".join(lines) + "\n"


def generate() -> str:
    return HEADER + "\n" + "\n".join(render_tool(m) for m in TOOLS)


def default_path() -> Path:
    # src/repro/launch/cli_reference.py -> repo root -> docs/CLI.md
    return Path(__file__).resolve().parents[3] / "docs" / "CLI.md"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=str(default_path()))
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--write", action="store_true",
                      help="(re)generate the reference file")
    mode.add_argument("--check", action="store_true",
                      help="exit 1 if the file drifts from the parsers")
    args = ap.parse_args(argv)
    out = Path(args.out)
    text = generate()
    if args.write:
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(text)
        print(f"wrote {out} ({len(TOOLS)} tools)")
        return 0
    if not out.exists():
        print(f"{out}: missing — run with --write")
        return 1
    if out.read_text() != text:
        print(f"{out}: stale — a parser changed; run with --write")
        return 1
    print(f"{out}: up to date")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
