"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --batch 8 --prompt-len 64 --gen 16 --devices 8
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scheme", default="zero_topo")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-block", type=int, default=128)
    args = ap.parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.devices}"

    import time

    import jax
    import numpy as np
    from ..core.engine import TrainHparams, ZeroEngine
    from ..models.config import ShapeConfig
    from ..models.registry import build_model, get_arch
    from ..serve.engine import ServeEngine
    from .mesh import make_test_mesh, scheme_config

    mesh = make_test_mesh()
    arch = get_arch(args.arch).reduced()
    model = build_model(arch)
    cfg = scheme_config(args.scheme, mesh, quant_block=args.quant_block)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))

    total = args.prompt_len + args.gen
    shape = ShapeConfig("cli", total, args.batch, "decode")
    se = ServeEngine(model, eng, mesh, shape)
    rng = np.random.default_rng(0)
    st = args.prompt_len - (arch.n_patches or 0)
    batch = {"tokens": rng.integers(0, arch.vocab, (args.batch, st),
                                    dtype=np.int32)}
    if arch.n_patches:
        batch["patches"] = rng.standard_normal(
            (args.batch, arch.n_patches, arch.d_model)).astype(np.float32)
    if arch.enc_layers:
        batch["frames"] = rng.standard_normal(
            (args.batch, arch.n_frames, arch.d_model)).astype(np.float32)

    t0 = time.time()
    toks = se.generate(state, batch, args.gen)
    dt = time.time() - t0
    print(f"arch={arch.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    main()
