"""Serving launcher: continuous batching over the paged KV pool.

Two weight backends share the scheduler (DESIGN.md §12): ``gathered``
re-gathers fp weights per decoded token (the seed serving path) and
``resident`` serves from the INT8 wire residency built once from the
training engine's shards.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --backend resident --requests 16 --devices 8
    PYTHONPATH=src python -m repro.launch.serve --n-pages 6 \
        --max-queue-steps 8 --requests 64        # oversubscribed + SLO
"""
import argparse
import os


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Continuous-batching serving demo: paged KV pool + "
                    "SLO admission over the gathered or INT8-resident "
                    "weight backend")
    ap.add_argument("--arch", default="qwen2-0.5b",
                    help="registered architecture (reduced for CPU)")
    ap.add_argument("--scheme", default="zero_topo")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake CPU device count (XLA_FLAGS)")
    ap.add_argument("--quant-block", type=int, default=128)
    ap.add_argument("--backend", default="gathered",
                    choices=("gathered", "resident"),
                    help="weight path: fp re-gather per token, or the INT8 "
                         "wire residency")
    ap.add_argument("--res-axes", default="",
                    help="comma-separated residency axes (resident backend; "
                         "default: the scheme's secondary partition)")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of random requests to queue")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64,
                    help="per-slot KV provisioning length")
    ap.add_argument("--gen", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens (0 = auto)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="KV pool pages (0 = fully provisioned; fewer "
                         "oversubscribes and triggers preemption)")
    ap.add_argument("--max-queue-steps", type=int, default=0,
                    help="SLO: reject requests queued longer than N "
                         "scheduler steps (0 = never)")
    ap.add_argument("--reserve-pages", type=int, default=0,
                    help="SLO: keep N pages free when admitting")
    ap.add_argument("--metrics-jsonl", default="",
                    help="write per-step serving metrics (obs JSONL "
                         "schema; feeds dryrun --compare)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main():
    args = build_parser().parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.devices}"

    import time

    import jax
    import numpy as np
    from ..core.engine import TrainHparams, ZeroEngine
    from ..models.registry import build_model, get_arch
    from ..obs.metrics import SERVE_REQUIRED_FIELDS, MetricsWriter
    from ..serve.scheduler import ContinuousBatcher, Request, ServeSLO
    from .mesh import make_test_mesh, scheme_config

    mesh = make_test_mesh()
    arch = get_arch(args.arch).reduced()
    model = build_model(arch)
    cfg = scheme_config(args.scheme, mesh, quant_block=args.quant_block)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))

    res_axes = None
    if args.backend == "resident":
        from ..serve.resident import build_resident
        want = tuple(a for a in args.res_axes.split(",") if a) or None
        layout, params = build_resident(eng, state, mesh, want)
        res_axes = layout.res_axes
        rep = layout.memory_report()
        print(f"residency: axes={rep['res_axes']} degree={rep['res_degree']} "
              f"wire={rep['wire_bytes']}B dense={rep['dense_bytes']}B "
              f"per device")
    else:
        params = state["primaries"]

    metrics = MetricsWriter(args.metrics_jsonl,
                            fields=SERVE_REQUIRED_FIELDS) \
        if args.metrics_jsonl else None
    slo = ServeSLO(max_queue_steps=args.max_queue_steps,
                   reserve_pages=args.reserve_pages)
    cb = ContinuousBatcher(
        model, eng, mesh, n_slots=args.slots, max_len=args.max_len,
        prompt_len=args.prompt_len, page_size=args.page_size or None,
        n_pages=args.n_pages, slo=slo, backend=args.backend,
        res_axes=res_axes, metrics=metrics)
    print(f"paged pool: {cb.paged.n_pages} pages x {cb.paged.page_size} "
          f"tokens ({cb.paged.blocks_per_slot}/slot)")

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, arch.vocab, args.prompt_len)
                    .astype(np.int32),
                    max_new=args.gen) for i in range(args.requests)]
    t0 = time.time()
    cb.run(params, reqs)
    dt = time.time() - t0
    if metrics is not None:
        metrics.close()

    c = cb.counters
    tok = sum(len(r.out) for r in reqs)
    lat = cb.latency_percentiles()
    print(f"arch={arch.name} backend={args.backend} {args.requests} reqs "
          f"-> {tok} tokens in {dt:.2f}s ({tok / max(dt, 1e-9):.1f} tok/s, "
          f"{cb.step_count} steps)")
    print(f"admitted {c['admitted']} rejected {c['rejected']} "
          f"preempted {c['preempted']} retired {c['retired']}; "
          f"p50 {lat['p50_ms']:.1f}ms p99 {lat['p99_ms']:.1f}ms")
    done = next((r for r in reqs if r.out), None)
    if done is not None:
        print("sample:", done.out[:16])


if __name__ == "__main__":
    main()
