"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --scheme zero_topo --steps 100 --reduced --devices 8

``--reduced`` trains the smoke-scale variant on fake CPU devices (what this
container can run); on a real TPU pod drop it and pass --mesh prod.

Multi-process (one process per node/GCD; README "Multi-host quickstart"):
either pass --coordinator/--num-processes/--process-id explicitly, or let
SLURM / OpenMPI / REPRO_* env autodetection fill them in. ``--devices`` is
the *global* device count; each process brings its share.
"""
import argparse
import os

from .distributed import add_cli_args, from_args, initialize


def build_parser() -> argparse.ArgumentParser:
    """The launcher's full CLI surface (also rendered into docs/CLI.md by
    ``repro.launch.cli_reference``)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.train",
        description="training launcher (smoke-scale on fake CPU devices "
                    "with --reduced, or a real pod)")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scheme", default="zero_topo",
                    help="partition preset, or 'auto' to let the topology "
                         "planner (repro.topo) pick for the live mesh")
    ap.add_argument("--mesh", default="test")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant-block", type=int, default=128)
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered prefetch of the per-layer weight "
                         "all-gather (DESIGN.md §3)")
    ap.add_argument("--stream-grads", action="store_true",
                    help="streaming gradient path (DESIGN.md §8): per-layer "
                         "grad reduce-scatter fused into the backward, "
                         "microbatch grads accumulated in fp32 "
                         "optimizer-shard layout (grad buffer 4*psi/os "
                         "instead of 4*psi/w)")
    ap.add_argument("--kernel-impl", default=None,
                    choices=["jnp", "pallas", "pallas_interpret"],
                    help="quantization-kernel implementation (DESIGN.md §5):"
                         " jnp oracle (default), compiled Pallas (TPU), or"
                         " interpreted Pallas bodies (CPU validation)")
    ap.add_argument("--compute-dtype", default=None,
                    choices=["bfloat16", "float32"],
                    help="activation/primary dtype (default: the scheme's, "
                         "bf16). float32 also pins matmul precision — the "
                         "cross-process bitwise-comparison regime "
                         "(DESIGN.md §6; at bf16, or above XLA CPU's "
                         "threaded-reduction thresholds, layouts differ by "
                         "~1e-5 deterministic reassociation noise)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir; a "
                         "checkpoint written under a different mesh/process "
                         "layout or scheme is resharded onto the live one "
                         "(elastic restore, DESIGN.md §11)")
    ap.add_argument("--strict-restore", action="store_true",
                    help="with --resume: refuse any layout difference "
                         "(MeshMismatch/SchemeMismatch) instead of "
                         "resharding — the pre-elastic behavior")
    ap.add_argument("--budget-gb", type=float, default=0.0,
                    help="--scheme auto: per-device memory budget in GB "
                         "(0 = unbounded; fake CPU devices have no real HBM)")
    ap.add_argument("--log-json", default="")
    g = ap.add_argument_group(
        "observability", "opt-in runtime tracing (DESIGN.md §10); without "
        "--trace the monolithic step runs untouched and every bitwise "
        "contract holds")
    g.add_argument("--trace", action="store_true",
                   help="run the phased fenced step: per-phase spans, "
                        "comm-attribution probes, JSONL metrics stream")
    g.add_argument("--metrics-jsonl", default="",
                   help="per-step JSONL metrics path (multi-process runs "
                        "write per-rank .rank<k> lanes next to it)")
    g.add_argument("--chrome-trace", default="",
                   help="write collected spans as a Chrome/Perfetto "
                        "trace.json at end of run")
    g.add_argument("--heartbeat-dir", default="",
                   help="per-rank heartbeat files + straggler report "
                        "(launch.distributed.Heartbeat)")
    g.add_argument("--probe-every", type=int, default=4,
                   help="steps between out-of-band comm-attribution probe "
                        "runs (0 disables probes)")
    add_cli_args(ap)
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    dcfg = from_args(args)
    n_fake = args.devices if args.mesh == "test" else 512
    if n_fake % dcfg.num_processes:
        ap.error(f"--devices {n_fake} not divisible by the "
                 f"{dcfg.num_processes} processes ({dcfg.source})")
    # rendezvous (no-op single-process) BEFORE the first jax device access;
    # each process only forces its local share of the fake CPU devices
    initialize(dcfg, local_devices=n_fake // dcfg.num_processes)
    log0 = print if dcfg.process_id == 0 else (lambda *a, **k: None)

    import jax
    if args.compute_dtype == "float32":
        jax.config.update("jax_default_matmul_precision", "float32")
    if args.kernel_impl:
        # process default: covers every config built from here on (the
        # explicit per-config override below pins the engine's own cfg)
        from ..kernels import ops as kernel_ops
        kernel_ops.set_default_impl(args.kernel_impl)
    from ..core.engine import TrainHparams, ZeroEngine
    from ..models.config import ShapeConfig, SHAPES
    from ..models.registry import build_model, get_arch
    from ..train.trainer import Trainer
    from .mesh import make_production_mesh, make_test_mesh, make_topo_mesh, \
        scheme_config

    mesh = {"test": lambda: make_test_mesh(),
            "prod": lambda: make_production_mesh(),
            "topo": lambda: make_topo_mesh()}[args.mesh]()
    arch = get_arch(args.arch)
    if args.reduced or args.mesh == "test":
        arch = arch.reduced()
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
    else:
        shape = SHAPES["train_4k"]

    model = build_model(arch)
    planner_kw = {}
    if args.scheme == "auto":
        # workload for the planner: the real model on the live mesh
        planner_kw = dict(psi=model.param_count(), n_layers=arch.n_layers,
                          memory_budget=args.budget_gb * 1e9
                          if args.budget_gb else None)
    dtype_kw = {"compute_dtype": args.compute_dtype} \
        if args.compute_dtype else {}
    cfg = scheme_config(args.scheme, mesh, quant_block=args.quant_block,
                        overlap=args.overlap, stream_grads=args.stream_grads,
                        impl=args.kernel_impl, **dtype_kw, **planner_kw)
    if args.scheme == "auto":
        a = cfg.axes
        log0(f"planner choice: w={a.weight} e={a.extra_grad} r={a.replica} "
             f"sec={a.secondary} int8w={cfg.quantize_weights} "
             f"int4g={cfg.quantize_grads}")
    hp = TrainHparams(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 2),
                      overlap=args.overlap, stream_grads=args.stream_grads)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, hp)
    log0(f"arch={arch.name} scheme={cfg.name} mesh={dict(mesh.shape)} "
         f"params={eng.param_count():,} overlap={eng.cfg.overlap} "
         f"stream_grads={eng.cfg.stream_grads} "
         f"kernel_impl={eng.cfg.impl or 'jnp'} "
         f"processes={dcfg.num_processes} ({dcfg.source})")
    log0(f"per-device state bytes: {eng.memory_report()}")

    trace = None
    if args.trace or args.metrics_jsonl or args.chrome_trace \
            or args.heartbeat_dir:
        from ..obs.spans import TraceConfig
        trace = TraceConfig(metrics_path=args.metrics_jsonl or None,
                            chrome_trace=args.chrome_trace or None,
                            heartbeat_dir=args.heartbeat_dir or None,
                            probe_every=args.probe_every)
        log0(f"trace mode: phased fenced step (float-close, NOT bitwise, "
             f"to the fused step) probes_every={args.probe_every}")

    from ..train.trainer import _host_int
    tr = Trainer(model, eng, mesh, shape, trace=trace)
    if args.resume and args.ckpt_dir:
        state = tr.restore(args.ckpt_dir, reshard=not args.strict_restore)
        log0(f"resumed from step {_host_int(state['step'])}"
             + ("" if args.strict_restore else " (elastic restore enabled)"))
    else:
        state = eng.init_state(jax.random.key(0))
    state = tr.run(state, args.steps,
                   ckpt_dir=args.ckpt_dir or None,
                   ckpt_every=args.ckpt_every,
                   print_fn=log0)
    if args.log_json and dcfg.process_id == 0:
        tr.log.save(args.log_json)
    if args.heartbeat_dir:
        from .distributed import heartbeat
        log0(heartbeat(args.heartbeat_dir).format_report())
    agg = tr.log.aggregates()
    if agg.get("n_timed_steps"):
        log0(f"throughput (excl. compile step): "
             f"{agg['tokens_per_s_mean']:.0f} tok/s, "
             f"{agg['tflops_per_gpu_mean']:.3f} model-TFLOPS/GPU")
    log0(f"final loss: {tr.log.losses[-1]}")


if __name__ == "__main__":
    main()
