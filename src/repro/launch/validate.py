"""Close the loop: measured collective traffic (compiled-HLO census) vs the
paper's analytic Tables VII/VIII, per scheme.

    PYTHONPATH=src python -m repro.launch.validate --arch gpt-neox-20b

For each phase we compare the census' per-group wire bytes against the
analytic model built from the engine's actual padded sizes:

  fwd+bwd weight all-gather   n_passes * psi_pad * bytes_w * (d-1)/d
  gradient reduce-scatter     psi_pad * bytes_g * (d-1)/d  (a2a-based)
  cross-replica sync          2 * (psi_pad/g) * (r-1)/r * 4   (allreduce)
  update all-gather           (psi_pad/w) * bytes_u * (1 - w/os)

Remat makes the backward re-gather run twice (checkpointed blocks recompute
their forward), so n_passes = 3 for gathered weights. The check asserts
measured/analytic within a factor window and prints the detailed split.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def analytic(engine, cfg, n_passes_gather: float = 3.0) -> dict[str, float]:
    """Expected per-device wire bytes per step from the engine's real sizes."""
    psi = engine.padded_param_count()
    w, g, os_ = cfg.w_degree, cfg.g_degree, cfg.os_degree
    r = cfg.size(cfg.axes.replica)
    bytes_w = 1.0 if cfg.quantize_weights else 2.0
    # quantized INT4 grads: 0.5 B payload (+ scales, small); else fp32 RS
    bytes_g = 0.5 if cfg.quantize_grads else 4.0
    out = {}
    out["weight_gathers"] = n_passes_gather * psi * bytes_w * (w - 1) / w \
        if w > 1 else 0.0
    if cfg.axes.secondary is not None and cfg.sec_degree and w == 1:
        out["weight_gathers"] = 0.0
    out["grad_rs"] = psi * bytes_g * (g - 1) / g if g > 1 else 0.0
    out["cross_replica"] = 2.0 * (psi / g) * 4.0 * (r - 1) / r if r > 1 else 0.0
    upd_axes = cfg.axes.extra_grad + cfg.axes.replica
    d_upd = cfg.size(upd_axes)
    bytes_u = 1.0 if cfg.quantize_update_gather else 2.0
    out["update_gather"] = (psi / w) * bytes_u * (1 - 1 / d_upd) \
        if d_upd > 1 else 0.0
    out["total"] = sum(out.values())
    return out


def compare(arch: str, scheme: str, rec_path: Path, print_fn=print,
            window=(0.5, 2.0)) -> bool:
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from ..core.engine import TrainHparams, ZeroEngine
    from ..models.registry import build_model, get_arch
    from .mesh import make_production_mesh, scheme_config

    rec = json.loads(rec_path.read_text())
    mesh = make_production_mesh(multi_pod=(rec["mesh"] == "prod_mp"))
    arch_cfg = get_arch(arch)
    model = build_model(arch_cfg)
    cfg = scheme_config(scheme, mesh, quant_block=2048)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())

    a = analytic(eng, cfg)
    measured = rec["census"]["total_wire_bytes"]
    ratio = measured / max(a["total"], 1.0)
    print_fn(f"{arch} {scheme} ({rec['mesh']}):")
    for k, v in a.items():
        print_fn(f"  analytic {k:16s} {v / 1e9:8.2f} GB")
    print_fn(f"  measured total         {measured / 1e9:8.2f} GB "
             f"(ratio {ratio:.2f}; window {window})")
    ok = window[0] <= ratio <= window[1]
    if not ok:
        print_fn("  !! outside window — investigate")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-neox-20b")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    d = Path(args.dir)
    ok = True
    for scheme in ("zero3", "zeropp", "zero_topo"):
        p = d / f"{args.arch}__train_4k__prod__{scheme}.json"
        if p.exists():
            ok &= compare(args.arch, scheme, p)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
