"""Structured per-step metrics stream (JSONL) for trained/traced runs.

One JSON object per line per step. Cluster-global scalars (loss, gnorm,
tokens) arrive already reduced through the engine's ``det_psum`` path;
host-only fields (per-phase ms, memory high-water) are per-process, so in
multi-process runs every rank writes its own *lane* — ``<stem>.rank<k>``
suffixed files — and readers merge on ``(step, rank)``. The schema below is
the contract README documents and tests/test_obs.py round-trips; the CI
``obs`` leg gates its field list (not its values) in ``BENCH_obs.json``.
"""
from __future__ import annotations

import json
from pathlib import Path

# every record carries these; absence is a schema violation
REQUIRED_FIELDS = (
    "step", "rank", "loss", "grad_norm", "lr", "tokens",
    "dt_s", "tokens_per_s", "tflops_per_gpu",
    "phase_ms", "overlap_efficiency",
    "memory_hw_bytes", "memory_pred_bytes",
)

# serving runs (serve/scheduler.py) write the same JSONL transport with a
# serving schema: throughput + queue/SLO state per scheduler step. Readers
# auto-detect by the presence of "loss" (train) vs "queue_depth" (serve);
# the CI serve leg gates this field list in BENCH_serve.json
SERVE_REQUIRED_FIELDS = (
    "step", "rank", "tokens", "dt_s", "tokens_per_s",
    "queue_depth", "active_slots",
    "admitted", "rejected", "preempted", "retired", "free_pages",
    "p50_ms", "p99_ms", "phase_ms",
)


def _fields_for(rec: dict) -> tuple[str, ...]:
    return REQUIRED_FIELDS if "loss" in rec else SERVE_REQUIRED_FIELDS


def model_flops_per_token(param_count: int) -> float:
    """Dense-transformer step FLOPs per token: 6·N (fwd 2·N + bwd 4·N) —
    the same accounting as topo.cost.tflops_per_device and
    benchmarks/scaling_model.py (cross-checked in tests/test_obs.py)."""
    return 6.0 * float(param_count)


def tflops_per_gpu(param_count: int, tokens: float, dt_s: float,
                   n_devices: int) -> float:
    """Achieved model-TFLOPS per device for one step: ``tokens`` is the
    cluster-global token count, so divide the FLOP total across devices."""
    if dt_s <= 0.0 or n_devices <= 0:
        return 0.0
    return model_flops_per_token(param_count) * tokens / dt_s / n_devices / 1e12


def lane_path(path, rank: int, n_ranks: int) -> Path:
    """Single-process runs write ``path`` itself; multi-process runs write
    per-rank lanes next to it so no cross-process file contention exists."""
    p = Path(path)
    if n_ranks <= 1:
        return p
    return p.with_name(f"{p.stem}.rank{rank}{p.suffix}")


class MetricsWriter:
    """Append-mode JSONL writer; one instance per process/lane.

    ``fields`` selects the schema contract each record must satisfy:
    ``REQUIRED_FIELDS`` (train, the default) or ``SERVE_REQUIRED_FIELDS``
    (the continuous batcher's per-step stream)."""

    def __init__(self, path, rank: int = 0, n_ranks: int = 1,
                 fields: tuple[str, ...] = REQUIRED_FIELDS):
        self.rank = rank
        self.fields = fields
        self.path = lane_path(path, rank, n_ranks)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "w")

    def write(self, record: dict) -> dict:
        rec = dict(record)
        rec.setdefault("rank", self.rank)
        missing = [k for k in self.fields if k not in rec]
        if missing:
            raise ValueError(f"metrics record missing fields: {missing}")
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self._fh.flush()
        return rec

    def close(self):
        self._fh.close()


def read_jsonl(path, fields: tuple[str, ...] | None = None) -> list[dict]:
    """Read one metrics lane, validating the schema per line.

    ``fields=None`` auto-detects train vs serve records per line, so mixed
    tooling (``dryrun --compare``, the calibration loop) reads both."""
    records = []
    for line in Path(path).read_text().splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        want = fields if fields is not None else _fields_for(rec)
        missing = [k for k in want if k not in rec]
        if missing:
            raise ValueError(f"{path}: record missing fields: {missing}")
        records.append(rec)
    return records


def read_lanes(path, fields: tuple[str, ...] | None = None) -> list[dict]:
    """Read a metrics stem plus any ``.rank<k>`` lanes, merged and sorted
    by (step, rank)."""
    p = Path(path)
    records = []
    if p.exists():
        records += read_jsonl(p, fields)
    for lane in sorted(p.parent.glob(f"{p.stem}.rank*{p.suffix}")):
        records += read_jsonl(lane, fields)
    return sorted(records, key=lambda r: (r["step"], r["rank"]))


def aggregates(records: list[dict]) -> dict:
    """Run-level throughput summary. The first recorded step is the compile
    step — its dt includes tracing+compilation and would skew every rate —
    so throughput/dt aggregates exclude it (satellite: TrainLog discipline).
    Loss/gnorm means keep all steps."""
    if not records:
        return {}
    steps = sorted({r["step"] for r in records})
    post = [r for r in records if r["step"] != steps[0]] or records
    mean = lambda rows, k: sum(r[k] for r in rows) / len(rows)  # noqa: E731
    return dict(
        n_steps=len(steps),
        n_timed_steps=len(sorted({r["step"] for r in post})),
        loss_mean=mean(records, "loss"),
        grad_norm_mean=mean(records, "grad_norm"),
        dt_s_mean=mean(post, "dt_s"),
        tokens_per_s_mean=mean(post, "tokens_per_s"),
        tflops_per_gpu_mean=mean(post, "tflops_per_gpu"),
    )


def serve_aggregates(records: list[dict]) -> dict:
    """Run-level serving summary from a serve-schema lane: totals from the
    final record's monotone counters, rates excluding the compile step
    (first record), latency percentiles from the last record that saw a
    completion."""
    if not records:
        return {}
    last = records[-1]
    post = records[1:] or records
    tok = sum(r["tokens"] for r in post)
    dt = sum(r["dt_s"] for r in post)
    return dict(
        n_steps=len(records),
        tokens=sum(r["tokens"] for r in records),
        tokens_per_s=(tok / dt if dt > 0 else 0.0),
        admitted=last["admitted"], rejected=last["rejected"],
        preempted=last["preempted"], retired=last["retired"],
        queue_depth_max=max(r["queue_depth"] for r in records),
        p50_ms=last["p50_ms"], p99_ms=last["p99_ms"],
    )


def last_phase_ms(records: list[dict]) -> dict[str, float]:
    """Per-phase ms from the last record that carries a non-empty
    ``phase_ms`` (used by ``launch/dryrun.py --compare``)."""
    for rec in reversed(records):
        if rec.get("phase_ms"):
            return {k: float(v) for k, v in rec["phase_ms"].items()}
    return {}


def memory_high_water() -> int:
    """Peak device-memory bytes across live devices, 0 where the backend
    does not expose memory stats (CPU fake devices)."""
    import jax
    peak = 0
    for d in jax.local_devices():
        stats = getattr(d, "memory_stats", None)
        try:
            ms = stats() if stats else None
        except Exception:
            ms = None
        if ms:
            peak = max(peak, int(ms.get("peak_bytes_in_use",
                                        ms.get("bytes_in_use", 0))))
    return peak
