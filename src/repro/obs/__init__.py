"""Runtime observability (DESIGN.md §10): phase spans, metrics stream,
heartbeat stall detection, and the measured-vs-predicted calibration loop.

Import surface is deliberately thin — ``spans``/``metrics``/``heartbeat``
are stdlib(+lazy jax) only, safe to import from any layer including
``core.schedule``. The heavyweight pieces (``obs.phased`` builds jitted
segments; ``obs.calibrate`` is a CLI) are imported as submodules by their
consumers, never here, to keep import cycles impossible.
"""
from . import heartbeat, metrics, spans
from .spans import SpanRecorder, TraceConfig, scope, tracing

__all__ = [
    "spans", "metrics", "heartbeat",
    "SpanRecorder", "TraceConfig", "scope", "tracing",
]
