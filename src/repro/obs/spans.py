"""Phase spans: host-side fenced timers + profiler annotations, dead by default.

The engine's step is one fused jit program — nothing inside it can be timed
from the host. Trace mode (``--trace``, DESIGN.md §10) therefore runs the
*phased* step (``obs.phased``): the same math split at the schedule's
machine boundaries into separately-jitted segments, each executed under a
``SpanRecorder.fenced`` timer that blocks until every output is ready before
reading the clock. The segment boundaries are exactly the issue/wait/sink
sites ``analysis/tags.py`` enumerates; ``site_inventory`` re-derives that
census from a tagged trace so the obs layer and the static verifier can
never disagree about what the schedule contains.

Discipline (same as ``contract_tag``): everything here is OFF unless running
under the ``tracing()`` context. ``scope()`` returns a null context and no
profiler annotation is emitted, so the production step's jaxpr, HLO, jit
cache key — and every bitwise CI contract — are byte-identical to a build
without this module. Trace mode itself is *excluded* from the bitwise
contract: fencing changes XLA's fusion boundaries, so traced losses are
only required to agree with the seed step within float tolerance
(tests/_scenarios.py ``obs_trace_equivalence`` pins both properties).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

# top-level segments of the phased step: fenced, directly measured, and
# summing to the traced step's wall time (the 10% acceptance bound)
SEGMENTS = ("fwd_bwd", "grad_rs_e", "cross_replica", "gnorm_clip", "update")
# attribution probes (obs.phased.run_probes): serial re-executions of the
# in-loop collectives, measured out-of-band and NOT counted in the wall sum
PROBES = ("fwd", "fwd_allgather", "bwd_allgather", "grad_rs_w",
          "update_gather")

_state = threading.local()


def enabled() -> bool:
    return getattr(_state, "on", False)


class tracing:
    """Context manager enabling span scopes/annotations for code run inside
    it (thread-local, re-entrant — the ``tagging()`` discipline)."""

    def __enter__(self):
        self._prev = enabled()
        _state.on = True
        return self

    def __exit__(self, *exc):
        _state.on = self._prev
        return False


def scope(name: str):
    """``jax.named_scope("obs.<name>")`` under ``tracing()``, else a null
    context — so schedule-layer call sites (core/schedule.py) can annotate
    their issue/wait halves without perturbing production traces."""
    if not enabled():
        return contextlib.nullcontext()
    import jax
    return jax.named_scope(f"obs.{name}")


def _annotation(name: str):
    """Host-side profiler annotation (shows up in jax.profiler traces)."""
    import jax
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    if ta is None:
        return contextlib.nullcontext()
    return ta(f"obs.{name}")


@dataclass
class Span:
    name: str
    t0: float        # process-relative seconds (time.perf_counter)
    dur: float       # seconds
    step: int = -1


@dataclass
class SpanRecorder:
    """Collects fenced spans; one recorder spans a whole traced run (the
    ``step`` attribute is bumped per step so Chrome export can lane them)."""
    step: int = -1
    spans: list[Span] = field(default_factory=list)

    def fenced(self, name: str, fn, *args):
        """Run ``fn(*args)``, block until every output is device-ready, and
        record the wall duration as one span. The fence is the point of the
        phased step: without it XLA's async dispatch would attribute every
        phase's time to whichever call finally blocks."""
        import jax
        t0 = time.perf_counter()
        with _annotation(name):
            out = fn(*args)
            jax.block_until_ready(out)
        self.spans.append(Span(name, t0, time.perf_counter() - t0, self.step))
        return out

    def timed(self, name: str, seconds: float):
        """Record an externally-measured duration (probe aggregates)."""
        self.spans.append(Span(name, time.perf_counter() - seconds,
                               seconds, self.step))

    def step_seconds(self, step: int) -> dict[str, float]:
        """Per-name summed seconds for one step."""
        out: dict[str, float] = {}
        for s in self.spans:
            if s.step == step:
                out[s.name] = out.get(s.name, 0.0) + s.dur
        return out

    def chrome_events(self, rank: int = 0) -> list[dict]:
        """Chrome/Perfetto ``traceEvents`` (complete events, us units):
        pid = rank, tid = span name, args carry the step index."""
        return [dict(name=s.name, ph="X", ts=s.t0 * 1e6, dur=s.dur * 1e6,
                     pid=rank, tid=s.name, args={"step": s.step})
                for s in self.spans]


def write_chrome_trace(events: list[dict], path) -> str:
    """Write a chrome://tracing / Perfetto-loadable trace.json."""
    Path(path).write_text(json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}))
    return str(path)


def site_inventory(step_fn, *abstract_args) -> dict[str, int]:
    """Schedule-site census of a traced step: ``{machine/role: count}`` of
    every contract-tag site, by tracing under ``analysis.tags.tagging()``
    and counting tag primitives — the same counter the static verifier's
    census uses (``analysis.dataflow``), so the two inventories are equal by
    construction (tests/test_obs.py pins it)."""
    import jax

    from ..analysis import tags
    from ..analysis.dataflow import _count_tags
    with tags.tagging():
        jx = jax.make_jaxpr(step_fn)(*abstract_args)
    return {k: int(v) for k, v in sorted(_count_tags(jx.jaxpr).items())}


@dataclass
class TraceConfig:
    """Opt-in runtime tracing for Trainer.run (launch/train.py ``--trace``).

    ``probe_every``: cadence (in steps) of the serial comm-attribution
    probes; 0 disables them. ``heartbeat_dir`` enables the per-rank stall
    detector (obs.heartbeat via launch.distributed.heartbeat). Trace mode is
    excluded from the bitwise contract (DESIGN.md §10) — with ``trace=None``
    the Trainer runs the untouched monolithic step.
    """
    metrics_path: str | None = None     # JSONL stream (obs.metrics)
    chrome_trace: str | None = None     # trace.json written at end of run
    heartbeat_dir: str | None = None    # per-rank heartbeat files
    probe_every: int = 4                # 0 = never run attribution probes
