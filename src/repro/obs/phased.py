"""The phased traced step: the engine's train step, fenceable per phase.

The production step is one fused jit program; nothing inside it is
host-timeable. Under ``--trace`` the Trainer swaps in ``PhasedStep``: the
same math, built from the engine's own pieces (``_make_local_grads``,
``_stage2_rs``, ``_replica_sync``, ``_clip_grads``, ``_apply_updates``),
split into separately-jitted ``shard_map`` segments at exactly the
boundaries the cost model prices (``topo.cost.PHASES``), each run under
``SpanRecorder.fenced``. Segment sum ≈ step wall time by construction (the
acceptance bound); fencing changes XLA's fusion, so traced runs are
float-close, not bitwise, to the seed step — which is why ``--trace`` off
keeps the untouched monolithic step (DESIGN.md §10).

Inter-segment gradient arrays use ``engine._os_spec`` — sharded over **all**
mesh axes — even for primary-layout grads: seed-regime grads are
device-varying over the E/R axes (the deferred hierarchical sync), so any
spec that nominally replicates them would corrupt the round-trip between
segments. Sharding over every axis makes each device's local block travel
untouched.

The in-loop collectives (per-layer weight gathers, stage-1 grad RS) cannot
be fenced — they live inside ``lax.scan``. ``run_probes`` measures them
out-of-band: serial re-executions of each collective over the real stacked
primaries (one per layer, so XLA cannot hoist a loop-invariant gather),
reduced to a scalar so only the collective's cost is timed. Probe spans are
attribution only — they are NOT part of the wall-time sum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core import collectives as col
from ..core.partition import GATHER_Q, MATMUL
from .spans import PROBES, SpanRecorder, tracing


class PhasedStep:
    """Fenceable train step for one engine + loss_fn (trace mode only)."""

    def __init__(self, engine, loss_fn, batch_specs):
        self.eng = eng = engine
        cfg = eng.cfg
        self.stream = cfg.stream_grads
        self.names = sorted(eng.specs)
        snames = set(eng.stream_leaf_names()) if self.stream else set()
        # legacy = primary-layout grads (seed path); streamed sinks arrive
        # from the backward already reduced to os layout
        self.legacy = [n for n in self.names if n not in snames]
        self.sink_names = sorted(snames)

        state_specs = eng.state_in_specs()
        # every inter-segment grad leaf: sharded over ALL axes (see module
        # docstring — device-varying blocks must round-trip untouched)
        gspec = {n: eng._os_spec(eng.specs[n]) for n in self.names}
        leg_spec = {n: gspec[n] for n in self.legacy}
        sink_spec = {n: gspec[n] for n in self.sink_names}
        local_grads = eng._make_local_grads(loss_fn)
        stream = self.stream

        def sm(fn, in_specs, out_specs, **jit_kw):
            return jax.jit(shard_map(fn, mesh=eng.mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False),
                           **jit_kw)

        # -- segment: fwd_bwd (microbatch loop, grads in diff layout) ------
        def seg_grads(state, batch):
            grads, loss_rep, gtok = local_grads(state["primaries"], batch)
            g_legacy, g_sinks = grads if stream else (grads, {})
            return dict(g_legacy), dict(g_sinks), loss_rep, gtok

        self._grads = sm(seg_grads, (state_specs, batch_specs),
                         (leg_spec, sink_spec, P(), P()))

        # -- segment: grad_rs_e (stage-2 RS over the extra-grad axes) ------
        def seg_stage2(g_legacy):
            return {n: eng._stage2_rs(n, g) for n, g in g_legacy.items()}

        self._stage2 = sm(seg_stage2, (leg_spec,), leg_spec)

        # -- segment: cross_replica (stage-3 replica sync) -----------------
        def seg_cross(g2):
            return {n: eng._replica_sync(n, g) for n, g in g2.items()}

        self._cross = sm(seg_cross, (leg_spec,), leg_spec)

        # -- segment: gnorm_clip -------------------------------------------
        def seg_clip(os_grads):
            return eng._clip_grads(os_grads)

        self._clip = sm(seg_clip, (gspec,), (gspec, P()))

        # -- segment: update (AdamW + update all-gather) -------------------
        def seg_update(state, os_grads):
            return eng._apply_updates(state, os_grads)

        self._update = sm(seg_update, (state_specs, gspec),
                          (state_specs, P()), donate_argnums=(0,))

        # -- out-of-band probes --------------------------------------------
        self._eval = eng.make_eval_step(loss_fn, batch_specs)
        self._build_probes()

    def __call__(self, state, batch, rec: SpanRecorder):
        """One fenced step; same (new_state, metrics) as the seed step."""
        with tracing():
            g_leg, g_sink, loss_rep, gtok = rec.fenced(
                "fwd_bwd", self._grads, state, batch)
            if self.legacy:
                g_leg = rec.fenced("grad_rs_e", self._stage2, g_leg)
                g_leg = rec.fenced("cross_replica", self._cross, g_leg)
            os_grads = {n: g_leg[n] if n in g_leg else g_sink[n]
                        for n in self.names}
            os_grads, gnorm = rec.fenced("gnorm_clip", self._clip, os_grads)
            new_state, lr = rec.fenced("update", self._update,
                                       state, os_grads)
        metrics = dict(loss=loss_rep, grad_norm=gnorm, lr=lr, tokens=gtok)
        return new_state, metrics

    # -- probes -------------------------------------------------------------

    def _build_probes(self):
        eng = self.eng
        cdt = jnp.dtype(eng.cfg.compute_dtype)
        prim_specs = eng.state_in_specs()["primaries"]
        os_specs = eng.state_in_specs()["master"]
        # stacked leaves with an issue() half: the layer loop's gathers
        self.pf = [n for n in self.names
                   if eng.specs[n].stack and eng.fns[n].issue is not None]
        self.rs_leaves = [n for n in self.names
                          if eng.specs[n].stack
                          and eng.specs[n].kind in (MATMUL, GATHER_Q)]

        def sm(fn, names, specs):
            return jax.jit(shard_map(
                fn, mesh=eng.mesh,
                in_specs=({n: specs[n] for n in names},),
                out_specs=P(), check_vma=False))

        def checksum(tree):
            return sum(jnp.sum(leaf.astype(jnp.float32))
                       for leaf in jax.tree.leaves(tree))

        # fwd_allgather: scan the real per-layer gather issue over the
        # stacked primaries — one collective per layer, each layer's input
        # distinct, so nothing is hoistable or CSE-able
        def probe_fwd_ag(prims):
            total = jnp.zeros((), jnp.float32)
            for n in self.pf:
                def body(c, row, n=n):
                    return c + checksum(eng.fns[n].issue(row)), None
                s, _ = lax.scan(body, jnp.zeros((), jnp.float32), prims[n])
                total = total + s
            return total

        self._p_fwd_ag = sm(probe_fwd_ag, self.pf, prim_specs) \
            if self.pf else None

        # bwd_allgather: the backward re-materialization. With a secondary
        # partition, gather the wire-format secondary shards (synthesized
        # per layer from the real primary row — values are irrelevant to
        # timing, per-layer variation defeats CSE); without one the
        # backward re-runs the primary gather, so reuse the issue probe.
        def probe_bwd_ag(prims):
            total = jnp.zeros((), jnp.float32)
            for n in self.rs_leaves:
                lcfg = eng.leaf_cfg[n]
                if lcfg.axes.secondary is None:
                    if eng.fns[n].issue is None:
                        continue

                    def body(c, row, n=n):
                        return c + checksum(eng.fns[n].issue(row)), None
                else:
                    pad = eng._pad[n]
                    sec_len = pad // lcfg.sec_degree
                    n_scales = pad // lcfg.quant_block // lcfg.sec_degree

                    def body(c, row, lcfg=lcfg, sec_len=sec_len,
                             n_scales=n_scales):
                        base = row.astype(jnp.float32)
                        sq = jnp.resize(base, (sec_len,)).astype(jnp.int8)
                        ss = jnp.abs(jnp.resize(base, (n_scales,))) + 1.0
                        out = col.gather_secondary_q(
                            sq, ss, lcfg.axes.secondary, lcfg)
                        return c + checksum(out), None
                s, _ = lax.scan(body, jnp.zeros((), jnp.float32), prims[n])
                total = total + s
            return total

        self._p_bwd_ag = sm(probe_bwd_ag, self.rs_leaves, prim_specs) \
            if self.rs_leaves else None

        # grad_rs_w: stage-1 dense-grad reduce-scatter over the W axes, one
        # per layer per backward — dense row synthesized from the primary
        def probe_grs_w(prims):
            total = jnp.zeros((), jnp.float32)
            for n in self.rs_leaves:
                lcfg = eng.leaf_cfg[n]
                pad = eng._pad[n]

                def body(c, row, lcfg=lcfg, pad=pad):
                    g = jnp.resize(row.astype(jnp.float32), (pad,))
                    out = col.reduce_scatter_flat(g, lcfg.axes.weight, lcfg)
                    return c + jnp.sum(out), None
                s, _ = lax.scan(body, jnp.zeros((), jnp.float32), prims[n])
                total = total + s
            return total

        self._p_grs_w = sm(probe_grs_w, self.rs_leaves, prim_specs) \
            if self.rs_leaves else None

        # update_gather: the real per-leaf update all-gather over E+R
        def probe_upd(master):
            return sum(
                (checksum(col.update_all_gather(master[n],
                                                eng.leaf_cfg[n], cdt))
                 for n in self.names),
                jnp.zeros((), jnp.float32))

        self._p_upd = sm(probe_upd, self.names, os_specs)

    def run_probes(self, state, batch, rec: SpanRecorder):
        """Out-of-band comm attribution: serial re-execution of each
        collective family, fenced individually. Records one span per probe
        (NOT summed into the wall-time budget)."""
        prim = state["primaries"]
        with tracing():
            rec.fenced("fwd", self._eval, state, batch)
            if self._p_fwd_ag is not None:
                rec.fenced("fwd_allgather", self._p_fwd_ag,
                           {n: prim[n] for n in self.pf})
            if self._p_bwd_ag is not None:
                rec.fenced("bwd_allgather", self._p_bwd_ag,
                           {n: prim[n] for n in self.rs_leaves})
            if self._p_grs_w is not None:
                rec.fenced("grad_rs_w", self._p_grs_w,
                           {n: prim[n] for n in self.rs_leaves})
            rec.fenced("update_gather", self._p_upd, state["master"])

    def probe_inventory(self) -> dict:
        """Deterministic description of what the probes execute — gated in
        BENCH_obs.json (structure, never wall-clock)."""
        eng = self.eng
        layers = {n: int(eng.specs[n].stack or 0) for n in self.rs_leaves}
        return dict(
            fwd_allgather=dict(leaves=list(self.pf),
                               layers=sum(layers.get(n, 0)
                                          for n in self.pf)),
            bwd_allgather=dict(
                leaves=list(self.rs_leaves),
                secondary=[n for n in self.rs_leaves
                           if eng.leaf_cfg[n].axes.secondary is not None]),
            grad_rs_w=dict(leaves=list(self.rs_leaves),
                           layers=sum(layers.values())),
            update_gather=dict(leaves=list(self.names)),
        )

    # -- measured phase attribution -----------------------------------------

    def phase_seconds(self, rec: SpanRecorder, step: int,
                      probe: dict[str, float] | None = None) -> dict:
        """Map one step's fenced segments (+ the latest probe measurements)
        onto the cost model's phase names (``topo.cost.PHASES``) plus
        ``compute``. In-loop probes measure one microbatch's collectives, so
        they scale by n_microbatch; ``compute`` is the fwd_bwd segment minus
        the in-loop comm estimate (floored at 0 — on overlap schedules the
        comm is partially hidden inside that same segment)."""
        seg = rec.step_seconds(step)
        probe = probe if probe is not None else self.last_probe(rec)
        n_mb = self.eng.hp.n_microbatch
        out = {}
        for ph in ("fwd_allgather", "bwd_allgather", "grad_rs_w"):
            out[ph] = n_mb * probe.get(ph, 0.0)
        out["grad_rs_e"] = seg.get("grad_rs_e", 0.0)
        out["cross_replica"] = seg.get("cross_replica", 0.0)
        # the update segment is AdamW + gather; the probe isolates the
        # gather share when available, capped by the measured segment
        upd_seg = seg.get("update", 0.0)
        out["update_gather"] = min(probe["update_gather"], upd_seg) \
            if "update_gather" in probe else upd_seg
        in_loop = sum(out[ph] for ph in
                      ("fwd_allgather", "bwd_allgather", "grad_rs_w"))
        out["compute"] = max(seg.get("fwd_bwd", 0.0) - in_loop, 0.0)
        return out

    def last_probe(self, rec: SpanRecorder) -> dict[str, float]:
        """Most recent measurement of each probe span, any step."""
        out: dict[str, float] = {}
        for s in rec.spans:
            if s.name in PROBES:
                out[s.name] = s.dur
        return out

    def overlap_efficiency(self, rec: SpanRecorder, step: int) -> float:
        """Fraction of measured comm time that sits in the *overlappable*
        in-loop region rather than the structurally-serial post-backward
        tail. Measurement-only (no model input); the calibrate CLI's A/B
        run measures how much of the in-loop share is actually hidden."""
        ph = self.phase_seconds(rec, step)
        hideable = (ph["fwd_allgather"] + ph["bwd_allgather"]
                    + ph["grad_rs_w"])
        exposed = ph["grad_rs_e"] + ph["cross_replica"] + ph["update_gather"]
        total = hideable + exposed
        return hideable / total if total > 0 else 0.0
