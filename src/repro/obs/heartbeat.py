"""Rank heartbeat / stall detector for multi-process trace mode.

Every collective in the step is a barrier: one slow or dead rank stalls the
whole cluster with no indication of *which* rank. In trace mode each
process stamps a tiny heartbeat file before every step; any process (or a
human with ``ls``) can then read all stamps and produce a straggler report
naming the rank that is behind or silent. Stamps are written atomically
(tmp + rename) so a reader never sees a torn JSON.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path


def stamp_path(directory, rank: int) -> Path:
    return Path(directory) / f"heartbeat.rank{rank}.json"


def stamp(directory, rank: int, step: int) -> Path:
    """Atomically record ``rank`` entering ``step`` at wall-clock now."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    path = stamp_path(d, rank)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(
        dict(rank=rank, step=step, time=time.time(), pid=os.getpid())))
    os.replace(tmp, path)
    return path


def read_stamps(directory) -> dict[int, dict]:
    out: dict[int, dict] = {}
    for p in sorted(Path(directory).glob("heartbeat.rank*.json")):
        try:
            rec = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue  # mid-replace on a non-atomic filesystem; next read wins
        out[int(rec["rank"])] = rec
    return out


def straggler_report(directory, n_ranks: int, *, stall_s: float = 30.0,
                     now: float | None = None) -> dict:
    """Classify every expected rank from its last heartbeat.

    A rank is ``dead`` if it never stamped, ``stalled`` if its stamp is
    older than ``stall_s`` seconds, and ``behind`` if its step trails the
    cluster max (the rank everyone else is waiting on). ``ok`` is True only
    when every rank stamped recently at the max step.
    """
    now = time.time() if now is None else now
    stamps = read_stamps(directory)
    max_step = max((r["step"] for r in stamps.values()), default=-1)
    ranks = {}
    for rank in range(n_ranks):
        rec = stamps.get(rank)
        if rec is None:
            ranks[rank] = dict(status="dead", step=None, age_s=None)
        else:
            age = now - rec["time"]
            status = ("stalled" if age > stall_s
                      else "behind" if rec["step"] < max_step else "ok")
            ranks[rank] = dict(status=status, step=rec["step"],
                               age_s=round(age, 3))
    bad = sorted(r for r, v in ranks.items() if v["status"] != "ok")
    return dict(ok=not bad, max_step=max_step, stragglers=bad, ranks=ranks)


def format_report(report: dict) -> str:
    if report["ok"]:
        return f"heartbeat: all ranks ok at step {report['max_step']}"
    lines = [f"heartbeat: STRAGGLERS at step {report['max_step']}: "
             f"ranks {report['stragglers']}"]
    for rank, v in sorted(report["ranks"].items()):
        if v["status"] != "ok":
            lines.append(f"  rank {rank}: {v['status']}"
                         f" (step={v['step']}, age={v['age_s']}s)")
    return "\n".join(lines)
