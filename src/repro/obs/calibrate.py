"""Measured-vs-predicted calibration loop (DESIGN.md §10).

    PYTHONPATH=src python -m repro.obs.calibrate \
        [--scheme zero_topo] [--steps 4] [--out-topology topo_calibrated.json]

Runs a reduced-model traced loop (the phased fenced step of ``obs.phased``
plus the comm-attribution probes), compares measured per-phase seconds
against ``topo.cost.step_cost``'s prediction for the same ZeroConfig,
reports per-phase error, and back-solves effective link bandwidths into a
calibrated ``Topology`` JSON the planner consumes
(``python -m repro.topo.planner --topology <file>``) — closing the loop so
``--scheme auto`` can plan off *measured*, not preset, bandwidths.

Back-solve: the model prices each phase as ``wire_bytes/bandwidth(axes) +
latency_s`` with the bottleneck axis setting the bandwidth
(``topo.cost.phase_breakdown``). Holding the latency model fixed, a
measurement ``m`` inverts to ``eff_bw = wire_bytes / max(m - latency_s,
eps)``, attributed to that phase's bottleneck axis; the per-axis median
over phases becomes the calibrated link bandwidth. On fake CPU devices the
resulting numbers predict nothing about real hardware — the point here is
the loop's plumbing; on a real cluster the same command calibrates real
links.

The overlap A/B (skipped under ``--quick``) measures how much in-loop comm
the §3 schedule actually hides: the same model's fwd_bwd segment with
overlap off vs on — ``hidden = clamp(t_serial - t_overlap, 0, comm)`` —
the measured counterpart of ``Workload.hidden_fraction``.

``--quick`` (the CI ``obs`` leg): two measured steps, no A/B, and emit
``BENCH_obs.json`` gating only deterministic structure — the contract-tag
span census, the probe inventory, segment names and the JSONL schema —
never wall-clock.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time
from pathlib import Path


def _build(args, overlap: bool, stream: bool):
    """One (engine, model, concrete batch) at CI scale (check.py idiom)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..core.engine import TrainHparams, ZeroEngine
    from ..launch.mesh import make_test_mesh, scheme_config
    from ..models.registry import build_model, get_arch

    mesh = make_test_mesh(shape=tuple(args.mesh), axes=tuple(args.axes))
    cfg = scheme_config(args.scheme, mesh, quant_block=args.quant_block,
                        overlap=overlap, stream_grads=stream)
    arch = get_arch(args.model).reduced(
        n_layers=args.n_layers, d_model=args.d_model, vocab=args.vocab)
    model = build_model(arch)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=64, warmup_steps=0,
                                  n_microbatch=args.n_microbatch))
    data_axes = tuple(args.axes)
    bspecs = {"tokens": P(data_axes)}
    rows = max(args.n_microbatch, 1) * len(jax.devices())
    rng = np.random.default_rng(0)
    batch = {"tokens": jax.device_put(
        jnp.asarray(rng.integers(0, args.vocab, (rows, args.seq),
                                 dtype=np.int32)),
        NamedSharding(mesh, P(data_axes)))}
    return mesh, cfg, eng, model, arch, bspecs, batch


def _measure(args, overlap: bool, stream: bool, *, steps: int, warmup: int):
    """Traced run: warmup (compile) + measured steps + one probe pass.
    Returns per-segment and per-phase medians plus wall-time coverage."""
    import jax

    from .phased import PhasedStep
    from .spans import SEGMENTS, SpanRecorder

    mesh, cfg, eng, model, arch, bspecs, batch = _build(args, overlap, stream)
    phased = PhasedStep(eng, model.loss_fn(), bspecs)
    state = eng.init_state(jax.random.key(0))
    rec = SpanRecorder()
    walls = []
    for i in range(warmup + steps):
        rec.step = i
        t0 = time.perf_counter()
        state, _ = phased(state, batch, rec)
        walls.append(time.perf_counter() - t0)
    phased.run_probes(state, batch, rec)

    probe = phased.last_probe(rec)
    measured = range(warmup, warmup + steps)
    per_step = [phased.phase_seconds(rec, i, probe) for i in measured]
    phase = {k: statistics.median([p[k] for p in per_step])
             for k in per_step[0]}
    seg = {}
    for name in SEGMENTS:
        vals = [s.dur for s in rec.spans
                if s.name == name and s.step >= warmup]
        if vals:
            seg[name] = statistics.median(vals)
    # spans-sum vs wall coverage over the measured steps (acceptance: the
    # fenced segments account for the step, within 10%)
    cov = []
    for i in measured:
        segs = sum(v for k, v in rec.step_seconds(i).items()
                   if k in SEGMENTS)
        cov.append(segs / walls[i] if walls[i] > 0 else 0.0)
    return dict(mesh=mesh, cfg=cfg, eng=eng, model=model, arch=arch,
                bspecs=bspecs, batch=batch, phased=phased, rec=rec,
                seg=seg, phase=phase, probe=probe,
                coverage=statistics.median(cov))


def solve_bandwidths(predicted: dict, measured_phase: dict,
                     *, eps: float = 1e-9) -> dict[str, float]:
    """Invert the cost model per phase and reduce to per-axis medians.

    ``predicted`` is ``topo.cost.phase_breakdown`` output; ``measured_phase``
    maps phase name -> measured seconds at the same cadence.
    """
    per_axis: dict[str, list[float]] = {}
    for ph, rec in predicted.items():
        m = measured_phase.get(ph, 0.0)
        if not rec["wire_bytes"] or rec["bottleneck"] is None or m <= 0:
            continue
        eff = rec["wire_bytes"] / max(m - rec["latency_s"], eps)
        per_axis.setdefault(rec["bottleneck"], []).append(eff)
    return {ax: statistics.median(vals) for ax, vals in per_axis.items()}


def _bench_path() -> Path:
    return Path(os.environ.get("REPRO_BENCH_DIR", ".")) / "BENCH_obs.json"


def build_parser() -> argparse.ArgumentParser:
    """The calibration CLI surface (rendered into docs/CLI.md by
    ``repro.launch.cli_reference``)."""
    ap = argparse.ArgumentParser(prog="python -m repro.obs.calibrate",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="qwen2-0.5b")
    ap.add_argument("--scheme", default="zero_topo")
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--stream-grads", action="store_true")
    ap.add_argument("--n-microbatch", type=int, default=2)
    ap.add_argument("--quant-block", type=int, default=64)
    ap.add_argument("--mesh", type=lambda s: [int(x) for x in s.split(",")],
                    default=[2, 2, 2])
    ap.add_argument("--axes", type=lambda s: s.split(","),
                    default=["data", "node", "gcd"])
    ap.add_argument("--seq", type=int, default=33)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--steps", type=int, default=4,
                    help="measured steps (after one compile/warmup step)")
    ap.add_argument("--topology", default="",
                    help="preset name or Topology JSON to calibrate "
                         "(default: Topology.from_mesh of the live mesh)")
    ap.add_argument("--out-topology", default="topo_calibrated.json",
                    help="where to write the calibrated Topology JSON")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: 2 measured steps, no overlap A/B, emit "
                         "BENCH_obs.json (deterministic structure only)")
    ap.add_argument("--emit-bench", action="store_true")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    n_dev = 1
    for d in args.mesh:
        n_dev *= d
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    from ..topo import cost as tcost
    from ..topo.model import Topology, calibrated, load_topology
    from .metrics import REQUIRED_FIELDS
    from .spans import SEGMENTS, site_inventory

    steps = 2 if args.quick else args.steps
    run = _measure(args, args.overlap, args.stream_grads,
                   steps=steps, warmup=1)
    eng, cfg, mesh, arch = run["eng"], run["cfg"], run["mesh"], run["arch"]

    topo = load_topology(args.topology) if args.topology \
        else Topology.from_mesh(mesh)
    rows_per_mb = len(mesh.devices.flat)   # one row per device per mb here
    wl = tcost.Workload(
        psi=float(eng.param_count()), n_layers=arch.n_layers,
        tokens_per_device_mb=args.seq * rows_per_mb // n_dev,
        n_microbatch=args.n_microbatch, stream_grads=cfg.stream_grads)
    pred = tcost.phase_breakdown(cfg, topo, wl)

    print(f"calibrate: {arch.name}/{cfg.name} on {topo.name} "
          f"({steps} measured steps, n_mb={args.n_microbatch})")
    print(f"span/wall coverage (median): {run['coverage']:.3f}")
    print(f"{'phase':<16}{'measured_ms':>12}{'predicted_ms':>14}{'error':>9}")
    for ph in tcost.PHASES:
        m = run["phase"].get(ph, 0.0)
        p = pred[ph]["seconds"]
        err = f"{(m - p) / p:+8.1%}" if p > 0 else "      --"
        print(f"{ph:<16}{m * 1e3:>12.2f}{p * 1e3:>14.3f}{err:>9}")
    mcomp = run["phase"].get("compute", 0.0)
    pcomp = 6.0 * wl.psi * wl.n_microbatch * wl.tokens_per_device_mb \
        / topo.flops_per_device
    print(f"{'compute':<16}{mcomp * 1e3:>12.2f}{pcomp * 1e3:>14.3f}")

    # measured overlap A/B: same model, §3 prefetch off vs on
    if not args.quick:
        serial = _measure(args, False, args.stream_grads, steps=steps,
                          warmup=1)
        over = _measure(args, True, args.stream_grads, steps=steps, warmup=1)
        comm = sum(run["phase"].get(ph, 0.0)
                   for ph in ("fwd_allgather", "bwd_allgather", "grad_rs_w"))
        hidden = min(max(serial["seg"].get("fwd_bwd", 0.0)
                         - over["seg"].get("fwd_bwd", 0.0), 0.0), comm)
        frac = hidden / comm if comm > 0 else 0.0
        print(f"overlap A/B: fwd_bwd serial "
              f"{serial['seg'].get('fwd_bwd', 0.0) * 1e3:.2f}ms vs "
              f"overlapped {over['seg'].get('fwd_bwd', 0.0) * 1e3:.2f}ms -> "
              f"hidden {hidden * 1e3:.2f}ms "
              f"({frac:.2f} of in-loop comm; "
              f"model hidden_fraction={wl.hidden_fraction})")

    eff = solve_bandwidths(pred, run["phase"])
    for ax, bw in sorted(eff.items()):
        print(f"effective bandwidth[{ax}]: {bw / 1e9:.3f} GB/s "
              f"(preset {topo.link(ax).bandwidth / 1e9:.3f})")
    cal = calibrated(topo, eff)
    if args.out_topology:
        cal.save(args.out_topology)
        print(f"wrote calibrated topology -> {args.out_topology} "
              f"(feed to: python -m repro.topo.planner --topology "
              f"{args.out_topology})")

    if args.quick or args.emit_bench:
        # deterministic structure only — the gateable part of this run
        step = eng.make_train_step(run["model"].loss_fn(), run["bspecs"])
        census = site_inventory(step, eng.abstract_state(), run["batch"])
        bench = dict(
            model=args.model, scheme=args.scheme,
            span_census=census,
            segments=list(SEGMENTS),
            phases=list(tcost.PHASES),
            probe_inventory=run["phased"].probe_inventory(),
            jsonl_schema=list(REQUIRED_FIELDS),
        )
        path = _bench_path()
        path.write_text(json.dumps(bench, indent=2, sort_keys=True))
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
