"""Topology-aware partition planning (DESIGN.md §4).

Three layers:

  model.py   — ``Topology``: each mesh axis mapped to link bandwidth /
               latency / tier, with built-in presets (Frontier, GPU pod,
               TPU pod) and JSON load/save for user-declared clusters.
  cost.py    — analytic per-step communication seconds and per-device
               memory bytes for *any* valid ``ZeroConfig``, priced from the
               real collective inventory of ``core/collectives.py``.
  planner.py — enumerate every axis-prefix assignment satisfying the AMSP
               dependency rule (plus secondary placement and quantization),
               score under a memory budget, emit ranked ``ZeroConfig``s.
"""
from .cost import StepCost, Workload, phase_volumes, step_cost  # noqa: F401
from .model import (Link, Topology, frontier, gpu_pod,  # noqa: F401
                    load_topology, tpu_pod)

_PLANNER_EXPORTS = ("Plan", "enumerate_candidates", "plan", "plan_for_mesh",
                    "preset_on_topology", "model_workload")


def __getattr__(name):
    # planner re-exports are lazy so `python -m repro.topo.planner` does not
    # import the submodule twice (runpy's sys.modules warning)
    if name in _PLANNER_EXPORTS:
        from . import planner
        return getattr(planner, name)
    raise AttributeError(name)
