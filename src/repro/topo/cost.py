"""Analytic cost model: seconds and bytes for any ``ZeroConfig`` on any
``Topology``.

The model prices exactly the collective inventory ``core/collectives.py``
emits per train step (DESIGN.md §4):

  per microbatch (inside the layer loop / backward):
    fwd_allgather  — weight all-gather of the primary shard over the W axes,
                     INT8 when ``quantize_weights`` (collectives.quant_all_
                     gather_int8 / gather_issue_int8);
    bwd_allgather  — backward re-materialization: all-gather of the INT8
                     secondary partition over the secondary axes when one
                     exists (collectives.gather_secondary), else the primary
                     gather again;
    grad_rs_w      — stage-1 weight-grad reduce-scatter over the W axes,
                     inside every backward pass (linear._grad_to_primary_
                     shard), INT4 all-to-all based when ``quantize_grads``
                     (collectives.a2a_quant_reduce_scatter);
  per step (after microbatch accumulation; seed regime):
    grad_rs_e      — stage-2 reduce-scatter of the accumulated primary-layout
                     grads over the E axes (engine ``_to_os``; once per step,
                     strictly less communication than per-microbatch);
    cross_replica  — replica-tier gradient sync (allreduce+select, or the
                     beyond-paper reduce_scatter at half volume);
    update_gather  — the update all-gather over E+R rebuilding bf16 primaries
                     (collectives.update_all_gather), INT8-halved when
                     ``quantize_update_gather``.

With ``Workload.stream_grads`` (the streaming grad path, DESIGN.md §8),
``grad_rs_e`` and ``cross_replica`` move into the backward layer loop:
per-microbatch cadence (volume x n_microbatch, latency per layer) but
*overlappable* with the backward matmuls, so only the update gather stays
in the exposed post-backward section (``StepCost.exposed_s``), and grad
memory is charged at os-shard layout (``partition.grad_buffer_bytes``).

In the seed regime the two grad-RS stages telescope: ``grad_rs_w +
grad_rs_e = g_bytes * (dg-1)/dg``, exactly the single-stage Table VIII
figure, so byte counts stay comparable with ``benchmarks/comm_volume.py``
while the *timing* charges each stage at its own tier and cadence (the
streaming regime trades n_microbatch x the stage-2 bytes for the overlap
and the memory drop).

Each phase costs ``volume / bottleneck_bandwidth + hops * per_hop_latency``
where the bottleneck link is the slowest axis the collective spans and
``hops = group_size - 1`` (ring schedule).  Per-microbatch phases pay the
latency term once per layer — the paper's central argument: ZeRO-topo pins
those group sizes (2 / 8) so the latency term is constant in cluster size,
while ZeRO-3/ZeRO++ groups grow with scale.

Volumes are the paper's Tables VII/VIII accounting, generalized to any
``ZeroAxes`` assignment; ``benchmarks/comm_volume.py`` cross-checks the
three preset columns against its own independently-written formulas
(tests/test_topo.py).  Memory reuses the Table V/VI formulas from
``core/partition.py`` — one source of truth for both planner and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.partition import (ZeroConfig, grad_buffer_bytes,
                              grad_memory_bytes, optimizer_memory_bytes,
                              weight_memory_bytes)
from .model import Topology

PER_MICROBATCH = ("fwd_allgather", "bwd_allgather", "grad_rs_w")
PER_STEP = ("grad_rs_e", "cross_replica", "update_gather")
PHASES = PER_MICROBATCH + PER_STEP
# phases the streaming grad path (DESIGN.md §8) moves into the backward
# layer loop: per-microbatch cadence, overlappable with the backward matmuls
STREAMED = ("grad_rs_e", "cross_replica")


@dataclass(frozen=True)
class Workload:
    """What one train step does, per device."""
    psi: float                        # total model parameters
    n_layers: int = 44                # layer-loop trip count (latency term)
    tokens_per_device_mb: int = 2048  # tokens per device per microbatch
    n_microbatch: int = 4             # gradient-accumulation factor
    hidden_fraction: float = 0.6      # fraction of comm hidden under compute
    # (DeepSpeed-style prefetch; matches the repo's overlap schedule §3)
    fused_kernels: bool = True        # dequant fused into the consumer
    # (kernels/dequant_matmul.py + the a2a dequant-reduce). False prices the
    # unfused pipeline: every gathered weight is dequantized to bf16 in HBM
    # and re-read by the matmul, and the a2a-received grad chunks round-trip
    # once more before the reduction (step_cost's kernel_s term).
    stream_grads: bool = False        # streaming grad regime (DESIGN.md §8):
    # stage-2 RS + cross-replica run per layer per microbatch inside the
    # backward (volume x n_microbatch, but overlappable) instead of once per
    # step fully exposed, and grad memory is charged at os-shard layout —
    # which is what lets the planner's memory-budget search admit schemes it
    # previously rejected.


def phase_volumes(cfg: ZeroConfig, psi: float) -> dict[str, float]:
    """Bytes per device per step for each collective phase (Tables VII/VIII).

    All-gather over degree d moves ``shard_bytes * (d-1)`` per device;
    reduce-scatter moves ``full_bytes * (d-1)/d``; ring allreduce twice that.
    """
    dw = cfg.w_degree
    ds = cfg.sec_degree or dw
    dg = cfg.g_degree
    dos = cfg.os_degree
    dr = cfg.size(cfg.axes.replica)
    # forward all-gather of the primary: INT8 (1 B/param) when quantized
    w_bytes = psi / dw * (1 if cfg.quantize_weights else 2)
    fwd = w_bytes * (dw - 1)
    # backward re-gather: INT8 secondary over its own group, else primary again
    if cfg.axes.secondary is not None:
        bwd = psi / ds * (ds - 1)
    else:
        bwd = fwd
    # gradient reduce-scatter, two stages: INT4 (0.5 B/param) when quantized,
    # bf16 otherwise. Stage 1 (per backward pass): full dense grad -> primary
    # shard over W. Stage 2 (per step): primary-layout shard -> grad shard
    # over E. Their sum equals the single-stage figure over dg.
    gb = 0.5 if cfg.quantize_grads else 2
    de = cfg.size(cfg.axes.extra_grad)
    grs_w = psi * gb * (dw - 1) / dw
    grs_e = (psi / dw) * gb * (de - 1) / de if de > 1 else 0.0
    # cross-replica sync of the grad shards (bf16-accounted, paper §V-C):
    # ring allreduce = 2x the reduce-scatter volume
    if dr > 1:
        ar = 2 if cfg.cross_replica == "allreduce" else 1
        crs = ar * (2 * psi / dg) * (dr - 1) / dr
    else:
        crs = 0.0
    # update all-gather over E+R (bf16 primaries; INT8 halves it)
    upd = (2 * psi / dw) * (1 - dw / dos) \
        * (0.5 if cfg.quantize_update_gather else 1)
    return dict(fwd_allgather=fwd, bwd_allgather=bwd,
                grad_rs_w=grs_w, grad_rs_e=grs_e,
                cross_replica=crs, update_gather=upd,
                total=fwd + bwd + grs_w + grs_e + crs + upd)


def phase_axes(cfg: ZeroConfig) -> dict[str, tuple[str, ...]]:
    """Which mesh axes each phase's collective spans (collectives.py)."""
    return dict(
        fwd_allgather=cfg.axes.weight,
        bwd_allgather=cfg.axes.secondary if cfg.axes.secondary is not None
        else cfg.axes.weight,
        grad_rs_w=cfg.axes.weight,
        grad_rs_e=cfg.axes.extra_grad,
        cross_replica=cfg.axes.replica,
        update_gather=cfg.axes.extra_grad + cfg.axes.replica,
    )


@dataclass(frozen=True)
class StepCost:
    """Predicted cost of one train step for (cfg, topo, workload)."""
    comm_s: dict[str, float]          # seconds per phase, per step
    volumes: dict[str, float]         # bytes per device per step, per phase
    compute_s: float
    memory: dict[str, float]          # per-device state bytes (Tables V/VI)
    fits: bool                        # memory_total <= budget
    kernel_s: float = 0.0             # unfused quant/dequant HBM round-trips
    # (zero when Workload.fused_kernels: the dequant rides the matmul's
    # VMEM pipeline and never touches HBM)
    exposed_s: float = 0.0            # comm seconds that CANNOT hide under
    # compute: the serial post-backward section (stage-2 RS, cross-replica,
    # update gather run after the last backward matmul). The streaming grad
    # regime moves the grad phases into the backward layer loop, leaving
    # only the update gather exposed — exposed-comm pricing (DESIGN.md §8).

    @property
    def comm_total_s(self) -> float:
        return sum(self.comm_s.values())

    @property
    def memory_total(self) -> float:
        return self.memory["total"]

    def step_s(self, hidden_fraction: float = 0.6) -> float:
        """Wall-clock: overlappable comm partially hides under compute;
        exposed comm (the serial post-backward phases) adds on top."""
        c = self.compute_s + self.kernel_s
        m = self.comm_total_s - self.exposed_s
        return max(c, m) + (1 - hidden_fraction) * min(c, m) + self.exposed_s


def memory_bytes(cfg: ZeroConfig, psi: float, *,
                 streaming: bool | None = None) -> dict[str, float]:
    """Per-device training-state bytes.

    Weights/optimizer follow the paper Table V/VI formulas; grads are
    charged at the buffer the engine *actually allocates*
    (``partition.grad_buffer_bytes``): fp32 primary layout on the seed
    path, fp32 os-shard layout when streaming — the memory-budget lever of
    the streaming grad regime. ``grads_table`` keeps the paper's Table VI
    grad-shard figure for reference."""
    weights = weight_memory_bytes(cfg, int(psi))
    grads = grad_buffer_bytes(cfg, int(psi), streaming=streaming)
    opt = optimizer_memory_bytes(cfg, int(psi))
    return dict(weights=weights, grads=grads,
                grads_table=grad_memory_bytes(cfg, int(psi)),
                optimizer=opt, total=weights + grads + opt)


def phase_breakdown(cfg: ZeroConfig, topo: Topology,
                    wl: Workload) -> dict[str, dict]:
    """Per-phase prediction record: the seconds ``step_cost`` charges plus
    everything needed to *invert* the model from a measurement
    (obs.calibrate): total wire bytes at the phase's cadence, the spanned
    axes and bottleneck axis, and the latency share. For each phase::

        seconds = wire_bytes / bandwidth(axes) + latency_s

    with per-microbatch phases paying the ring latency once per layer per
    microbatch (the paper's central group-size argument) and wire bytes
    multiplied by the cadence.
    """
    vols = phase_volumes(cfg, wl.psi)
    axes = phase_axes(cfg)
    # streaming regime: the stage-2 RS and cross-replica sync run per layer
    # per microbatch inside the backward (overlappable); otherwise they are
    # once-per-step and fully exposed, like the update gather
    in_loop = set(PER_MICROBATCH) | (set(STREAMED) if wl.stream_grads
                                     else set())
    out = {}
    for phase in PHASES:
        ax = axes[phase]
        group = cfg.size(ax)
        rec = dict(axes=list(ax or ()), group=group,
                   in_loop=phase in in_loop, seconds=0.0, wire_bytes=0.0,
                   latency_s=0.0, bottleneck=None)
        if ax and group > 1:
            wire = vols[phase] / topo.bandwidth(ax)
            hops = (group - 1) * topo.latency(ax)
            if phase in in_loop:
                # inside the layer loop: one collective per layer per mb
                rec["seconds"] = wl.n_microbatch * (wire + wl.n_layers * hops)
                rec["wire_bytes"] = wl.n_microbatch * vols[phase]
                rec["latency_s"] = wl.n_microbatch * wl.n_layers * hops
            else:
                rec["seconds"] = wire + hops
                rec["wire_bytes"] = vols[phase]
                rec["latency_s"] = hops
            rec["bottleneck"] = min(ax, key=lambda a: topo.link(a).bandwidth)
        out[phase] = rec
    return out


def step_cost(cfg: ZeroConfig, topo: Topology, wl: Workload,
              memory_budget: float | None = None) -> StepCost:
    """Price one train step of ``wl`` under ``cfg`` on ``topo``."""
    vols = phase_volumes(cfg, wl.psi)
    phases = phase_breakdown(cfg, topo, wl)
    comm = {phase: phases[phase]["seconds"] for phase in PHASES}
    exposed_s = sum(comm[ph] for ph in PER_STEP if not phases[ph]["in_loop"])
    tokens_per_device = wl.n_microbatch * wl.tokens_per_device_mb
    compute_s = 6.0 * wl.psi * tokens_per_device / topo.flops_per_device
    kernel_s = 0.0
    if not wl.fused_kernels:
        # unfused quant path: every INT8 weight gather is dequantized to a
        # bf16 copy in HBM (write 2B/param) that the matmul re-reads
        # (another 2B/param), forward and backward, per microbatch; the a2a
        # grad RS likewise materializes the received chunks in f32 before
        # reducing. Fusion (kernels/dequant_matmul.py, *_sum kernels)
        # deletes all of it — HBM only ever sees the 1B/param wire format.
        kb = 0.0
        if cfg.quantize_weights:
            kb += wl.n_microbatch * 2 * 4.0 * wl.psi        # fwd + bwd dequant
        if cfg.quantize_grads:
            kb += wl.n_microbatch * 2 * 4.0 * wl.psi / cfg.w_degree
            # producing side: without the matmul_quant epilogue the dense
            # f32 dW is written to HBM (4B/param) and re-read by the
            # quantize kernel (4B/param) before the wire format exists;
            # the fused epilogue (kernels/ops.matmul_quant) emits the
            # INT-wire directly from the accumulator, per microbatch
            kb += wl.n_microbatch * 2 * 4.0 * wl.psi
        kernel_s = kb / topo.hbm_bw
    mem = memory_bytes(cfg, wl.psi, streaming=wl.stream_grads
                       or cfg.stream_grads)
    budget = topo.hbm_bytes if memory_budget is None else memory_budget
    return StepCost(comm_s=comm, volumes=vols, compute_s=compute_s,
                    memory=mem, fits=mem["total"] <= budget,
                    kernel_s=kernel_s, exposed_s=exposed_s)


def tflops_per_device(cfg: ZeroConfig, topo: Topology, wl: Workload) -> float:
    """Modeled sustained TFLOP/s per device (the paper's Figs 7/8 metric)."""
    c = step_cost(cfg, topo, wl)
    tokens_per_device = wl.n_microbatch * wl.tokens_per_device_mb
    return 6.0 * wl.psi * tokens_per_device / c.step_s(wl.hidden_fraction) / 1e12


# ---------------------------------------------------------------------------
# serving cost model (DESIGN.md §12)
#
# One continuous-batching decode step prices three traffic classes:
#
#   res_gather — per-token re-materialization of the weights from the
#                residency partition: the INT8 wire shards (1 + 4/Q B/param)
#                all-gathered over the residency axes per layer
#                (collectives.gather_residency_q -> the fused dequant matmul),
#                or the fp-materialized gather (compute-dtype B/param) for the
#                seed "gathered" backend;
#   act_psum   — per-layer activation allreduce of each slot's single-token
#                row over the residency/model axes (collectives.
#                activation_psum in the decode shard_map);
#   kv_pages   — HBM traffic of the paged pool: the page-table gather reads
#                every live context position once per step and the writeback
#                scatters one new position per active slot (serve/paged.py).
#
# Weights are read from HBM once per step regardless of batch, so arithmetic
# intensity (2*psi*slots flops over weight+KV bytes) grows with the live
# batch — the knob the SLO admission controls. Residency memory reuses the
# partition.resident_memory_bytes accounting: wire bytes shrink with the
# residency degree while res_gather traffic grows with it — the serving
# analog of the training weight-axes trade the planner already ranks.

SERVE_PHASES = ("res_gather", "act_psum")


@dataclass(frozen=True)
class ServeWorkload:
    """What one continuous-batching decode step does, per device."""
    psi: float                         # total model parameters
    n_layers: int = 44                 # decode layer-loop trip count
    d_model: int = 6144                # activation width (act_psum volume)
    n_slots: int = 8                   # live decode rows (the batch)
    context: int = 1024                # mean live context per slot, tokens
    max_len: int = 2048                # pool provisioning length per slot
    kv_bytes_per_token: float = 0.0    # all-layer KV bytes per token;
    # 0 estimates a GQA-quarter-width cache (serve_workload_for_model fills
    # the exact figure from model.cache_shapes)
    page_size: int = 16
    quant_block: int = 64

    def kv_token_bytes(self) -> float:
        if self.kv_bytes_per_token:
            return self.kv_bytes_per_token
        return 2 * (self.d_model / 4) * 2 * self.n_layers


def serve_wire_bytes(psi: float, quant_block: int, res_degree: int, *,
                     resident: bool = True) -> float:
    """Per-device weight bytes held by the serving path.

    Resident: the INT8 wire shard, 1 B/param + 4/Q B/param of f32 scales,
    over the residency degree (matches partition.resident_memory_bytes).
    Gathered: the seed fp-materialized path keeps bf16 primaries."""
    per_param = (1 + 4 / max(quant_block, 1)) if resident else 2
    return psi * per_param / max(res_degree, 1)


def serve_phase_volumes(wl: ServeWorkload, res_degree: int, *,
                        resident: bool = True) -> dict[str, float]:
    """Network bytes per device per decode step, plus the KV HBM traffic."""
    deg = max(res_degree, 1)
    shard = serve_wire_bytes(wl.psi, wl.quant_block, deg, resident=resident)
    gather = shard * (deg - 1)
    # per-layer single-token-row allreduce (2x the RS volume), bf16 rows
    psum = 2 * (2 * wl.d_model * wl.n_slots) * wl.n_layers \
        * (deg - 1) / deg if deg > 1 else 0.0
    kv_read = wl.kv_token_bytes() * wl.context * wl.n_slots
    kv_write = wl.kv_token_bytes() * wl.n_slots
    return dict(res_gather=gather, act_psum=psum,
                kv_pages=kv_read + kv_write,
                total=gather + psum + kv_read + kv_write)


def serve_memory_bytes(wl: ServeWorkload, res_degree: int, *,
                       resident: bool = True) -> dict[str, float]:
    """Per-device serving-state bytes: wire residency + the paged pool."""
    weights = serve_wire_bytes(wl.psi, wl.quant_block, res_degree,
                               resident=resident)
    kv = wl.kv_token_bytes() * wl.max_len * wl.n_slots
    return dict(weights=weights, kv_pool=kv, total=weights + kv)


@dataclass(frozen=True)
class ServeStepCost:
    """Predicted cost of one continuous-batching decode step."""
    comm_s: dict[str, float]
    volumes: dict[str, float]
    hbm_s: float                       # weight + KV-page HBM traffic
    compute_s: float
    memory: dict[str, float]
    fits: bool
    n_slots: int

    @property
    def comm_total_s(self) -> float:
        return sum(self.comm_s.values())

    @property
    def memory_total(self) -> float:
        return self.memory["total"]

    def step_s(self) -> float:
        # decode is bandwidth-bound: HBM streaming and compute overlap,
        # the per-layer collectives are latency-dominated and exposed
        return max(self.compute_s, self.hbm_s) + self.comm_total_s

    def tokens_per_s(self) -> float:
        return self.n_slots / self.step_s()

    def arithmetic_intensity(self) -> float:
        """flops per HBM byte — grows with the live batch (weights are read
        once per step regardless of how many slots decode)."""
        bytes_touched = self.memory["weights"] + self.volumes["kv_pages"]
        return 2.0 * self._psi * self.n_slots / max(bytes_touched, 1.0)

    _psi: float = 0.0


def serve_step_cost(topo: Topology, wl: ServeWorkload,
                    res_axes: tuple[str, ...], *, resident: bool = True,
                    memory_budget: float | None = None) -> ServeStepCost:
    """Price one decode step with the wire residency sharded over
    ``res_axes`` (empty = fully replicated wire, no per-token gather)."""
    deg = 1
    sizes = dict(topo.axis_sizes)
    for a in res_axes:
        deg *= sizes[a]
    vols = serve_phase_volumes(wl, deg, resident=resident)
    comm = {}
    for phase in SERVE_PHASES:
        if not res_axes or deg <= 1:
            comm[phase] = 0.0
            continue
        wire = vols[phase] / topo.bandwidth(res_axes)
        hops = (deg - 1) * topo.latency(res_axes)
        # both phases run once per layer inside the decode loop
        comm[phase] = wire + wl.n_layers * hops
    mem = serve_memory_bytes(wl, deg, resident=resident)
    hbm = (mem["weights"] + vols["kv_pages"]) / topo.hbm_bw
    compute = 2.0 * wl.psi * wl.n_slots / topo.flops_per_device
    budget = topo.hbm_bytes if memory_budget is None else memory_budget
    return ServeStepCost(comm_s=comm, volumes=vols, hbm_s=hbm,
                         compute_s=compute, memory=mem,
                         fits=mem["total"] <= budget, n_slots=wl.n_slots,
                         _psi=wl.psi)
