"""Topology description: mesh axes annotated with link characteristics.

A ``Topology`` is the planner's view of a cluster: an ordered list of mesh
axes, fastest link first, each carrying the effective per-device bandwidth,
the per-hop latency of the collective algorithm on that link, and a tier
label (``l0`` / ``intra`` / ``inter``) matching the paper's three Frontier
levels (GCD pair / node / Slingshot).  New clusters are config files, not
code: declare the axes in JSON (`Topology.save` / `load_topology`) and the
planner searches the full scheme space on them.

The axis *order* is load-bearing: the partition presets in
``core/partition.py`` build their axis tuples fastest-first
(l0 + intra + inter), and the planner enumerates prefix assignments of the
same ordering, so every hand-written preset is a point inside the searched
space.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, replace
from pathlib import Path

TIERS = ("l0", "intra", "inter")


@dataclass(frozen=True)
class Link:
    """One mesh axis and the interconnect its neighbours talk over."""
    name: str
    size: int                 # mesh axis size (devices along this axis)
    bandwidth: float          # effective per-device bandwidth, bytes/s
    latency: float            # per-hop latency of ring collectives, s
    tier: str = "intra"       # l0 | intra | inter (paper's three levels)

    def __post_init__(self):
        assert self.size >= 1 and self.bandwidth > 0 and self.latency >= 0, self
        assert self.tier in TIERS, f"tier must be one of {TIERS}: {self}"


@dataclass(frozen=True)
class Topology:
    """A cluster as a bandwidth hierarchy: axes ordered fastest -> slowest."""
    name: str
    links: tuple[Link, ...]
    flops_per_device: float = 135e12   # achievable matmul FLOP/s
    hbm_bytes: float = 64e9            # per-device memory budget default
    hbm_bw: float = 1.6e12             # per-device HBM bandwidth, B/s
    # (prices the dequant round-trip the fused kernels remove, cost.py)

    def __post_init__(self):
        names = [l.name for l in self.links]
        assert len(set(names)) == len(names), f"duplicate axes: {names}"
        # fastest -> slowest is the canonical order (stable for ties, so
        # same-tier axes keep their declared relative order)
        ordered = tuple(sorted(self.links, key=lambda l: -l.bandwidth))
        object.__setattr__(self, "links", ordered)

    # -- views ---------------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(l.name for l in self.links)

    @property
    def axis_sizes(self) -> tuple[tuple[str, int], ...]:
        return tuple((l.name, l.size) for l in self.links)

    @property
    def n_devices(self) -> int:
        return math.prod(l.size for l in self.links)

    def link(self, axis: str) -> Link:
        for l in self.links:
            if l.name == axis:
                return l
        raise KeyError(axis)

    def tiers(self) -> dict[str, tuple[str, ...]]:
        """(l0, intra, inter) axis split, mirroring ``mesh.zero_tiers``.

        ``l0`` falls back to the fastest axis when no axis is labelled l0;
        ``intra`` always contains l0 (the paper's node contains the GCD pair).
        """
        l0 = tuple(l.name for l in self.links if l.tier == "l0")
        if not l0 and self.links:
            l0 = (self.links[0].name,)
        intra = l0 + tuple(l.name for l in self.links
                           if l.tier == "intra" and l.name not in l0)
        inter = tuple(l.name for l in self.links if l.name not in intra)
        return dict(l0=l0, intra=intra, inter=inter)

    # -- link aggregation over a collective's axis tuple ---------------------

    def bandwidth(self, axes: tuple[str, ...]) -> float:
        """Bottleneck bandwidth of a collective spanning ``axes``."""
        assert axes, "no link to price for an empty axis tuple"
        return min(self.link(a).bandwidth for a in axes)

    def latency(self, axes: tuple[str, ...]) -> float:
        """Per-hop latency of a collective spanning ``axes`` (slowest hop)."""
        assert axes
        return max(self.link(a).latency for a in axes)

    def group_size(self, axes: tuple[str, ...]) -> int:
        return math.prod(self.link(a).size for a in axes)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Topology":
        links = tuple(Link(**l) for l in d["links"])
        return cls(name=d["name"], links=links,
                   flops_per_device=float(d.get("flops_per_device", 135e12)),
                   hbm_bytes=float(d.get("hbm_bytes", 64e9)),
                   hbm_bw=float(d.get("hbm_bw", 1.6e12)))

    def save(self, path) -> str:
        Path(path).write_text(json.dumps(self.to_dict(), indent=1))
        return str(path)

    @classmethod
    def load(cls, path) -> "Topology":
        return cls.from_dict(json.loads(Path(path).read_text()))

    # -- from a live mesh ----------------------------------------------------

    @classmethod
    def from_mesh(cls, mesh, *, bandwidths: dict[str, float] | None = None,
                  latencies: dict[str, float] | None = None,
                  flops_per_device: float = 135e12,
                  hbm_bytes: float = 64e9) -> "Topology":
        """Annotate a live mesh with per-tier link defaults.

        The tier split comes from ``launch.mesh.zero_tiers`` (the same rule
        the hand-written presets use), so ``--scheme auto`` searches exactly
        the space the presets live in.  ``bandwidths``/``latencies`` override
        per *tier* (keys l0/intra/inter).

        Axes that cross a *process* boundary (``launch.mesh.process_axes``)
        are pinned to the inter tier and priced at the inter link — the
        process boundary IS the slow network, whatever the axis is named.
        ``zero_tiers`` raises if a process boundary would cut an intra axis,
        so by the time we get here spanning axes are inter axes; the pin is
        asserted rather than silently re-derived.
        """
        from ..launch.mesh import process_axes, zero_tiers
        bw = dict(DEFAULT_TIER_BANDWIDTH)
        bw.update(bandwidths or {})
        lat = dict(DEFAULT_TIER_LATENCY)
        lat.update(latencies or {})
        tiers = zero_tiers(mesh)
        spanning = process_axes(mesh)
        assert all(a in tiers["inter"] for a in spanning), (spanning, tiers)
        links = []
        for tier in ("l0", "intra", "inter"):
            for a in tiers[tier]:
                if any(l.name == a for l in links):
                    continue     # l0 axes also appear in intra
                links.append(Link(a, mesh.shape[a], bw[tier], lat[tier], tier))
        name = f"mesh:{dict(mesh.shape)}"
        if spanning:
            name += f" procs@{','.join(spanning)}"
        return cls(name=name, links=tuple(links),
                   flops_per_device=flops_per_device, hbm_bytes=hbm_bytes)


# per-tier defaults for meshes declared without explicit link data
# (Frontier numbers: MI250X GCD pair / intra-node IF / 4x Slingshot per node)
DEFAULT_TIER_BANDWIDTH = dict(l0=200e9, intra=40e9, inter=100e9 / 8)
DEFAULT_TIER_LATENCY = dict(l0=2e-6, intra=4e-6, inter=15e-6)


# ---------------------------------------------------------------------------
# Built-in presets
# ---------------------------------------------------------------------------

def frontier(n_nodes: int = 48) -> Topology:
    """Frontier (paper §IV): MI250X GCD pair / 8-GCD node / Slingshot.

    Per-GCD effective numbers used throughout the paper-figure benchmarks:
    200 GB/s inside the GCD pair, ~40 GB/s across the node, 4x100 GB/s
    Slingshot NICs shared by 8 GCDs inter-node.
    """
    return Topology("frontier", (
        Link("gcd", 2, 200e9, 2e-6, "l0"),
        Link("node", 4, 40e9, 4e-6, "intra"),
        Link("data", n_nodes, 100e9 / 8, 15e-6, "inter"),
    ), flops_per_device=135e12, hbm_bytes=64e9)


def gpu_pod(n_nodes: int = 32, gpus_per_node: int = 8) -> Topology:
    """Generic NVLink-node GPU cluster: NVLink intra-node, IB inter-node."""
    return Topology("gpu_pod", (
        Link("model", gpus_per_node, 300e9, 3e-6, "intra"),
        Link("data", n_nodes, 25e9, 10e-6, "inter"),
    ), flops_per_device=300e12, hbm_bytes=80e9)


def tpu_pod(ici: int = 16, dci: int = 16) -> Topology:
    """TPU pod slice: short ICI paths intra, long ICI + DCI inter."""
    return Topology("tpu", (
        Link("model", ici, 50e9, 1e-6, "intra"),
        Link("data", dci, 50e9 / 4, 10e-6, "inter"),
    ), flops_per_device=197e12, hbm_bytes=16e9)


PRESETS = dict(frontier=frontier, gpu_pod=gpu_pod, tpu=tpu_pod,
               tpu_pod=tpu_pod)


def load_topology(spec: str, **kw) -> Topology:
    """Resolve a topology: preset name or a JSON file path."""
    if spec in PRESETS:
        return PRESETS[spec](**kw)
    p = Path(spec)
    if p.exists():
        return Topology.load(p)
    raise ValueError(f"unknown topology {spec!r}: not a preset "
                     f"({sorted(PRESETS)}) and no such file")


def scaled(topo: Topology, axis: str, size: int) -> Topology:
    """Same topology with one axis resized (scaling sweeps)."""
    links = tuple(replace(l, size=size) if l.name == axis else l
                  for l in topo.links)
    return replace(topo, links=links)


def calibrated(topo: Topology, eff_bandwidths: dict[str, float],
               name: str | None = None) -> Topology:
    """Same topology with *measured* effective bandwidths swapped in on the
    named axes (the ``obs.calibrate`` back-solve); axes without a
    measurement keep their preset numbers. The result round-trips through
    ``save``/``load_topology``, so ``planner --topology <file>`` plans off
    measured links."""
    links = tuple(
        replace(l, bandwidth=float(eff_bandwidths[l.name]))
        if eff_bandwidths.get(l.name, 0) and eff_bandwidths[l.name] > 0
        else l for l in topo.links)
    return replace(topo, name=name or f"{topo.name}:calibrated",
                   links=links)
