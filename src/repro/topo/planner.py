"""Search the partition-scheme space on a topology and rank by predicted
step time (the ZeRO++-style "targeted strategy", generalized to any cluster).

Search space (DESIGN.md §4): with topology axes ordered fastest -> slowest
``(a_1 .. a_k)``, every scheme is an **axis-prefix assignment**

    weight    = a_1 .. a_i          (fastest links)
    extra_grad= a_{i+1} .. a_j
    replica   = a_{j+1} .. a_k      (slowest links)

for ``0 <= i <= j <= k`` — which satisfies the AMSP dependency rule
``deg(os) >= deg(grad) >= deg(weight)`` by construction (still asserted per
candidate) — crossed with the secondary-partition placement (None or any
axis prefix; requires the INT8 weight path) and the quantization switches.
Every hand-written preset in ``core/partition.py`` is a point in this space,
so the planner's top choice can never predict worse than the presets.

CLI:

    PYTHONPATH=src python -m repro.topo.planner \
        --topology frontier --model gpt_neox_20b [--top 8] [--budget-gb 64]

``--topology`` takes a preset name (frontier / gpu_pod / tpu) or a JSON file
written by ``Topology.save`` — new clusters are config files, not code.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from ..core.partition import ZeroAxes, ZeroConfig, preset
from .cost import (ServeStepCost, ServeWorkload, StepCost, Workload,
                   serve_step_cost, step_cost)
from .model import Topology, load_topology


@dataclass(frozen=True)
class Plan:
    cfg: ZeroConfig
    cost: StepCost
    step_s: float

    @property
    def label(self) -> str:
        a = self.cfg.axes

        def j(t):
            return "+".join(t) if t else "-"

        quant = ("int8w" if self.cfg.quantize_weights else "fp16w") + \
            ("/int4g" if self.cfg.quantize_grads else "/fp16g")
        return (f"w={j(a.weight)} e={j(a.extra_grad)} r={j(a.replica)} "
                f"sec={j(a.secondary) if a.secondary is not None else 'none'} "
                f"{quant}")


def enumerate_candidates(topo: Topology, *,
                         quantize: bool | None = None) -> list[ZeroConfig]:
    """All prefix assignments x secondary placements x quantization switches.

    ``quantize=True/False`` pins both switches; None searches both.
    """
    axes = topo.axis_names
    sizes = topo.axis_sizes
    k = len(axes)
    q_opts = [(False, False), (True, False), (False, True), (True, True)] \
        if quantize is None else [(quantize, quantize)]
    out: list[ZeroConfig] = []
    seen: set = set()
    for i in range(k + 1):
        for j in range(i, k + 1):
            za = ZeroAxes(weight=axes[:i], extra_grad=axes[i:j],
                          replica=axes[j:])
            for qw, qg in q_opts:
                # secondary is an INT8 copy sliced from the quantized forward
                # gather (linear._gather_full): needs qw and a real gather
                secs: list[tuple[str, ...] | None] = [None]
                if qw and i > 0:
                    secs += [axes[:m] for m in range(1, k + 1)]
                if qw and i == 0:
                    continue   # w_degree==1: nothing to gather or compress
                for sec in secs:
                    cfg = ZeroConfig(
                        dataclasses.replace(za, secondary=sec), sizes,
                        quantize_weights=qw, quantize_grads=qg, name="auto")
                    cfg.validate_dependency_rule()
                    key = (za.weight, za.extra_grad, za.replica, sec, qw, qg)
                    if key not in seen:
                        seen.add(key)
                        out.append(cfg)
    return out


def plan(topo: Topology, wl: Workload, *,
         memory_budget: float | None = None,
         quantize: bool | None = None,
         top_k: int | None = None) -> list[Plan]:
    """Rank the whole scheme space by predicted step time under the budget.

    Plans that exceed the memory budget sort after every plan that fits
    (they are still reported — on a toy mesh nothing may fit the default
    HBM budget and the ranking is still the deliverable).
    """
    plans = []
    for cfg in enumerate_candidates(topo, quantize=quantize):
        if wl.stream_grads:
            # carry the regime on the config so an engine built from the
            # chosen plan actually streams (scheme_config("auto"))
            cfg = dataclasses.replace(cfg, stream_grads=True)
        c = step_cost(cfg, topo, wl, memory_budget=memory_budget)
        plans.append(Plan(cfg, c, c.step_s(wl.hidden_fraction)))
    plans.sort(key=lambda p: (not p.cost.fits, p.step_s,
                              p.cost.memory_total))
    return plans[:top_k] if top_k else plans


def preset_on_topology(scheme: str, topo: Topology, **over) -> ZeroConfig:
    """Build a hand-written preset on this topology's tier split."""
    t = topo.tiers()
    return preset(scheme, intra_axes=t["intra"], inter_axes=t["inter"],
                  l0_axes=t["l0"] or None, axis_sizes=dict(topo.axis_sizes),
                  **over)


def plan_for_mesh(mesh, *, psi: float | None = None,
                  n_layers: int | None = None,
                  memory_budget: float | None = None,
                  stream_grads: bool = False,
                  top_k: int | None = None, **topo_kw) -> list[Plan]:
    """Run the planner against a live mesh (``--scheme auto``).

    Axis link data comes from ``Topology.from_mesh`` tier defaults unless
    overridden.  ``psi``/``n_layers`` default to the paper's 20B / 44-layer
    evaluation model when the caller has no model at hand.
    ``stream_grads`` prices (and tags) the streaming grad regime.
    """
    topo = Topology.from_mesh(mesh, **topo_kw)
    wl = Workload(psi=float(psi) if psi else 20e9,
                  n_layers=int(n_layers) if n_layers else 44,
                  stream_grads=stream_grads)
    budget = memory_budget if memory_budget is not None else float("inf")
    # default budget inf: the live mesh is often fake CPU devices — ranking,
    # not feasibility, is the deliverable there; real launches pass a budget
    return plan(topo, wl, memory_budget=budget, top_k=top_k)


def model_workload(model_name: str, *, n_microbatch: int = 4,
                   tokens_per_device_mb: int = 2048,
                   stream_grads: bool = False) -> Workload:
    """Workload from a registered architecture (CLI helper).

    Accepts registry names with ``_`` or ``-`` separators
    (``gpt_neox_20b`` == ``gpt-neox-20b``).
    """
    from ..models.registry import build_model, get_arch, list_archs
    names = {n.replace("-", "_").replace(".", "_"): n for n in list_archs()}
    canon = model_name.replace("-", "_").replace(".", "_")
    if canon not in names and model_name not in list_archs():
        raise SystemExit(f"unknown model {model_name!r}; "
                         f"known: {', '.join(list_archs())}")
    arch = get_arch(names.get(canon, model_name))
    psi = build_model(arch).param_count()
    return Workload(psi=float(psi), n_layers=arch.n_layers,
                    n_microbatch=n_microbatch,
                    tokens_per_device_mb=tokens_per_device_mb,
                    stream_grads=stream_grads)


def replan_from_checkpoint(ckpt: str, topo: Topology, *,
                           step: int | None = None,
                           memory_budget: float | None = None,
                           stream_grads: bool = False,
                           top_k: int | None = None):
    """Elastic re-plan (DESIGN.md §11): price the SURVIVING topology for the
    workload recorded in a checkpoint's meta.json and rank new schemes.

    ``ckpt`` is either a ``step_NNNNNNNN`` directory or a checkpoint root
    (latest step picked). The workload is recovered from the checkpoint
    itself — psi from the primaries' global shapes, layer count from the
    stacked leading dim — so no model registry lookup is needed; the chosen
    scheme is what ``launch.train --scheme auto --resume`` would build on
    the new mesh, and the elastic restore path reshards the checkpoint onto
    it. Returns ``(meta, workload, plans)``. Reads only meta.json (no jax).
    """
    import json
    from pathlib import Path
    p = Path(ckpt)
    if not p.name.startswith("step_"):
        steps = sorted(int(q.name.split("_")[1]) for q in p.glob("step_*"))
        if step is None:
            if not steps:
                raise SystemExit(f"no checkpoints under {ckpt}")
            step = steps[-1]
        p = p / f"step_{step:08d}"
    meta = json.loads((p / "meta.json").read_text())
    shapes = {k: v for k, v in meta.get("global_shapes", {}).items()
              if k.startswith("primaries/")}
    if not shapes:
        raise SystemExit(f"{p}/meta.json records no primaries leaves")
    psi = sum(math.prod(v) for v in shapes.values())
    n_layers = max([v[0] for v in shapes.values() if len(v) == 2],
                   default=1)
    wl = Workload(psi=float(psi), n_layers=int(n_layers),
                  stream_grads=stream_grads)
    return meta, wl, plan(topo, wl, memory_budget=memory_budget,
                          top_k=top_k)


@dataclass(frozen=True)
class ServePlan:
    """One serving layout: residency axes x backend (DESIGN.md §12)."""
    res_axes: tuple[str, ...]
    resident: bool
    cost: ServeStepCost
    tok_s: float

    @property
    def label(self) -> str:
        ax = "+".join(self.res_axes) if self.res_axes else "-"
        return f"res={ax} {'int8-wire' if self.resident else 'fp-gathered'}"


def serve_workload_for_model(model_name: str, *, n_slots: int = 8,
                             context: int = 1024, max_len: int = 2048,
                             page_size: int = 16,
                             quant_block: int = 64) -> ServeWorkload:
    """Serving workload from a registered architecture, with the exact
    all-layer KV bytes/token taken from ``model.cache_shapes`` (the same
    source of truth the paged pool provisions from)."""
    from ..models.config import ShapeConfig
    from ..models.registry import build_model, get_arch, list_archs
    names = {n.replace("-", "_").replace(".", "_"): n for n in list_archs()}
    canon = model_name.replace("-", "_").replace(".", "_")
    if canon not in names and model_name not in list_archs():
        raise SystemExit(f"unknown model {model_name!r}; "
                         f"known: {', '.join(list_archs())}")
    arch = get_arch(names.get(canon, model_name))
    model = build_model(arch)
    import numpy as np
    shape = ShapeConfig("plan", max_len, n_slots, "decode")
    kv_per_tok = 0.0
    for entry in model.cache_shapes(shape).values():
        for (shp, dtype, seq_shard) in entry.values():
            if seq_shard:   # (count, b, s, *tail): bytes/token = count * tail
                kv_per_tok += shp[0] * math.prod(shp[3:]) \
                    * np.dtype(dtype).itemsize
    return ServeWorkload(psi=float(model.param_count()),
                         n_layers=arch.n_layers, d_model=arch.d_model,
                         n_slots=n_slots, context=context, max_len=max_len,
                         kv_bytes_per_token=kv_per_tok, page_size=page_size,
                         quant_block=quant_block)


def plan_serve(topo: Topology, wl: ServeWorkload, *,
               memory_budget: float | None = None,
               top_k: int | None = None) -> list[ServePlan]:
    """Rank serving layouts: every residency axis-prefix x backend.

    The trade is the serving analog of the training weight axes: a larger
    residency degree shrinks per-device wire bytes but pays the per-layer
    re-gather on every decoded token. Fitting layouts rank first, then by
    predicted tokens/s (descending), then by memory."""
    axes = topo.axis_names
    out = []
    for i in range(len(axes) + 1):
        for resident in (True, False):
            c = serve_step_cost(topo, wl, axes[:i], resident=resident,
                                memory_budget=memory_budget)
            out.append(ServePlan(axes[:i], resident, c, c.tokens_per_s()))
    out.sort(key=lambda p: (not p.cost.fits, -p.tok_s, p.cost.memory_total))
    return out[:top_k] if top_k else out


def format_serve_plans(plans: list[ServePlan], top_k: int = 8) -> str:
    rows = [f"{'#':>3s} {'tok/s':>10s} {'step(ms)':>9s} {'comm(ms)':>9s} "
            f"{'mem/dev':>9s} {'AI':>7s} {'fits':>4s}  layout"]
    for r, p in enumerate(plans[:top_k], 1):
        rows.append(
            f"{r:3d} {p.tok_s:10.1f} {p.cost.step_s() * 1e3:9.3f} "
            f"{p.cost.comm_total_s * 1e3:9.3f} "
            f"{p.cost.memory_total / 1e9:8.2f}G "
            f"{p.cost.arithmetic_intensity():7.1f} "
            f"{'y' if p.cost.fits else 'N':>4s}  {p.label}")
    return "\n".join(rows)


def format_plans(plans: list[Plan], presets: dict[str, Plan] | None = None,
                 top_k: int = 8) -> str:
    rows = [f"{'#':>3s} {'step(s)':>9s} {'comm(s)':>9s} {'mem/dev':>9s} "
            f"{'fits':>4s}  scheme"]
    for r, p in enumerate(plans[:top_k], 1):
        rows.append(f"{r:3d} {p.step_s:9.4f} {p.cost.comm_total_s:9.4f} "
                    f"{p.cost.memory_total / 1e9:8.2f}G "
                    f"{'y' if p.cost.fits else 'N':>4s}  {p.label}")
    if presets:
        rows.append("  -- hand-written presets, same cost model --")
        for name, p in presets.items():
            rows.append(f"    {p.step_s:9.4f} {p.cost.comm_total_s:9.4f} "
                        f"{p.cost.memory_total / 1e9:8.2f}G "
                        f"{'y' if p.cost.fits else 'N':>4s}  "
                        f"{name}: {p.label}")
    return "\n".join(rows)


def build_parser():
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.topo.planner",
        description="rank ZeRO partition schemes on a topology")
    ap.add_argument("--topology", default="frontier",
                    help="preset name (frontier/gpu_pod/tpu) or JSON path")
    ap.add_argument("--model", default="gpt_neox_20b",
                    help="registered architecture for the workload")
    ap.add_argument("--replan-from", default="",
                    help="checkpoint dir (root or step_NNNNNNNN): take the "
                         "workload from its meta.json and re-plan for the "
                         "surviving --topology; adopt the choice by "
                         "relaunching with --scheme auto --resume "
                         "(elastic restore reshards the checkpoint)")
    ap.add_argument("--n-microbatch", type=int, default=4)
    ap.add_argument("--tokens-per-device", type=int, default=2048)
    ap.add_argument("--budget-gb", type=float, default=0.0,
                    help="per-device memory budget; 0 = topology HBM")
    ap.add_argument("--top", type=int, default=8)
    ap.add_argument("--no-quant", action="store_true",
                    help="restrict the search to unquantized collectives")
    ap.add_argument("--stream-grads", action="store_true",
                    help="price the streaming grad regime (DESIGN.md §8): "
                         "per-layer grad RS overlapped with the backward, "
                         "grad memory at os-shard layout")
    ap.add_argument("--save-topology", default="",
                    help="write the resolved topology JSON here and exit")
    ap.add_argument("--serve", action="store_true",
                    help="rank SERVING layouts instead of training schemes: "
                         "residency axis-prefixes x {int8-wire, fp-gathered} "
                         "priced by per-token gather/dequant volume, KV-page "
                         "traffic, and batch-dependent arithmetic intensity "
                         "(DESIGN.md §12)")
    ap.add_argument("--slots", type=int, default=8,
                    help="live decode slots (serve workload batch)")
    ap.add_argument("--context", type=int, default=1024,
                    help="mean live context per slot, tokens (serve)")
    ap.add_argument("--max-len", type=int, default=2048,
                    help="paged-pool provisioning length per slot (serve)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens (serve)")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)

    topo = load_topology(args.topology)
    if args.save_topology:
        print(topo.save(args.save_topology))
        return 0
    budget = args.budget_gb * 1e9 if args.budget_gb else None
    if args.serve:
        swl = serve_workload_for_model(
            args.model, n_slots=args.slots, context=args.context,
            max_len=args.max_len, page_size=args.page_size)
        plans_s = plan_serve(topo, swl, memory_budget=budget)
        print(f"topology {topo.name}: " + ", ".join(
            f"{l.name}({l.size}) {l.bandwidth / 1e9:.0f}GB/s {l.tier}"
            for l in topo.links) + f"  [{topo.n_devices} devices]")
        print(f"serve workload: psi={swl.psi / 1e9:.1f}B params, "
              f"{swl.n_layers} layers, {swl.n_slots} slots x "
              f"{swl.context} ctx (max {swl.max_len}), "
              f"{swl.kv_token_bytes() / 1e3:.1f}KB KV/token, "
              f"page={swl.page_size}")
        print(format_serve_plans(plans_s, top_k=args.top))
        best = plans_s[0]
        print(f"serve: residency over {best.label} — adopt with "
              f"`repro.launch.serve --backend "
              f"{'resident' if best.resident else 'gathered'}"
              + (f" --res-axes {','.join(best.res_axes)}`"
                 if best.res_axes else "`"))
        return 0
    if args.replan_from:
        meta, wl, plans = replan_from_checkpoint(
            args.replan_from, topo, memory_budget=budget,
            stream_grads=args.stream_grads)
        saved_mesh = meta.get("mesh", {})
        saved_scheme = meta.get("scheme", {})
        print(f"re-planning from checkpoint step {meta.get('step')}: "
              f"psi={wl.psi / 1e9:.2f}B (padded), {wl.n_layers} layers")
        print(f"  written on: {dict(zip(saved_mesh.get('axes', []), saved_mesh.get('shape', [])))} "
              f"{saved_mesh.get('process_count')} process(es), "
              f"scheme={saved_scheme.get('scheme')} "
              f"degrees={saved_scheme.get('degrees')}")
        print(f"  surviving topology {topo.name}: " + ", ".join(
            f"{l.name}({l.size}) {l.bandwidth / 1e9:.0f}GB/s {l.tier}"
            for l in topo.links) + f"  [{topo.n_devices} devices]")
        print(format_plans(plans, top_k=args.top))
        print("adopt: relaunch `repro.launch.train --scheme auto --resume "
              "--ckpt-dir ...` on the surviving mesh — elastic restore "
              "reshards every leaf onto the new layout (DESIGN.md §11)")
        return 0
    wl = model_workload(args.model, n_microbatch=args.n_microbatch,
                        tokens_per_device_mb=args.tokens_per_device,
                        stream_grads=args.stream_grads)
    plans = plan(topo, wl, memory_budget=budget,
                 quantize=False if args.no_quant else None)
    presets = {}
    for scheme in ("zero3", "zeropp", "zero_topo"):
        # presets priced in the same regime so the dominance check compares
        # like with like
        cfg = preset_on_topology(scheme, topo,
                                 stream_grads=args.stream_grads)
        c = step_cost(cfg, topo, wl, memory_budget=budget)
        presets[scheme] = Plan(cfg, c, c.step_s(wl.hidden_fraction))

    print(f"topology {topo.name}: " + ", ".join(
        f"{l.name}({l.size}) {l.bandwidth / 1e9:.0f}GB/s {l.tier}"
        for l in topo.links) + f"  [{topo.n_devices} devices]")
    print(f"workload: psi={wl.psi / 1e9:.1f}B params, {wl.n_layers} layers, "
          f"{wl.n_microbatch}x{wl.tokens_per_device_mb} tokens/device/step, "
          f"{len(plans)} candidate schemes")
    print(format_plans(plans, presets, top_k=args.top))

    # dominance is within the same feasibility class: a preset that blows
    # the memory budget may have a lower raw step time, but the planner
    # correctly ranks every fitting plan ahead of it
    def rank_key(p):
        return (not p.cost.fits, p.step_s)

    best = plans[0]
    fastest_preset = min(presets.values(), key=rank_key)
    worst_preset = max(presets.values(), key=rank_key)
    print(f"planner choice is {fastest_preset.step_s / best.step_s:.2f}x the "
          f"best preset, {worst_preset.step_s / best.step_s:.2f}x the worst"
          + ("" if fastest_preset.cost.fits else
             "  (no preset fits the memory budget)"))
    if args.no_quant:
        # the quantized presets are outside the restricted search space, so
        # dominance is not guaranteed (the comparison is informational)
        print("note: search restricted to unquantized schemes; quantized "
              "presets may rank faster")
    else:
        assert rank_key(best) <= rank_key(fastest_preset), \
            "planner must never rank below a preset (presets are in the space)"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
