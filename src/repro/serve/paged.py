"""Paged KV cache: fixed-size pages, slot -> page table, no reallocation.

The seed ``ContinuousBatcher`` kept one dense ``(L, B, max_len, ...)`` cache
per sequence-indexed entry and spliced every admitted request in with a
host-side per-leaf loop (``_grow_seq`` + ``_splice``). This module replaces
that with the vLLM-style layout at miniature scale:

* Sequence-indexed cache entries (full-attention k/v, MLA latents) live in a
  **page pool** ``(L, n_pages + 1, page_size, *tail)``; a slot owns pages
  through a host-side page table ``(n_slots, blocks_per_slot)`` and pages
  are allocated lazily as positions advance, so provisioning
  ``n_pages < n_slots * blocks_per_slot`` oversubscribes KV memory the way
  real serving does (the batcher preempts when the free list runs dry).
  Page index ``n_pages`` is a write sink: inactive slots and unallocated
  table entries point at it, and nothing ever reads it un-masked —
  flash-decode masks ``kpos <= pos`` per row, so garbage beyond a row's
  position is arithmetic-neutral (exp(-inf) == 0 exactly).

* O(1)-per-slot entries (sliding-window rings, SSM states, cross k/v) stay
  dense ``(L, n_slots, ...)`` — paging them buys nothing.

* Admission is a **single jitted, donated scatter**: the B=1 prefill cache
  is reshaped into whole pages and written to the slot's pages + per-slot
  rows in one compiled call (no per-leaf host round-trip).

The dense decode view is assembled per step by one gather
(``pool.take(table)``) and the decode step's single written position is
scattered back, both inside the same jit as the decode shard_map — the
decode math itself is unchanged, which is why paged serving stays bitwise
with the dense engines (tests/test_paged.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ShapeConfig


def seq_entry_keys(model, shape: ShapeConfig) -> set[tuple[str, str]]:
    """(kind, name) pairs whose caches are sequence-indexed (pageable)."""
    shapes = model.cache_shapes(shape)
    return {(kind, name)
            for kind, entry in shapes.items()
            for name, (_, _, seq_shard) in entry.items() if seq_shard}


@dataclass
class PagedKV:
    """Page-pool layout + host-side page table for one decode shape.

    ``shape`` is the decode ShapeConfig: ``global_batch`` = n_slots,
    ``seq_len`` = max_len. The device-side state is a pytree shaped like the
    dense cache dict except that pageable entries are page pools; the page
    table and free list live on the host (numpy) and are re-uploaded per
    step (n_slots * blocks_per_slot int32 — trivia next to the pool).
    """
    model: object
    shape: ShapeConfig
    page_size: int
    n_pages: int = 0          # 0 = fully provisioned (no oversubscription)
    seq_keys: set = field(init=False)
    blocks_per_slot: int = field(init=False)
    table: np.ndarray = field(init=False)
    free: list = field(init=False)
    owner: np.ndarray = field(init=False)   # page -> slot (-1 free)

    def __post_init__(self):
        n_slots, max_len = self.shape.global_batch, self.shape.seq_len
        assert max_len % self.page_size == 0, (max_len, self.page_size)
        self.blocks_per_slot = max_len // self.page_size
        if not self.n_pages:
            self.n_pages = n_slots * self.blocks_per_slot
        self.seq_keys = seq_entry_keys(self.model, self.shape)
        self.table = np.full((n_slots, self.blocks_per_slot), -1, np.int32)
        self.free = list(range(self.n_pages))
        self.owner = np.full((self.n_pages,), -1, np.int32)

    # -- host-side page accounting ------------------------------------------

    def pages_needed(self, length: int) -> int:
        return -(-length // self.page_size)

    def free_pages(self) -> int:
        return len(self.free)

    def alloc(self, slot: int, block: int) -> bool:
        """Allocate page for ``table[slot, block]``; False if none free."""
        if self.table[slot, block] >= 0:
            return True
        if not self.free:
            return False
        page = self.free.pop(0)
        self.table[slot, block] = page
        self.owner[page] = slot
        return True

    def alloc_prefix(self, slot: int, length: int) -> bool:
        """Allocate the first ``pages_needed(length)`` pages of a slot."""
        need = self.pages_needed(length)
        if len([b for b in range(need) if self.table[slot, b] < 0]) \
                > len(self.free):
            return False
        return all(self.alloc(slot, b) for b in range(need))

    def release(self, slot: int):
        """Return a finished/preempted slot's pages to the free list."""
        for b in range(self.blocks_per_slot):
            page = self.table[slot, b]
            if page >= 0:
                self.owner[page] = -1
                self.free.append(int(page))
                self.table[slot, b] = -1

    def device_table(self) -> jnp.ndarray:
        """Page table with unallocated entries redirected to the sink."""
        return jnp.asarray(np.where(self.table < 0, self.n_pages,
                                    self.table).astype(np.int32))

    # -- device-side layout --------------------------------------------------

    def _pool_sds(self, dense_sds):
        """Dense cache ShapeDtypeStructs -> pool-state ShapeDtypeStructs."""
        out = {}
        for kind, entry in dense_sds.items():
            if kind == "pos":
                out[kind] = entry
                continue
            out[kind] = {}
            for name, s in entry.items():
                if (kind, name) in self.seq_keys:
                    tail = s.shape[3:]
                    out[kind][name] = jax.ShapeDtypeStruct(
                        (s.shape[0], self.n_pages + 1, self.page_size)
                        + tail, s.dtype)
                else:
                    out[kind][name] = jax.ShapeDtypeStruct(s.shape, s.dtype)
        return out

    def init_pool(self, dense_sds):
        """Zero-initialized pool state matching the dense cache sds tree."""
        sds = self._pool_sds(dense_sds)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def assemble(self, pool, table):
        """Pool state -> dense decode view: one gather per pageable entry.

        ``table`` (n_slots, blocks_per_slot) int32 with sink redirection
        (``device_table``); the dense view's row r is its pages in order,
        i.e. exactly the seed contiguous layout for every written position.
        """
        out = {}
        for kind, entry in pool.items():
            if kind == "pos":
                out[kind] = entry
                continue
            out[kind] = {}
            for name, v in entry.items():
                if (kind, name) in self.seq_keys:
                    d = jnp.take(v, table, axis=1)
                    # (L, B, blocks, page, *tail) -> (L, B, S, *tail)
                    out[kind][name] = d.reshape(
                        d.shape[:2] + (d.shape[2] * d.shape[3],)
                        + d.shape[4:])
                else:
                    out[kind][name] = v
        return out

    def writeback(self, pool, dense_new, table, row_pos, active):
        """Scatter the decode step's written position back into the pool.

        Each active row wrote exactly one new position (``row_pos``); its
        page-local address is ``(table[r, pos // page], pos % page)``.
        Inactive rows are redirected to the sink page. Non-pageable entries
        were updated in place by the decode and replace the pool's copy.
        """
        b = row_pos.shape[0]
        page_i = jnp.where(active,
                           table[jnp.arange(b), row_pos // self.page_size],
                           self.n_pages)
        off = row_pos % self.page_size
        out = {}
        for kind, entry in dense_new.items():
            if kind == "pos":
                out[kind] = entry
                continue
            out[kind] = {}
            for name, d in entry.items():
                if (kind, name) in self.seq_keys:
                    idx = row_pos.reshape((1, -1) + (1,) * (d.ndim - 2))
                    row = jnp.take_along_axis(d, idx, axis=2)[:, :, 0]
                    out[kind][name] = \
                        pool[kind][name].at[:, page_i, off].set(row)
                else:
                    out[kind][name] = d
        return out

    def admit_scatter(self, pool, c1, slot, slot_pages):
        """One donated scatter: B=1 prefill cache -> slot's pages + rows.

        ``slot_pages`` (pages_needed(prompt_len),) int32 — the slot's
        allocated prompt pages; pageable entries are cut into whole pages
        (zero-padded to a page boundary) and written with one scatter each,
        per-slot entries take the prefill row at batch index ``slot``.
        """
        n_pp = slot_pages.shape[0]
        out = {}
        for kind, entry in pool.items():
            if kind == "pos":
                out[kind] = jnp.maximum(entry, c1["pos"])
                continue
            out[kind] = {}
            for name, dst in entry.items():
                src = c1[kind][name].astype(dst.dtype)
                if (kind, name) in self.seq_keys:
                    row = src[:, 0]                       # (L, P, *tail)
                    pad = n_pp * self.page_size - row.shape[1]
                    if pad:
                        width = [(0, 0)] * row.ndim
                        width[1] = (0, pad)
                        row = jnp.pad(row, width)
                    row = row.reshape((row.shape[0], n_pp, self.page_size)
                                      + row.shape[2:])
                    out[kind][name] = dst.at[:, slot_pages].set(row)
                else:
                    r = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
                    out[kind][name] = jax.lax.dynamic_update_slice_in_dim(
                        dst, r, slot, axis=1)
        return out
