"""Quantized-resident serving: the INT8 wire format as the weight residency.

The paper-faithful serving path (``ServeEngine``) reuses ZeRO's per-layer
weight all-gather: every decoded token re-quantizes the primary shards and
re-gathers the full parameter set over the weight axes. This module removes
both per-token costs without leaving the wire format:

* **Residency = the secondary partition.** At server start, one jitted
  shard_map quantizes + gathers each MATMUL/GATHER_Q leaf exactly the way
  the training forward does (``col.gather_issue_int8`` under the per-leaf
  config) and keeps only this device's ``col.residency_slice`` — by default
  over ``cfg.axes.secondary``, i.e. the resident shards ARE the training
  engine's secondary partition. No fp re-materialization: the build reads
  ``state["primaries"]`` and never touches the fp32 master.

* **Decode consumes the wire format.** ``ResidentView.mm`` re-gathers the
  INT8 payload + scales per layer (``col.gather_residency_q``) and routes
  them through the same fused ``dequant_matmul_flat`` path as training
  (``linear._mm_apply_q``, ``ops`` dispatch: jnp | pallas |
  pallas_interpret). slice-then-regather is a bitwise identity and the
  matmul epilogues are shared code, so prefill logits and greedy decode
  tokens are bitwise identical to the training engine's forward at matching
  quant config (tests/_scenarios.py::serve_resident_quant_equivalence).

Per-token wire traffic drops from ``quantize + all-gather(psi)`` over the
weight axes to ``all-gather(psi/|R|)`` of pre-quantized shards over the
residency axes, for a resident cost of ``psi/|R| + 4*psi/(block*|R|)`` bytes
per device (``partition.resident_memory_bytes``). Leaves outside the wire
format (PLAIN leaves; every leaf when the scheme doesn't quantize weights)
are materialized once through the same gather code path as training and kept
dense + replicated, so equivalence holds config-by-config.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import collectives as col
from ..core import linear
from ..core.engine import ParamView, ZeroEngine
from ..core.partition import GATHER_Q, MATMUL, resident_memory_bytes
from ..models.config import ShapeConfig
from ..models.registry import ModelDef, model_axes
from .engine import ServeConfig, make_serve_config

WIRE = "wire"     # INT8 payload + per-block scales, sharded over res axes
DENSE = "dense"   # compute-dtype dense tensor, replicated


def default_res_axes(cfg, mesh: Mesh) -> tuple[str, ...]:
    """Residency axes: the training secondary partition when the scheme has
    one, else the mesh's model tier (intra-node bandwidth for the per-token
    re-gather)."""
    if cfg.axes.secondary:
        return tuple(cfg.axes.secondary)
    return tuple(model_axes(mesh))


@dataclass
class ResidentLayout:
    """Shapes/specs of the wire-format residency for one engine + axes."""
    engine: ZeroEngine
    res_axes: tuple[str, ...]
    res_degree: int = field(init=False)

    def __post_init__(self):
        self.res_axes = tuple(self.res_axes)
        self.res_degree = self.engine.cfg.size(self.res_axes)

    def mode(self, name: str) -> str:
        spec = self.engine.specs[name]
        lcfg = self.engine.leaf_cfg[name]
        if spec.kind in (MATMUL, GATHER_Q) and lcfg.quantize_weights:
            return WIRE
        return DENSE

    def wire_lens(self, name: str) -> tuple[int, int]:
        """Per-device (q, scales) residency lengths for a WIRE leaf."""
        pad = self.engine._pad[name]
        lcfg = self.engine.leaf_cfg[name]
        return (pad // self.res_degree,
                pad // lcfg.quant_block // self.res_degree)

    def pspec(self, name: str):
        spec = self.engine.specs[name]
        if self.mode(name) == WIRE:
            ax = self.res_axes if self.res_axes else None
            p = P(None, ax) if spec.stack else P(ax)
            return {"q": p, "s": p}
        return P()

    def in_specs(self):
        return {n: self.pspec(n) for n in self.engine.specs}

    def abstract(self, mesh: Mesh):
        """ShapeDtypeStructs (global shapes + shardings) of the residency."""
        out = {}
        cdt = linear._dtype(self.engine.cfg)
        for name, spec in self.engine.specs.items():
            if self.mode(name) == WIRE:
                qlen, slen = self.wire_lens(name)
                qlen *= self.res_degree
                slen *= self.res_degree
                qshape = (spec.stack, qlen) if spec.stack else (qlen,)
                sshape = (spec.stack, slen) if spec.stack else (slen,)
                sh = NamedSharding(mesh, self.pspec(name)["q"])
                out[name] = {
                    "q": jax.ShapeDtypeStruct(qshape, jnp.int8, sharding=sh),
                    "s": jax.ShapeDtypeStruct(sshape, jnp.float32,
                                              sharding=sh)}
            else:
                shape = ((spec.stack,) + spec.shape) if spec.stack \
                    else spec.shape
                out[name] = jax.ShapeDtypeStruct(
                    shape, cdt, sharding=NamedSharding(mesh, P()))
        return out

    def memory_report(self) -> dict[str, Any]:
        """Per-device resident bytes, wire vs dense, plus the formula view."""
        cdt = linear._dtype(self.engine.cfg)
        wire = dense = 0
        for name, spec in self.engine.specs.items():
            reps = spec.stack or 1
            if self.mode(name) == WIRE:
                qlen, slen = self.wire_lens(name)
                wire += reps * (qlen + 4 * slen)
            else:
                dense += reps * spec.logical_size * cdt.itemsize
        psi = sum(s.logical_size * (s.stack or 1)
                  for n, s in self.engine.specs.items()
                  if self.mode(n) == WIRE)
        return dict(
            res_axes=list(self.res_axes), res_degree=self.res_degree,
            wire_bytes=int(wire), dense_bytes=int(dense),
            total_bytes=int(wire + dense),
            formula_bytes=int(resident_memory_bytes(
                self.engine.cfg, psi, res_degree=self.res_degree)))


def build_resident(engine: ZeroEngine, state, mesh: Mesh,
                   res_axes: tuple[str, ...] | None = None):
    """One jitted shard_map: training primary shards -> wire residency.

    Reads ``state["primaries"]`` only (never the fp32 master): each WIRE
    leaf runs the training forward's own quantize + weight-axes gather and
    keeps this device's residency slice; DENSE leaves run the training
    gather and stay replicated in compute dtype. Returns (layout, residency).
    """
    cfg = engine.cfg
    if res_axes is None:
        res_axes = default_res_axes(cfg, mesh)
    layout = ResidentLayout(engine, tuple(res_axes))
    prim_specs = engine.state_in_specs()["primaries"]

    def convert(primaries):
        out = {}
        for name, spec in engine.specs.items():
            lcfg = engine.leaf_cfg[name]
            prim = primaries[name]
            if layout.mode(name) == WIRE:
                if spec.stack:
                    qf, sf = col.gather_issue_int8_rows(
                        prim, cfg.axes.weight, lcfg)
                else:
                    qf, sf = col.gather_issue_int8(prim, cfg.axes.weight,
                                                   lcfg)
                q, s = col.residency_slice(qf, sf, layout.res_axes, lcfg)
                out[name] = {"q": q, "s": s}
            else:
                full = col.all_gather_flat(prim, cfg.axes.weight)
                n = spec.logical_size
                if spec.stack:
                    dense = full[:, :n].reshape((spec.stack,) + spec.shape)
                else:
                    dense = full[:n].reshape(spec.shape)
                out[name] = dense.astype(linear._dtype(lcfg))
        return out

    sm = shard_map(convert, mesh=mesh, in_specs=(prim_specs,),
                   out_specs=layout.in_specs(), check_vma=False)
    return layout, jax.jit(sm)(state["primaries"])


class ResidentView(ParamView):
    """ParamView over the wire-format residency (runs inside shard_map).

    ``mm`` on a fusable leaf re-gathers (q, scales) over the residency axes
    and calls the shared ``linear._mm_apply_q`` — the same fused
    dequant-matmul epilogue as the training forward, so serving math cannot
    drift from training math. Non-fusable / dense leaves mirror the
    training ``_gather_full`` + ``_mm_apply`` pair op for op.
    ``embed_lookup`` / ``expert_ffn`` inherit the ParamView defaults on top
    of ``get``, which keeps them bitwise too.
    """

    def __init__(self, layout: ResidentLayout, params: dict[str, Any]):
        self._layout = layout
        self._p = params

    def _wire(self, name: str):
        entry = self._p[name]
        lcfg = self._layout.engine.leaf_cfg[name]
        return col.gather_residency_q(entry["q"], entry["s"],
                                      self._layout.res_axes, lcfg)

    def mm(self, name: str, x, transpose: bool = False):
        eng = self._layout.engine
        spec = eng.specs[name]
        lcfg = eng.leaf_cfg[name]
        if self._layout.mode(name) == WIRE:
            qf, sf = self._wire(name)
            if linear._fusable(spec, lcfg):
                return linear._mm_apply_q(x, qf, sf, transpose, spec, lcfg)
            full = col.gather_wait_int8(qf, sf, lcfg, linear._dtype(lcfg))
            w = lax.slice(full, (0,), (spec.logical_size,)).reshape(spec.shape)
            return linear._mm_apply(x, w, transpose, lcfg)
        return linear._mm_apply(x, self._p[name], transpose, lcfg)

    def get(self, name: str):
        eng = self._layout.engine
        spec = eng.specs[name]
        lcfg = eng.leaf_cfg[name]
        if self._layout.mode(name) == WIRE:
            qf, sf = self._wire(name)
            full = col.gather_wait_int8(qf, sf, lcfg, linear._dtype(lcfg))
            return lax.slice(full, (0,), (spec.logical_size,)
                             ).reshape(spec.shape)
        return self._p[name]

    def sub(self, params):
        return ResidentView(self._layout, params)


class ResidentServeEngine:
    """ServeEngine twin that serves from the INT8 wire residency."""

    def __init__(self, model: ModelDef, engine: ZeroEngine, mesh: Mesh,
                 shape: ShapeConfig, sc: ServeConfig | None = None,
                 res_axes: tuple[str, ...] | None = None):
        self.model = model
        self.engine = engine
        self.mesh = mesh
        self.shape = shape
        self.sc = sc or make_serve_config(mesh, shape.global_batch)
        if res_axes is None:
            res_axes = default_res_axes(engine.cfg, mesh)
        self.layout = ResidentLayout(engine, tuple(res_axes))
        self.axis_sizes = dict(mesh.shape)

    def abstract_params(self):
        return self.layout.abstract(self.mesh)

    def _wrap(self, fn, extra_in, extra_out, donate=None):
        specs = self.layout.in_specs()

        def local(params, *args):
            view = ResidentView(self.layout, params)
            return fn(view, *args)

        sm = shard_map(local, mesh=self.mesh,
                       in_specs=(specs,) + tuple(extra_in),
                       out_specs=extra_out, check_vma=False)
        if donate:
            return jax.jit(sm, donate_argnums=donate)
        return jax.jit(sm)

    def make_prefill(self, seq_parallel: bool = False):
        m, sc = self.model, self.sc
        shapes = m.prefill_batch_shapes(self.shape)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        fn = m.prefill_fn(sc.seq_axes, self.axis_sizes, seq_parallel)
        ba = sc.batch_axes_ if sc.batch_axes_ else None
        return self._wrap(fn, (bspecs,), (P(ba), cspecs))

    def make_decode(self, per_row_pos: bool = False):
        m, sc = self.model, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        if per_row_pos:
            shapes["row_pos"] = ((self.shape.global_batch,), jnp.int32)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        fn = m.decode_fn(sc.seq_axes, self.axis_sizes)
        ba = sc.batch_axes_ if sc.batch_axes_ else None
        return self._wrap(fn, (cspecs, bspecs), (P(ba), cspecs), donate=(1,))

    def decode_inputs_sds(self):
        m, sc = self.model, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        batch = m.batch_sds(shapes, self.mesh, sc.batch_axes_)
        caches = m.cache_sds(self.shape, self.mesh, sc.batch_axes_,
                             sc.seq_axes)
        return caches, batch

    def prefill_inputs_sds(self):
        shapes = self.model.prefill_batch_shapes(self.shape)
        return self.model.batch_sds(shapes, self.mesh, self.sc.batch_axes_)

    def generate(self, residency, prompt_batch, n_tokens: int):
        """Greedy generation driver (CPU-testable): prefill then decode."""
        prefill = self.make_prefill()
        decode = self.make_decode()
        logits, caches = prefill(residency, prompt_batch)
        toks = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        for _ in range(n_tokens - 1):
            logits, caches = decode(residency, caches, {"token": toks[-1]})
            toks.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return jnp.stack(toks, axis=1)
