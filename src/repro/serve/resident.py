"""Resident tensor-parallel serving (beyond-paper optimization; EXPERIMENTS.md
§Perf pair 2).

The paper-faithful serving path reuses ZeRO's per-layer weight all-gather —
every decoded token re-gathers the full parameter set over the model axes.
For jamba-52B decode_32k that is ~1 GB of collective traffic **per token**
(the most collective-bound pair in the baseline roofline).

The fix is the classic inference trade: make weights *resident* and move the
collectives onto activations. Each matmul leaf is column-sharded over the TP
axes and its output all-gathered (embedding rows are row-sharded with a psum;
MoE experts use the Megatron pairing: gate/up column-sharded, down
row-sharded, one psum per expert block). Per-token traffic drops from
O(params) to O(activations) — a ~1000x cut at jamba scale — for a resident
memory cost of 2*psi/|TP| bytes per device (jamba: 6.5 GB/chip, fits v5e).

``build_resident`` reshapes the ZeRO primary shards into the resident layout
once at server start (one-time cost, amortized over the serving lifetime).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import collectives as col
from ..core.engine import ParamView, ZeroEngine
from ..core.partition import GATHER_Q, MATMUL, LeafSpec
from ..models.config import ShapeConfig
from ..models.registry import ModelDef, batch_axes, data_axes, model_axes
from .engine import ServeConfig, make_serve_config


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _policy(name: str, spec: LeafSpec) -> str:
    """How each leaf is laid out in resident form."""
    if name == "embed":
        return "row"                       # (V, d): shard V; lookup via psum
    if spec.kind == MATMUL and name.endswith("lm_head"):
        return "row"
    if spec.kind == GATHER_Q and len(spec.shape) == 3 \
            and name.split(".")[-1] in ("w_gate", "w_up"):
        return "expert_col"                # (E, d, ff): shard ff
    if spec.kind == GATHER_Q and len(spec.shape) == 3 \
            and name.split(".")[-1] == "w_down":
        return "expert_row"                # (E, ff, d): shard ff (contraction)
    if spec.kind == MATMUL:
        return "col"                       # (in.., out): shard out
    return "replicated"                    # norms, biases, scan params


@dataclass
class ResidentLayout:
    engine: ZeroEngine
    tp_axes: tuple[str, ...]
    tp: int

    def leaf_shape(self, name: str) -> tuple[tuple[int, ...], str]:
        """(global resident shape, policy); sharded dim padded to tp."""
        spec = self.engine.specs[name]
        pol = _policy(name, spec)
        shape = list(spec.shape)
        if pol in ("col", "expert_col"):
            shape[-1] = _pad_to(shape[-1], self.tp)
        elif pol == "row":
            shape[0] = _pad_to(shape[0], self.tp)
        elif pol == "expert_row":
            shape[1] = _pad_to(shape[1], self.tp)
        if spec.stack:
            shape = [spec.stack] + shape
        return tuple(shape), pol

    def pspec(self, name: str) -> P:
        spec = self.engine.specs[name]
        shape, pol = self.leaf_shape(name)
        dims = [None] * len(shape)
        off = 1 if spec.stack else 0
        if pol in ("col", "expert_col"):
            dims[-1] = self.tp_axes
        elif pol == "row":
            dims[off] = self.tp_axes
        elif pol == "expert_row":
            dims[off + 1] = self.tp_axes
        return P(*dims)

    def abstract(self, mesh: Mesh, dtype=jnp.bfloat16):
        out = {}
        for name in self.engine.specs:
            shape, pol = self.leaf_shape(name)
            dt = jnp.float32 if pol == "replicated" else dtype
            out[name] = jax.ShapeDtypeStruct(
                shape, dt, sharding=NamedSharding(mesh, self.pspec(name)))
        return out

    def in_specs(self):
        return {n: self.pspec(n) for n in self.engine.specs}


def build_resident(engine: ZeroEngine, state, mesh: Mesh,
                   tp_axes: tuple[str, ...], dtype=jnp.bfloat16):
    """One-time reshape: ZeRO master shards -> resident TP layout."""
    tp = math.prod(mesh.shape[a] for a in tp_axes)
    layout = ResidentLayout(engine, tp_axes, tp)

    def convert():
        out = {}
        for name, spec in engine.specs.items():
            flat = state["master"][name]
            n = spec.logical_size
            if spec.stack:
                dense = flat[:, :n].reshape((spec.stack,) + spec.shape)
            else:
                dense = flat[:n].reshape(spec.shape)
            shape, pol = layout.leaf_shape(name)
            pad = [(0, t - s) for t, s in zip(shape, dense.shape)]
            dense = jnp.pad(dense, pad)
            dt = jnp.float32 if pol == "replicated" else dtype
            out[name] = dense.astype(dt)
        return out

    sh = {n: NamedSharding(mesh, layout.pspec(n)) for n in engine.specs}
    return layout, jax.jit(convert, out_shardings=sh)()


class ResidentView(ParamView):
    """ParamView over resident TP shards (runs inside shard_map)."""

    def __init__(self, layout: ResidentLayout, params: dict[str, Any]):
        self._layout = layout
        self._p = params
        self._tp_axes = layout.tp_axes

    def mm(self, name: str, x, transpose: bool = False):
        spec = self._layout.engine.specs[name]
        w = self._p[name]
        pol = _policy(name, spec)
        n_out = spec.shape[0] if transpose else spec.shape[-1]
        if pol == "replicated":
            w2 = w.reshape(-1, w.shape[-1])
            return jnp.matmul(x, w2.T if transpose else w2)
        if pol == "col":
            assert not transpose
            w2 = w.reshape(-1, w.shape[-1])          # (in, out_pad/tp) local
            y = jnp.matmul(x.astype(w2.dtype), w2).astype(x.dtype)
            y = lax.all_gather(y, self._tp_axes, axis=y.ndim - 1, tiled=True)
            return y[..., :n_out]
        if pol == "row":
            # (V_pad/tp, d) local rows
            assert transpose, f"{name}: row-resident leaves serve the head"
            y = jnp.matmul(x.astype(w.dtype), w.T).astype(x.dtype)
            y = lax.all_gather(y, self._tp_axes, axis=y.ndim - 1, tiled=True)
            return y[..., :n_out]
        raise ValueError((name, pol))

    def get(self, name: str):
        """Materialize a dense leaf. Sharded leaves are gathered — intended
        for small tensors only (MLA up-projections, norms); the big paths go
        through mm/embed_lookup/expert_ffn and never materialize."""
        spec = self._layout.engine.specs[name]
        pol = _policy(name, spec)
        w = self._p[name]
        if pol == "replicated":
            return w.reshape(-1)[: spec.logical_size].reshape(spec.shape)
        if pol in ("col", "expert_col"):
            full = lax.all_gather(w, self._tp_axes, axis=w.ndim - 1,
                                  tiled=True)
            sl = [slice(None)] * full.ndim
            sl[-1] = slice(0, spec.shape[-1])
            return full[tuple(sl)]
        if pol == "row":
            full = lax.all_gather(w, self._tp_axes, axis=0, tiled=True)
            return full[: spec.shape[0]]
        full = lax.all_gather(w, self._tp_axes, axis=1, tiled=True)
        return full[:, : spec.shape[1]]

    def embed_lookup(self, name: str, ids):
        """Row-sharded lookup: mask out-of-range rows, psum over TP."""
        w = self._p[name]                           # (V_pad/tp, d)
        rows = w.shape[0]
        idx = lax.axis_index(self._tp_axes)
        local = ids - idx * rows
        inb = (local >= 0) & (local < rows)
        safe = jnp.clip(local, 0, rows - 1)
        emb = jnp.take(w, safe, axis=0)
        emb = jnp.where(inb[..., None], emb, 0)
        return col.activation_psum(emb, self._tp_axes, out_dtype=w.dtype)

    def expert_ffn(self, prefix: str, e_in):
        """Megatron pairing: gate/up column-sharded (ff), down row-sharded."""
        wg = self._p_leaf(prefix + "w_gate")        # (E, d, ff_pad/tp)
        wu = self._p_leaf(prefix + "w_up")
        wd = self._p_leaf(prefix + "w_down")        # (E, ff_pad/tp, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", e_in, wg)) \
            * jnp.einsum("ecd,edf->ecf", e_in, wu)
        # local ff slice contracts against the matching w_down rows; the
        # ff padding rows of w_down are zero so they contribute nothing
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        return col.activation_psum(out, self._tp_axes)

    def _p_leaf(self, name):
        return self._p[name]

    def sub(self, params):
        return ResidentView(self._layout, params)


class ResidentServeEngine:
    """ServeEngine twin that serves from resident TP weights."""

    def __init__(self, model: ModelDef, engine: ZeroEngine, mesh: Mesh,
                 shape: ShapeConfig, sc: ServeConfig | None = None):
        self.model = model
        self.engine = engine
        self.mesh = mesh
        self.shape = shape
        self.sc = sc or make_serve_config(mesh, shape.global_batch)
        self.layout = ResidentLayout(
            engine, model_axes(mesh),
            math.prod(mesh.shape[a] for a in model_axes(mesh)))
        self.axis_sizes = dict(mesh.shape)

    def abstract_params(self):
        return self.layout.abstract(self.mesh)

    def _wrap(self, fn, extra_in, extra_out):
        specs = self.layout.in_specs()

        def local(params, *args):
            view = ResidentView(self.layout, params)
            return fn(view, *args)

        return jax.jit(shard_map(
            local, mesh=self.mesh, in_specs=(specs,) + tuple(extra_in),
            out_specs=extra_out, check_vma=False))

    def make_prefill(self, seq_parallel: bool = False):
        m, sc = self.model, self.sc
        shapes = m.prefill_batch_shapes(self.shape)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        fn = m.prefill_fn(sc.seq_axes, self.axis_sizes, seq_parallel)
        ba = sc.batch_axes_ if sc.batch_axes_ else None
        return self._wrap(fn, (bspecs,), (P(ba), cspecs))

    def make_decode(self):
        m, sc = self.model, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        fn = m.decode_fn(sc.seq_axes, self.axis_sizes)
        ba = sc.batch_axes_ if sc.batch_axes_ else None
        return self._wrap(fn, (cspecs, bspecs), (P(ba), cspecs))

    def decode_inputs_sds(self):
        m, sc = self.model, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        batch = m.batch_sds(shapes, self.mesh, sc.batch_axes_)
        caches = m.cache_sds(self.shape, self.mesh, sc.batch_axes_,
                             sc.seq_axes)
        return caches, batch

    def prefill_inputs_sds(self):
        shapes = self.model.prefill_batch_shapes(self.shape)
        return self.model.batch_sds(shapes, self.mesh, self.sc.batch_axes_)

    def generate(self, resident_params, prompt_batch, n_tokens: int):
        prefill = self.make_prefill()
        decode = self.make_decode()
        logits, caches = prefill(resident_params, prompt_batch)
        toks = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        for _ in range(n_tokens - 1):
            logits, caches = decode(resident_params, caches,
                                    {"token": toks[-1]})
            toks.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return jnp.stack(toks, axis=1)
