"""Continuous batching: a slot-based request scheduler over the decode step.

vLLM-style serving shape at miniature scale: the server owns a fixed-B KV
cache; incoming requests are prefilled into free slots (single-row prefill,
cache row spliced in with one donated update), all active slots decode in
lock-step, and finished rows (EOS or max-length) free their slot for the
next queued request — no global pipeline flush when one request ends.

Per-row positions: the engine-level cache keeps one scalar `pos`, which a
mixed-age batch can't share, so the scheduler tracks per-slot positions and
(a) left-pads nothing — each prefill writes absolute positions 0..p-1 into
its row, and (b) passes decode steps the *maximum* position while masking
logits of inactive slots. Rows decode with their own causal masks because
cache validity is position-based (flash_decode masks `kpos <= pos` per row
via per-row `pos` — see `row_pos` plumbed through `batch`).

This module is CPU-runnable end-to-end (examples/continuous_batching.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ShapeConfig
from .engine import ServeEngine


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False


def _splice(caches_dst, caches_src, slot: int):
    """Copy batch row 0 of caches_src into row `slot` of caches_dst."""
    def one(dst, src):
        if dst.ndim == 0:
            return dst
        # batch dim is axis 1 for (L, B, ...) entries
        row = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(dst, row.astype(dst.dtype),
                                                   slot, axis=1)

    out = {}
    for kind, entry in caches_dst.items():
        if kind == "pos":
            out[kind] = jnp.maximum(caches_dst["pos"], caches_src["pos"])
            continue
        out[kind] = jax.tree.map(one, entry, caches_src[kind])
    return out


class ContinuousBatcher:
    """Fixed-slot continuous batching over ServeEngine steps."""

    def __init__(self, model, engine, mesh, *, n_slots: int, max_len: int,
                 prompt_len: int, eos_token: int = -1):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.eos = eos_token
        self.serve = ServeEngine(model, engine, mesh,
                                 ShapeConfig("cb", max_len, n_slots, "decode"))
        self.serve1 = ServeEngine(model, engine, mesh,
                                  ShapeConfig("cb1", prompt_len, 1, "decode"))
        self._prefill1 = self.serve1.make_prefill()
        self._decode = self.serve.make_decode(per_row_pos=True)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.caches = None
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)

    # -- api -----------------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _init_caches(self, primaries):
        import jax.numpy as jnp
        sds = self.serve.decode_inputs_sds()[0]

        def zero(s):
            return jnp.zeros(s.shape, s.dtype)

        self.caches = jax.tree.map(zero, sds)

    def _admit(self, primaries):
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = np.asarray(req.prompt, np.int32)[: self.prompt_len]
            if len(prompt) < self.prompt_len:   # bucket-pad short prompts
                prompt = np.pad(prompt, (self.prompt_len - len(prompt),),
                                mode="edge")
            logits, c1 = self._prefill1(primaries,
                                        {"tokens": jnp.asarray(prompt[None])})
            # grow the single-row cache to the slot layout and splice
            c1 = _grow_seq(c1, self.model, self.max_len)
            self.caches = _splice(self.caches, c1, slot)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.slots[slot] = req
            self.last_tok[slot] = tok
            self.pos[slot] = self.prompt_len

    def step(self, primaries) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        if self.caches is None:
            self._init_caches(primaries)
        self._admit(primaries)
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        # every row decodes at its own position (per-row rope, masks and
        # cache writes); inactive rows write harmlessly at their stale pos
        logits, self.caches = self._decode(
            primaries, self.caches,
            {"token": jnp.asarray(self.last_tok),
             "row_pos": jnp.asarray(self.pos)})
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.out.append(tok)
            self.last_tok[i] = tok
            self.pos[i] += 1
            if tok == self.eos or len(req.out) >= req.max_new \
                    or int(self.pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run(self, primaries, requests: list[Request], max_steps: int = 10_000):
        for r in requests:
            self.submit(r)
        steps = 0
        while (any(self.slots) or self.queue) and steps < max_steps:
            self.step(primaries)
            steps += 1
        return requests


def _grow_seq(caches, model, new_len: int):
    """Zero-pad position-indexed cache seq dims to the server's max_len."""
    from ..models.transformer import kind_meta
    arch = model.arch
    out = {}
    for kind, entry in caches.items():
        if kind == "pos":
            out[kind] = entry
            continue
        m = kind_meta(kind, arch)
        grown = {}
        for k, v in entry.items():
            seq_keys = (m.mixer == "attn" and not m.window and k in ("k", "v")) \
                or (m.mixer == "mla" and k == "lat")
            if seq_keys and v.shape[2] < new_len:
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, new_len - v.shape[2])
                grown[k] = jnp.pad(v, pad)
            else:
                grown[k] = v
        out[kind] = grown
    return out
