"""Continuous batching: an SLO-driven slot scheduler over the paged decode.

vLLM-style serving shape at miniature scale: the server owns a paged KV
pool (serve/paged.py); incoming requests are admitted into free slots under
a latency SLO (queue-wait bound + KV-page headroom), prefilled with a
single-row prefill on the model-tier axes, scattered into their pages with
one donated jit call, and all active slots decode in lock-step — no global
pipeline flush when one request ends, no cache reallocation ever
(``_grow_seq`` survives only as the sequential reference's helper).

Per-row positions: the engine-level cache keeps one scalar ``pos``, which a
mixed-age batch can't share, so the scheduler tracks per-slot positions and
passes decode steps per-row positions (``row_pos``); rows decode with their
own causal masks because cache validity is position-based (flash_decode
masks ``kpos <= pos`` per row).

Prefill/decode disaggregation across the mesh tiers: prefill runs a B=1
engine whose sequence dimension shards over the model-tier axes (optionally
sequence-parallel), while decode batches slots over the data-tier axes —
the same tier split the training schemes use for weight vs replica traffic.

Two weight backends share the scheduler: ``"gathered"`` (the seed
fp-materialized per-token weight gather, ``ServeEngine``) and
``"resident"`` (the INT8 wire residency, ``ResidentServeEngine``) —
``run(params, ...)`` takes the training primaries or the residency
respectively. This module is CPU-runnable end-to-end
(examples/continuous_batching.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ShapeConfig
from .engine import ServeEngine
from .paged import PagedKV
from .resident import ResidentServeEngine


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False
    rejected: bool = False             # dropped by the SLO queue-wait bound
    submit_step: int = -1
    t_submit: float = 0.0
    t_first: float = 0.0               # first token emitted (admission)
    t_done: float = 0.0


@dataclass
class ServeSLO:
    """Deterministic admission policy + latency targets.

    ``max_queue_steps``/``reserve_pages`` drive *step-count* decisions, so
    admission/rejection/preemption counts are reproducible and baseline-
    gateable; ``target_p99_ms`` is reporting-only (wall-clock is never
    gated)."""
    max_queue_steps: int = 0           # reject after N scheduler steps (0=off)
    reserve_pages: int = 0             # keep N pages free when admitting
    target_p99_ms: float = 0.0


def _default_page(max_len: int) -> int:
    return next(d for d in (16, 8, 4, 2, 1) if max_len % d == 0)


class ContinuousBatcher:
    """SLO-driven continuous batching over the paged pool."""

    def __init__(self, model, engine, mesh, *, n_slots: int, max_len: int,
                 prompt_len: int, eos_token: int = -1,
                 page_size: int | None = None, n_pages: int = 0,
                 slo: ServeSLO | None = None, backend: str = "gathered",
                 res_axes: tuple[str, ...] | None = None,
                 prefill_seq_parallel: bool = False, metrics=None):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.eos = eos_token
        self.slo = slo or ServeSLO()
        self.backend = backend
        self.metrics = metrics
        shape = ShapeConfig("cb", max_len, n_slots, "decode")
        shape1 = ShapeConfig("cb1", prompt_len, 1, "decode")
        if backend == "resident":
            self.serve = ResidentServeEngine(model, engine, mesh, shape,
                                             res_axes=res_axes)
            self.serve1 = ResidentServeEngine(model, engine, mesh, shape1,
                                              res_axes=res_axes)
        else:
            assert backend == "gathered", backend
            self.serve = ServeEngine(model, engine, mesh, shape)
            self.serve1 = ServeEngine(model, engine, mesh, shape1)
        self._prefill1 = self.serve1.make_prefill(
            seq_parallel=prefill_seq_parallel)
        self._decode = self.serve.make_decode(per_row_pos=True)
        self.paged = PagedKV(model, shape,
                             page_size=page_size or _default_page(max_len),
                             n_pages=n_pages)
        self._paged_step = self._make_paged_step()
        self._admit_scatter = jax.jit(self.paged.admit_scatter,
                                      donate_argnums=(0,))
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.pool = None
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.admit_order = np.full((n_slots,), -1, np.int64)
        self.step_count = 0
        self.counters = dict(admitted=0, rejected=0, preempted=0, retired=0)
        self._latencies_ms: list[float] = []

    # -- api -----------------------------------------------------------------

    def submit(self, req: Request):
        req.submit_step = self.step_count
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _init_pool(self):
        sds = self.serve.decode_inputs_sds()[0]
        self.pool = self.paged.init_pool(sds)

    def _make_paged_step(self):
        decode, paged = self._decode, self.paged

        def step_fn(params, pool, table, token, row_pos, active):
            dense = paged.assemble(pool, table)
            logits, new_dense = decode(params, dense,
                                       {"token": token, "row_pos": row_pos})
            new_pool = paged.writeback(pool, new_dense, table, row_pos,
                                       active)
            return logits, new_pool

        return jax.jit(step_fn, donate_argnums=(1,))

    # -- admission / eviction -------------------------------------------------

    def _reject_stale(self):
        if not self.slo.max_queue_steps:
            return
        keep = []
        for req in self.queue:
            if self.step_count - req.submit_step > self.slo.max_queue_steps:
                req.rejected = True
                req.done = True
                req.t_done = time.perf_counter()
                self.counters["rejected"] += 1
            else:
                keep.append(req)
        self.queue = keep

    def _can_admit(self) -> bool:
        need = self.paged.pages_needed(self.prompt_len)
        return self.paged.free_pages() - self.slo.reserve_pages >= need

    def _admit(self, params):
        n_pp = self.paged.pages_needed(self.prompt_len)
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            if not self._can_admit():
                break
            req = self.queue.pop(0)
            prompt = np.asarray(req.prompt, np.int32)[: self.prompt_len]
            if len(prompt) < self.prompt_len:   # bucket-pad short prompts
                prompt = np.pad(prompt, (self.prompt_len - len(prompt),),
                                mode="edge")
            logits, c1 = self._prefill1(params,
                                        {"tokens": jnp.asarray(prompt[None])})
            ok = self.paged.alloc_prefix(slot, self.prompt_len)
            assert ok, "free-page check raced the allocator"
            pages = jnp.asarray(self.paged.table[slot, :n_pp])
            self.pool = self._admit_scatter(self.pool, c1,
                                            jnp.int32(slot), pages)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            req.t_first = time.perf_counter()
            self.slots[slot] = req
            self.last_tok[slot] = tok
            self.pos[slot] = self.prompt_len
            self.admit_order[slot] = self.counters["admitted"]
            self.counters["admitted"] += 1

    def _preempt_youngest(self) -> int | None:
        """Evict the most recently admitted slot back to the queue front."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return None
        victim = max(active, key=lambda i: self.admit_order[i])
        req = self.slots[victim]
        req.out.clear()                 # restarts from its prompt
        req.submit_step = self.step_count   # wait clock restarts on requeue
        self.queue.insert(0, req)
        self.slots[victim] = None
        self.paged.release(victim)
        self.admit_order[victim] = -1
        self.counters["preempted"] += 1
        return victim

    def _grow_pages(self):
        """Lazily allocate the page each active slot is about to write."""
        for slot in range(self.n_slots):
            if self.slots[slot] is None:
                continue
            block = int(self.pos[slot]) // self.paged.page_size
            while not self.paged.alloc(slot, block):
                victim = self._preempt_youngest()
                if victim is None or victim == slot:
                    break
            # a preempted slot (victim == slot) simply skips this step

    def _retire(self, slot: int):
        req = self.slots[slot]
        req.done = True
        req.t_done = time.perf_counter()
        self._latencies_ms.append((req.t_done - req.t_submit) * 1e3)
        self.counters["retired"] += 1
        self.slots[slot] = None
        self.admit_order[slot] = -1
        self.paged.release(slot)

    # -- stepping -------------------------------------------------------------

    def step(self, params) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        t0 = time.perf_counter()
        if self.pool is None:
            self._init_pool()
        self._reject_stale()
        t_admit0 = time.perf_counter()
        self._admit(params)
        self._grow_pages()
        t_admit = time.perf_counter() - t_admit0
        active = [i for i, r in enumerate(self.slots) if r is not None]
        self.step_count += 1
        if not active:
            self._emit_metrics(0, time.perf_counter() - t0, t_admit, 0.0)
            return 0
        mask = np.zeros((self.n_slots,), bool)
        mask[active] = True
        t_dec0 = time.perf_counter()
        logits, self.pool = self._paged_step(
            params, self.pool, self.paged.device_table(),
            jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            jnp.asarray(mask))
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        t_dec = time.perf_counter() - t_dec0
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.out.append(tok)
            self.last_tok[i] = tok
            self.pos[i] += 1
            if tok == self.eos or len(req.out) >= req.max_new \
                    or int(self.pos[i]) >= self.max_len - 1:
                self._retire(i)
        self._emit_metrics(len(active), time.perf_counter() - t0,
                           t_admit, t_dec)
        return len(active)

    def _emit_metrics(self, n_active: int, dt_s: float, t_admit: float,
                      t_dec: float):
        if self.metrics is None:
            return
        lat = np.asarray(self._latencies_ms) if self._latencies_ms else None
        self.metrics.write(dict(
            step=self.step_count, tokens=n_active, dt_s=dt_s,
            tokens_per_s=(n_active / dt_s if dt_s > 0 else 0.0),
            queue_depth=len(self.queue), active_slots=n_active,
            admitted=self.counters["admitted"],
            rejected=self.counters["rejected"],
            preempted=self.counters["preempted"],
            retired=self.counters["retired"],
            free_pages=self.paged.free_pages(),
            p50_ms=(float(np.percentile(lat, 50)) if lat is not None
                    else 0.0),
            p99_ms=(float(np.percentile(lat, 99)) if lat is not None
                    else 0.0),
            phase_ms={"serve_admit": t_admit * 1e3,
                      "serve_decode": t_dec * 1e3}))

    def run(self, params, requests: list[Request], max_steps: int = 10_000):
        for r in requests:
            self.submit(r)
        steps = 0
        while (any(self.slots) or self.queue) and steps < max_steps:
            self.step(params)
            steps += 1
        return requests

    def latency_percentiles(self) -> dict[str, float]:
        if not self._latencies_ms:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        lat = np.asarray(self._latencies_ms)
        return {"p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99))}


def _grow_seq(caches, model, new_len: int):
    """Zero-pad position-indexed cache seq dims to a larger max_len.

    The paged pool made this obsolete in the serving path; it survives as
    the sequential reference's helper (tests/test_scheduler.py) and for
    one-off cache surgery."""
    from ..models.transformer import kind_meta
    arch = model.arch
    out = {}
    for kind, entry in caches.items():
        if kind == "pos":
            out[kind] = entry
            continue
        m = kind_meta(kind, arch)
        grown = {}
        for k, v in entry.items():
            seq_keys = (m.mixer == "attn" and not m.window and k in ("k", "v")) \
                or (m.mixer == "mla" and k == "lat")
            if seq_keys and v.shape[2] < new_len:
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, new_len - v.shape[2])
                grown[k] = jnp.pad(v, pad)
            else:
                grown[k] = v
        out[kind] = grown
    return out
