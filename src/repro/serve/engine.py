"""Serving runtime: batched prefill + single-token decode steps.

Decode shapes lower ``serve_step`` — one new token against a KV cache of
``seq_len``. Full-attention / MLA caches are **sequence-sharded** over the
mesh's model-tier axes and attended with exact distributed flash-decode
(partial softmax per shard + pmax/psum combine); sliding-window layers keep
replicated ring buffers; SSM layers carry O(1) recurrent state.

Weights are served from the same ZeRO primary shards as training (the
per-layer quantized all-gather) — FSDP-style inference. A tensor-parallel
serving path is a possible beyond-paper extension; see EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.engine import ParamView, ZeroEngine
from ..models.config import ShapeConfig
from ..models.registry import ModelDef, batch_axes, data_axes, model_axes


@dataclass
class ServeConfig:
    seq_axes: tuple[str, ...]          # cache sequence-sharding axes
    batch_axes_: tuple[str, ...]       # cache/batch batch-sharding axes


def make_serve_config(mesh: Mesh, global_batch: int) -> ServeConfig:
    baxes = batch_axes(mesh, global_batch, candidates=data_axes(mesh))
    return ServeConfig(seq_axes=model_axes(mesh), batch_axes_=baxes)


class ServeEngine:
    def __init__(self, model: ModelDef, engine: ZeroEngine, mesh: Mesh,
                 shape: ShapeConfig, sc: ServeConfig | None = None):
        self.model = model
        self.engine = engine
        self.mesh = mesh
        self.shape = shape
        self.sc = sc or make_serve_config(mesh, shape.global_batch)
        self.axis_sizes = dict(mesh.shape)

    # -- prefill ---------------------------------------------------------------

    def make_prefill(self, seq_parallel: bool = False):
        m, eng, sc = self.model, self.engine, self.sc
        shapes = m.prefill_batch_shapes(self.shape)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        prim_specs = eng.state_in_specs()["primaries"]
        fn = m.prefill_fn(sc.seq_axes, self.axis_sizes, seq_parallel)

        def local(primaries, batch):
            # serving keeps the inline (non-overlap) gather regardless of
            # ZeroConfig.overlap — see DESIGN.md §3
            view = ParamView(eng.fns, primaries)
            return fn(view, batch)

        ba = sc.batch_axes_ if sc.batch_axes_ else None
        sm = shard_map(local, mesh=self.mesh,
                           in_specs=(prim_specs, bspecs),
                           out_specs=(P(ba), cspecs), check_vma=False)
        return jax.jit(sm)

    def prefill_inputs_sds(self):
        shapes = self.model.prefill_batch_shapes(self.shape)
        return self.model.batch_sds(shapes, self.mesh, self.sc.batch_axes_)

    # -- decode ------------------------------------------------------------------

    def make_decode(self, per_row_pos: bool = False):
        m, eng, sc = self.model, self.engine, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        if per_row_pos:
            shapes["row_pos"] = ((self.shape.global_batch,), jnp.int32)
        bspecs = m.batch_pspecs(shapes, sc.batch_axes_)
        cspecs = m.cache_pspecs(self.shape, sc.batch_axes_, sc.seq_axes)
        prim_specs = eng.state_in_specs()["primaries"]
        fn = m.decode_fn(sc.seq_axes, self.axis_sizes)

        def local(primaries, caches, batch):
            view = ParamView(eng.fns, primaries)
            return fn(view, caches, batch)

        ba = sc.batch_axes_ if sc.batch_axes_ else None
        sm = shard_map(local, mesh=self.mesh,
                           in_specs=(prim_specs, cspecs, bspecs),
                           out_specs=(P(ba), cspecs), check_vma=False)
        return jax.jit(sm, donate_argnums=(1,))

    def decode_inputs_sds(self):
        m, sc = self.model, self.sc
        shapes = m.decode_batch_shapes(self.shape)
        batch = m.batch_sds(shapes, self.mesh, sc.batch_axes_)
        caches = m.cache_sds(self.shape, self.mesh, sc.batch_axes_, sc.seq_axes)
        return caches, batch

    # -- driver: generate n tokens greedily ---------------------------------------

    def generate(self, state, prompt_batch, n_tokens: int):
        """Greedy generation driver (CPU-testable): prefill then decode loop."""
        prefill = self.make_prefill()
        decode = self.make_decode()
        logits, caches = prefill(state["primaries"], prompt_batch)
        toks = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        for _ in range(n_tokens - 1):
            logits, caches = decode(state["primaries"], caches,
                                    {"token": toks[-1]})
            toks.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return jnp.stack(toks, axis=1)
