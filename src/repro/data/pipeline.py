"""Data pipeline: deterministic synthetic corpora + packed memmap loader.

Two sources, one interface (``__iter__`` yields ready-to-shard batch dicts):

* ``SyntheticTokens`` — seeded, Zipf-distributed token stream with injected
  local structure (repeated n-grams) so loss curves actually *decrease* and
  convergence comparisons (paper Figs 9/10) are meaningful. The modality
  carve-out lives here too: VLM patch / audio frame embeddings are drawn from
  a fixed random projection of the token stream (a stand-in for the stubbed
  ViT / conv frontend).

* ``PackedDataset`` — documents packed into fixed-length rows in a uint32
  ``np.memmap``; ``pack_documents`` writes it, the loader reads it with
  deterministic epoch shuffling. This is the on-disk format a real run would
  use; tests round-trip it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from ..models.config import ArchConfig, ShapeConfig


@dataclass
class BatchSpec:
    global_batch: int
    seq_len: int              # text tokens per row (excl. next-token shift)
    vocab: int
    n_patches: int = 0
    n_frames: int = 0
    d_model: int = 0


def spec_for(arch: ArchConfig, shape: ShapeConfig) -> BatchSpec:
    s_text = shape.seq_len - arch.n_patches if arch.n_patches else shape.seq_len
    return BatchSpec(shape.global_batch, s_text, arch.vocab,
                     n_patches=arch.n_patches, n_frames=arch.n_frames,
                     d_model=arch.d_model)


def shard_batch(np_batch: dict, mesh, pspecs: dict):
    """Place a host batch onto the mesh, multi-process safe.

    Every process holds (or can deterministically regenerate) the *global*
    batch; each shard of the resulting global ``jax.Array`` is fed from the
    matching slice, so only this process's addressable rows are ever copied
    to devices. Single-process this is exactly ``jax.device_put`` with a
    ``NamedSharding``; multi-process, ``device_put`` of a host array would
    try to place non-addressable shards and fail.

    Determinism across process layouts is the load-bearing property: a
    2-process x 4-device run consumes bitwise the same global batch as the
    single-process 8-device run (tests/_mp.py train-step parity).
    """
    import jax
    from jax.sharding import NamedSharding

    out = {}
    for k, v in np_batch.items():
        sh = NamedSharding(mesh, pspecs[k])
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, v=v: v[idx])
    return out


class SyntheticTokens:
    """Deterministic learnable token stream.

    Each row: Zipf(1.2)-sampled tokens where every position with
    ``i % 4 != 0`` deterministically repeats a function of the previous token
    — a next-token structure a model learns within a few hundred steps.
    """

    def __init__(self, spec: BatchSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed

    def batch(self, step: int) -> dict[str, np.ndarray]:
        sp = self.spec
        rng = np.random.default_rng((self.seed, step))
        b, s = sp.global_batch, sp.seq_len
        base = rng.zipf(1.2, size=(b, s + 1)).astype(np.int64)
        toks = (base - 1) % sp.vocab
        # learnable structure: deterministic successor for 3 of 4 positions,
        # chained left-to-right in 3-step runs between random anchors at i%4==0
        for k in range(1, 4):
            idx = np.arange(k, s + 1, 4)
            toks[:, idx] = (toks[:, idx - 1] * 31 + 7) % sp.vocab
        out = {"tokens": toks.astype(np.int32)}
        if sp.n_patches:
            out["patches"] = self._embed(rng, (b, sp.n_patches, sp.d_model))
        if sp.n_frames:
            out["frames"] = self._embed(rng, (b, sp.n_frames, sp.d_model))
        return out

    @staticmethod
    def _embed(rng, shape):
        return (rng.standard_normal(shape) * 0.02).astype(np.float32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


# ---------------------------------------------------------------------------
# Packed memmap corpus
# ---------------------------------------------------------------------------

_MAGIC = np.uint32(0x5245_5052)  # "REPR"


def pack_documents(docs: list[np.ndarray], path: str | Path, row_len: int,
                   eod_token: int) -> int:
    """Greedy-pack variable-length docs into (n_rows, row_len) uint32 memmap.

    Returns the number of rows written. Docs longer than a row are split;
    rows are separated by ``eod_token``. Header: [magic, row_len, n_rows].
    """
    stream: list[np.ndarray] = []
    for d in docs:
        stream.append(np.asarray(d, np.uint32))
        stream.append(np.asarray([eod_token], np.uint32))
    flat = np.concatenate(stream) if stream else np.zeros((0,), np.uint32)
    n_rows = len(flat) // row_len
    flat = flat[: n_rows * row_len]
    path = Path(path)
    mm = np.memmap(path, np.uint32, "w+", shape=(3 + n_rows * row_len,))
    mm[0], mm[1], mm[2] = _MAGIC, row_len, n_rows
    mm[3:] = flat
    mm.flush()
    return n_rows


class PackedDataset:
    def __init__(self, path: str | Path):
        header = np.memmap(path, np.uint32, "r", shape=(3,))
        assert header[0] == _MAGIC, f"bad magic in {path}"
        self.row_len = int(header[1])
        self.n_rows = int(header[2])
        self.data = np.memmap(path, np.uint32, "r",
                              offset=12, shape=(self.n_rows, self.row_len))

    def batch(self, step: int, global_batch: int, seed: int = 0) -> np.ndarray:
        """Deterministic epoch-shuffled (B, row_len) int32 batch."""
        per_epoch = max(self.n_rows // global_batch, 1)
        epoch, within = divmod(step, per_epoch)
        rng = np.random.default_rng((seed, epoch))
        perm = rng.permutation(self.n_rows)
        rows = perm[(within * global_batch) % self.n_rows:][:global_batch]
        if len(rows) < global_batch:  # wrap
            rows = np.concatenate([rows, perm[: global_batch - len(rows)]])
        return self.data[np.sort(rows)].astype(np.int32)
