"""Sharded AdamW: the pure per-shard update used by ZeroEngine.

Operates on optimizer-shard-layout flat tensors — every device updates only
the slice of the master parameters matching its optimizer shard (paper §V-C),
so the optimizer itself needs no communication.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class AdamWOut(NamedTuple):
    master: jnp.ndarray
    m: jnp.ndarray
    v: jnp.ndarray


def adamw_update(master, m, v, grad, *, step, lr, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.0) -> AdamWOut:
    """One decoupled-weight-decay Adam step on a flat fp32 shard.

    ``step`` is the 1-based step index (bias correction)."""
    g = grad.astype(jnp.float32)
    m = beta1 * m + (1 - beta1) * g
    v = beta2 * v + (1 - beta2) * jnp.square(g)
    t = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    mh = m / (1 - beta1 ** t)
    vh = v / (1 - beta2 ** t)
    upd = mh / (jnp.sqrt(vh) + eps)
    new_master = master * (1 - lr * weight_decay) - lr * upd
    return AdamWOut(new_master, m, v)


def cosine_lr(step, *, base_lr, warmup_steps, total_steps, min_frac=0.1):
    warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos
