"""Paper Figs 9/10 at example scale: train the same model on the same data
under (a) exact ZeRO-3 and (b) fully-quantized ZeRO-topo (INT8 weight
gathers + INT4 gradient reduce-scatter) and print the two loss curves
side by side.

    PYTHONPATH=src python examples/convergence_compare.py [--steps 150]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--arch", default="gpt-neox-10b")
    args = ap.parse_args()

    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.data.pipeline import BatchSpec, SyntheticTokens
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.registry import build_model, get_arch

    mesh = make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd"))
    AX = ("data", "node", "gcd")
    arch = get_arch(args.arch).reduced(n_layers=2, d_model=192, vocab=512)
    model = build_model(arch)
    data = SyntheticTokens(BatchSpec(16, 96, arch.vocab), seed=0)

    curves = {}
    for label, scheme, quant in (("zero3-exact", "zero3", False),
                                 ("zero_topo-quantized", "zero_topo", True)):
        cfg = scheme_config(scheme, mesh, quant_block=64,
                            compute_dtype="float32")
        cfg = dataclasses.replace(cfg, quantize_weights=quant,
                                  quantize_grads=quant)
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                         TrainHparams(lr=1e-3, total_steps=args.steps,
                                      warmup_steps=10))
        state = eng.init_state(jax.random.key(0))
        step = eng.make_train_step(model.loss_fn(), {"tokens": P(AX)})
        losses = []
        for i in range(args.steps):
            b = jax.device_put(jnp.asarray(data.batch(i)["tokens"]),
                               NamedSharding(mesh, P(AX)))
            state, m = step(state, {"tokens": b})
            losses.append(float(m["loss"]))
        curves[label] = losses
        print(f"{label}: start {losses[0]:.4f} final {losses[-1]:.4f}")

    print(f"\n{'step':>6s} {'zero3-exact':>14s} {'topo-quant':>14s} {'rel%':>7s}")
    a, b = curves["zero3-exact"], curves["zero_topo-quantized"]
    for i in range(0, args.steps, max(args.steps // 15, 1)):
        print(f"{i:6d} {a[i]:14.4f} {b[i]:14.4f} "
              f"{abs(a[i] - b[i]) / a[i] * 100:6.2f}%")
    final_rel = abs(a[-1] - b[-1]) / a[-1]
    print(f"\nfinal gap {final_rel * 100:.2f}% (paper: ~1%)")


if __name__ == "__main__":
    main()
