"""End-to-end driver (deliverable (b)): train a ~100M-param GPT for a few
hundred steps under every ZeRO scheme and report loss + per-step comm volume.

    PYTHONPATH=src python examples/scheme_shootout.py --steps 200
    (use --steps 30 for a quick pass)
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.data.pipeline import BatchSpec, SyntheticTokens
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.config import ArchConfig
    from repro.models.registry import build_model

    mesh = make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd"))
    AX = ("data", "node", "gcd")
    arch = ArchConfig(name="gpt-100m", family="dense",
                      n_layers=args.layers, d_model=args.d_model,
                      n_heads=8, n_kv_heads=8, d_ff=4 * args.d_model,
                      vocab=32_000, block_pattern=("neox",) * args.layers,
                      parallel_residual=True, norm="ln", act="gelu")
    model = build_model(arch)
    data = SyntheticTokens(BatchSpec(16, 128, arch.vocab), seed=0)

    psi = model.param_count()
    print(f"model: {arch.name}")
    results = {}
    for scheme in ("zero1", "zero2", "zero3", "zeropp", "zero_topo", "auto"):
        # "auto": the topology planner's pick for this mesh (DESIGN.md §4)
        cfg = scheme_config(scheme, mesh, quant_block=128,
                            psi=psi, n_layers=arch.n_layers)
        eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                         TrainHparams(lr=6e-4, total_steps=args.steps,
                                      warmup_steps=10))
        state = eng.init_state(jax.random.key(0))
        step = eng.make_train_step(model.loss_fn(), {"tokens": P(AX)})
        mem = eng.memory_report()
        losses = []
        for i in range(args.steps):
            b = jax.device_put(jnp.asarray(data.batch(i)["tokens"]),
                               NamedSharding(mesh, P(AX)))
            state, m = step(state, {"tokens": b})
            losses.append(float(m["loss"]))
        results[scheme] = (losses[0], losses[-1], mem["total"])
        print(f"{scheme:10s} params {eng.param_count():,}  "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
              f"state {mem['total'] / 1e6:.0f} MB/device "
              f"(w x{cfg.w_degree} g x{cfg.g_degree} os x{cfg.os_degree})")

    finals = [v[1] for v in results.values()]
    assert max(finals) - min(finals) < 0.35, \
        "schemes diverged more than quantization tolerance"
    print("\nall five schemes converge on the same data; "
          "zero_topo matches within quantization tolerance")


if __name__ == "__main__":
    main()
