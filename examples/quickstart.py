"""Quickstart: train a small GPT under ZeRO-topo on 8 (fake) devices.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the whole public API surface: pick an architecture, choose a
partitioning scheme (the paper's zero_topo by default), build the engine,
train with the synthetic pipeline, checkpoint, and reload.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.core.engine import TrainHparams, ZeroEngine  # noqa: E402
from repro.launch.mesh import make_test_mesh, scheme_config  # noqa: E402
from repro.models.config import ShapeConfig  # noqa: E402
from repro.models.registry import build_model, get_arch  # noqa: E402
from repro.train import checkpoint  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402


def main():
    # 1. an 8-device mesh split into the paper's three bandwidth tiers:
    #    gcd (fastest, =MI250X GCD pair) / node / data (slowest)
    mesh = make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd"))

    # 2. a reduced GPT-NeoX (the paper's model family) + the zero_topo scheme:
    #    weights sharded over 'gcd' (INT8 gathers), grads over the node
    #    (INT4 all-to-all reduce-scatter), optimizer over everything.
    #    stream_grads: each layer's grad reduce-scatter runs inside the
    #    backward and accumulates in optimizer-shard layout (DESIGN.md §8)
    arch = get_arch("gpt-neox-20b").reduced(n_layers=2, d_model=256, vocab=512)
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=128, stream_grads=True)
    print(f"scheme={cfg.name}: weight shards x{cfg.w_degree}, "
          f"grad shards x{cfg.g_degree}, optimizer shards x{cfg.os_degree}")

    # 3. engine + sharded state
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=60, warmup_steps=5))
    print(f"params: {eng.param_count():,}; per-device state bytes:",
          {k: f"{v / 1e6:.1f}MB" for k, v in eng.memory_report().items()})
    state = eng.init_state(jax.random.key(0))

    # 4. train on the deterministic synthetic pipeline
    shape = ShapeConfig("quickstart", seq_len=128, global_batch=16,
                        kind="train")
    tr = Trainer(model, eng, mesh, shape)
    state = tr.run(state, 60, log_every=10)

    # 5. checkpoint round-trip
    path = checkpoint.save(state, "/tmp/repro_quickstart", int(state["step"]))
    print("checkpointed to", path)
    restored = checkpoint.restore("/tmp/repro_quickstart", int(state["step"]),
                                  eng.state_shardings())
    state = tr.run(restored, 5, log_every=5)
    print("resumed OK; final loss", tr.log.losses[-1])
    assert tr.log.losses[-1] < tr.log.losses[0]


if __name__ == "__main__":
    main()
