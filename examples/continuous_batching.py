"""Continuous batching demo: a slot-based server streams tokens for more
requests than it has slots, admitting queued requests as others finish —
no global flush when one request ends.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.engine import TrainHparams, ZeroEngine  # noqa: E402
from repro.launch.mesh import make_test_mesh, scheme_config  # noqa: E402
from repro.models.registry import build_model, get_arch  # noqa: E402
from repro.serve.scheduler import ContinuousBatcher, Request  # noqa: E402


def main():
    mesh = make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd"))
    arch = get_arch("qwen2-0.5b").reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))

    cb = ContinuousBatcher(model, eng, mesh, n_slots=4, max_len=64,
                           prompt_len=16)
    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, arch.vocab, 16).astype(np.int32),
                        max_new=int(rng.integers(4, 12)))
                for i in range(10)]
    print(f"serving {len(requests)} requests on {cb.n_slots} slots "
          f"(max_new 4..12)")
    cb.run(state["primaries"], requests)
    for r in requests:
        assert r.done and len(r.out) <= r.max_new + 1
        print(f"  req {r.rid}: {len(r.out):2d} tokens  {r.out[:8]}")
    print("all requests completed with slot reuse (continuous batching)")


if __name__ == "__main__":
    main()
