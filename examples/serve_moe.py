"""Serve a (reduced) Mixtral-style MoE with batched requests: prefill a batch
of prompts, then stream greedy tokens with the sequence-sharded KV cache and
sliding-window ring buffers.

    PYTHONPATH=src python examples/serve_moe.py [--arch mixtral-8x7b]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import time

    import numpy as np
    import jax

    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch.mesh import make_test_mesh, scheme_config
    from repro.models.config import ShapeConfig
    from repro.models.registry import build_model, get_arch
    from repro.serve.engine import ServeEngine

    mesh = make_test_mesh(shape=(2, 2, 2), axes=("data", "node", "gcd"))
    arch = get_arch(args.arch).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    print(f"serving {arch.name}: {eng.param_count():,} params, "
          f"{arch.moe.n_experts} experts top-{arch.moe.top_k}, "
          f"window {arch.sliding_window}")

    total = args.prompt_len + args.gen
    se = ServeEngine(model, eng, mesh,
                     ShapeConfig("serve", total, args.batch, "decode"))
    print(f"cache layout: seq sharded over {se.sc.seq_axes}, batch over "
          f"{se.sc.batch_axes_}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    toks = se.generate(state, {"tokens": prompts}, args.gen)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s on CPU)")
    for i in range(min(3, args.batch)):
        print(f"  request {i}: ...{prompts[i, -4:].tolist()} -> "
              f"{np.asarray(toks)[i].tolist()}")


if __name__ == "__main__":
    main()
