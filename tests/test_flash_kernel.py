"""Flash attention under the kernels/ops dispatch: the jnp oracle and the
interpret-mode Pallas kernel must agree BITWISE through forward and backward
(DESIGN.md §5), multi-block kernel configs match to tolerance, and rejected
shapes fall back with a structured warning + counter, never silently.

hypothesis is an optional [test] extra: the property tests degrade to a
skip when it is missing (same guard as tests/test_kernels.py).
"""
import math
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models import layers as L


def _qkv(bh, sq, sk, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (bh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (bh, sk, d), jnp.float32).astype(dtype)
    return q, k, v


def _grads(q, k, v, impl, **kw):
    """Fresh jit per impl: the dispatch is baked in at trace time, so a
    shared jit cache would silently reuse the first impl's executable."""
    def loss(q, k, v):
        o = ops.flash_attention(q, k, v, impl=impl, **kw)
        return jnp.sum(o.astype(jnp.float32) ** 2)
    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))(q, k, v)


def _assert_bitwise(ra, rb, what):
    la, ga = ra
    lb, gb = rb
    assert np.asarray(la).tobytes() == np.asarray(lb).tobytes(), what
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(what))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ops_attention_jnp_vs_interpret_bitwise(causal, window, dtype):
    q, k, v = _qkv(4, 128, 128, 32, dtype)
    kw = dict(causal=causal, window=window)
    _assert_bitwise(_grads(q, k, v, "jnp", **kw),
                    _grads(q, k, v, "pallas_interpret", **kw),
                    f"causal={causal} window={window} dtype={dtype}")


def test_ops_attention_q_offset_decode_bitwise_and_correct():
    """A later q chunk (KV cache longer than q) is bitwise across impls and
    matches the dense softmax with absolute positions."""
    bh, sq, sk, off, d = 2, 128, 256, 128, 32
    q, k, v = _qkv(bh, sq, sk, d, seed=1)
    kw = dict(causal=True, q_offset=off)
    rj = _grads(q, k, v, "jnp", **kw)
    ri = _grads(q, k, v, "pallas_interpret", **kw)
    _assert_bitwise(rj, ri, "q_offset decode chunk")
    out = ops.flash_attention(q, k, v, impl="pallas_interpret", **kw)
    s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(d)
    mask = (off + jnp.arange(sq))[:, None] >= jnp.arange(sk)[None, :]
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    ref = jnp.einsum("bqk,bkd->bqd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 1), (8, 2)])
def test_layers_attention_gqa_bitwise_across_impls(h, hkv):
    """models/layers.flash_attention (GQA head folding included) is bitwise
    under the process-default impl switch."""
    b, s, d = 1, 128, 32
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    outs = {}
    try:
        for impl in ("jnp", "pallas_interpret"):
            ops.set_default_impl(impl)
            outs[impl] = jax.jit(
                lambda q, k, v, _i=impl: L.flash_attention(q, k, v))(q, k, v)
    finally:
        ops.set_default_impl("jnp")
    np.testing.assert_array_equal(np.asarray(outs["jnp"]),
                                  np.asarray(outs["pallas_interpret"]))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 100), (False, 0)])
def test_multiblock_kernel_matches_oracle(causal, window):
    """The production blocking (bq=bk=128, online-softmax rescales active)
    matches the oracle to fp32 tolerance — no bitwise contract here."""
    q, k, v = _qkv(2, 512, 512, 32, seed=3)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bb=1, bq=128, bk=128, interpret=True)
    ref = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_fallback_warns_once_and_counts():
    """Non-fusable shapes take the chunked jnp path with one structured
    warning per (kernel, reason) and a dispatch counter entry."""
    b, h, d = 1, 2, 32
    sq, sk = 128, 192               # 192 % min(128, 192) != 0
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, h, d))
    v = jax.random.normal(ks[2], (b, sk, h, d))
    ops.reset_dispatch_counters()
    with pytest.warns(UserWarning, match="fell back to the chunked jnp"):
        L.flash_attention(q, k, v, causal=False)
    counts = ops.dispatch_counters()
    assert counts.get("attention/fallback/seq_unaligned") == 1, counts
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call must NOT warn again
        L.flash_attention(q, k, v, causal=False)
    assert ops.dispatch_counters()["attention/fallback/seq_unaligned"] == 2
    # a different reason warns separately
    with pytest.warns(UserWarning, match="custom_scale"):
        sp = jax.random.normal(ks[0], (1, 128, 2, 32))
        L.flash_attention(sp, sp, sp, softmax_scale=0.5)
    assert ops.dispatch_counters()["attention/fallback/custom_scale"] == 1


def test_attention_fusable_reasons():
    ok, reason = ops.attention_fusable(128, 128, 32, 32)
    assert ok and reason is None
    assert ops.attention_fusable(128, 128, 32, 16)[1] == "mla_dv_mismatch"
    assert ops.attention_fusable(128, 128, 32, 32,
                                 softmax_scale=0.1)[1] == "custom_scale"
    assert ops.attention_fusable(
        128, 128, 32, 32, q_offset=jnp.int32(3))[1] == "traced_q_offset"
    assert ops.attention_fusable(128, 192, 32, 32)[1] == "seq_unaligned"
    assert ops.attention_fusable(4, 128, 32, 32)[1] == "seq_unaligned"


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 4), st.integers(0, 3), st.sampled_from([0, 32, 64]))
    def test_prop_window_offset_decode_bitwise(chunk_i, seed, window):
        """Sliding-window + q_offset decode attention: any 128-aligned q
        chunk against a longer cache is bitwise across impls, fwd + bwd."""
        sq, d = 128, 32
        off = chunk_i * sq
        sk = off + sq
        q, k, v = _qkv(2, sq, sk, d, seed=seed)
        kw = dict(causal=True, window=window, q_offset=off)
        _assert_bitwise(_grads(q, k, v, "jnp", **kw),
                        _grads(q, k, v, "pallas_interpret", **kw),
                        f"off={off} sk={sk} window={window} seed={seed}")
else:
    def test_prop_hypothesis_missing():
        pytest.skip("hypothesis not installed (optional [test] extra); "
                    "property tests skipped")
