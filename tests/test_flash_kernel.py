"""Pallas flash-attention kernel vs the jnp online-softmax reference:
shape/dtype/config sweeps in interpret mode (deliverable (c))."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models import layers as L


def _qkv(b, s, h, hkv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 1), (8, 2)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_jnp(h, hkv, causal, window, dtype):
    b, s, d = 1, 256, 32
    q, k, v = _qkv(b, s, h, hkv, d, dtype)
    L.set_attn_impl("jnp")
    ref = L.flash_attention(q, k, v, causal=causal, window=window)
    try:
        L.set_attn_impl("pallas_interpret")
        out = L.flash_attention(q, k, v, causal=causal, window=window)
    finally:
        L.set_attn_impl("jnp")
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_pallas_q_offset_decode_chunk():
    """A later q chunk (kv cache longer than q) masks correctly."""
    b, h, d = 1, 2, 32
    sq, sk, off = 128, 256, 128
    q = jax.random.normal(jax.random.key(0), (b * h, sq, d))
    k = jax.random.normal(jax.random.key(1), (b * h, sk, d))
    v = jax.random.normal(jax.random.key(2), (b * h, sk, d))
    out = flash_attention_pallas(q, k, v, causal=True, q_offset=off,
                                 bq=128, bk=128, interpret=True)
    # reference: dense softmax with absolute positions
    import math
    s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(d)
    mask = (off + jnp.arange(sq))[:, None] >= jnp.arange(sk)[None, :]
    p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    ref = jnp.einsum("bqk,bkd->bqd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_pallas_block_skip_equals_full():
    """Window masking must skip kv blocks without changing results."""
    b, s, d = 1, 512, 32
    q, k, v = _qkv(b, s, 2, 2, d, jnp.float32, seed=3)
    L.set_attn_impl("jnp")
    ref = L.flash_attention(q, k, v, causal=True, window=100)
    try:
        L.set_attn_impl("pallas_interpret")
        out = L.flash_attention(q, k, v, causal=True, window=100)
    finally:
        L.set_attn_impl("jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
