"""Partitioning math: scheme presets, the AMSP dependency rule, paper
Tables IV/V/VI formulas, padding/block invariants."""
import math

import pytest

pytest.importorskip("hypothesis")  # optional [test] extra; degrade to skip, not collection error
from hypothesis import given, settings, strategies as st

from repro.core.partition import (ZeroAxes, ZeroConfig, grad_memory_bytes,
                                  optimizer_memory_bytes, padded_flat_size,
                                  preset, sharding_factor_table,
                                  weight_memory_bytes)

SIZES = {"data": 4, "repl": 2, "node": 4, "gcd": 2}


def _preset(scheme, **over):
    return preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data", "repl"),
                  l0_axes=("gcd",), axis_sizes=SIZES, **over)


def test_sharding_factor_table_matches_paper_table_iv():
    # paper Table IV: zero1 (1,1,NP); zero2 (1,NP,NP); zero3 (NP,NP,NP);
    # ours (2, P_g, NP)
    total = math.prod(SIZES.values())
    assert sharding_factor_table(_preset("zero1")) == dict(
        weights=1, grads=1, optimizer=total, secondary=1)
    assert sharding_factor_table(_preset("zero2")) == dict(
        weights=1, grads=total, optimizer=total, secondary=1)
    assert sharding_factor_table(_preset("zero3")) == dict(
        weights=total, grads=total, optimizer=total, secondary=total)
    topo = sharding_factor_table(_preset("zero_topo"))
    assert topo == dict(weights=2, grads=8, optimizer=total, secondary=8)


def test_zeropp_preset():
    cfg = _preset("zeropp")
    assert cfg.w_degree == math.prod(SIZES.values())
    assert cfg.sec_degree == 8           # intra tier
    assert cfg.quantize_weights and cfg.quantize_grads


@pytest.mark.parametrize("scheme", ["zero1", "zero2", "zero3", "zeropp",
                                    "zero_topo"])
def test_dependency_rule_all_presets(scheme):
    _preset(scheme).validate_dependency_rule()


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["zero1", "zero2", "zero3", "zeropp", "zero_topo"]),
       st.integers(1, 10_000_000))
def test_prop_padding_alignment(scheme, n):
    cfg = _preset(scheme)
    padded = padded_flat_size(n, cfg)
    b = cfg.block_for(n)
    assert padded >= n
    assert padded % (cfg.os_degree * b) == 0
    # every stage's shard is whole blocks
    assert (padded // cfg.w_degree) % b == 0
    assert (padded // cfg.g_degree) % b == 0
    # padding waste is bounded for small leaves (adaptive block)
    assert padded <= max(2 * n, 2 * cfg.os_degree * 4)


def test_memory_tables_match_paper():
    psi = 10_000_000
    z3 = _preset("zero3")
    zpp = _preset("zeropp")
    topo = _preset("zero_topo")
    n = math.prod(SIZES.values())
    # Table V
    assert weight_memory_bytes(z3, psi) == 2 * psi // n
    assert weight_memory_bytes(zpp, psi) == 2 * psi // n + psi // 8
    assert weight_memory_bytes(topo, psi) == 2 * psi // 2 + psi // 8
    # Table VI (fp32 accumulation here; paper uses fp16 -> factor 2)
    assert grad_memory_bytes(topo, psi) == 4 * psi // 8
    assert grad_memory_bytes(z3, psi) == 4 * psi // n
    # optimizer: K=12 everywhere
    for cfg in (z3, zpp, topo):
        assert optimizer_memory_bytes(cfg, psi) == 12 * psi // n


def test_memory_constant_in_scale_for_topo():
    """Paper: 'our memory occupation remains fixed regardless of workers'."""
    small = preset("zero_topo", intra_axes=("node", "gcd"),
                   inter_axes=("data",), l0_axes=("gcd",),
                   axis_sizes={"data": 2, "node": 4, "gcd": 2})
    big = preset("zero_topo", intra_axes=("node", "gcd"),
                 inter_axes=("data",), l0_axes=("gcd",),
                 axis_sizes={"data": 64, "node": 4, "gcd": 2})
    psi = 1 << 20
    assert weight_memory_bytes(small, psi) == weight_memory_bytes(big, psi)
    assert grad_memory_bytes(small, psi) == grad_memory_bytes(big, psi)
    # optimizer memory *does* shrink with scale (by design)
    assert optimizer_memory_bytes(big, psi) < optimizer_memory_bytes(small, psi)


def test_axes_disjointness_enforced():
    with pytest.raises(AssertionError):
        ZeroAxes(weight=("a",), extra_grad=("a",), replica=())


def test_block_for_small_leaves():
    cfg = _preset("zero_topo", quant_block=2048)
    assert cfg.block_for(10) == 4
    assert cfg.block_for(10_000_000) == 2048
    # monotone
    prev = 0
    for n in [1, 100, 10_000, 1_000_000, 100_000_000]:
        b = cfg.block_for(n)
        assert b >= prev
        prev = b
