"""System-level correctness (deliverable (c), DESIGN §7.3).

On a degree-1 mesh every collective is a no-op, so the engine's entire
flat-storage / custom-VJP / padding machinery must reproduce plain dense
autodiff *exactly* (fp32, quantization off). With quantization on, the loss
must track the exact value within block-quantization tolerance (paper
Figs 9/10 claim). zero_topo with quantization disabled must equal zero3
bit-for-bit at the loss level — same math, different partitioning.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import ParamView, TrainHparams, ZeroEngine
from repro.core.partition import padded_flat_size
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.registry import build_model, get_arch

AX = ("data", "node", "gcd")


class DenseView:
    """Plain dense reference implementing the ParamView protocol."""

    def __init__(self, params):
        self._p = params

    def mm(self, name, x, transpose=False):
        w = self._p[name]
        w2 = w.reshape(-1, w.shape[-1])
        if transpose:
            w2 = w2.T
        return jnp.matmul(x, w2)

    def get(self, name):
        return self._p[name]

    def embed_lookup(self, name, ids):
        return jnp.take(self._p[name], ids, axis=0)

    def expert_ffn(self, prefix, e_in):
        wg = self._p[prefix + "w_gate"]
        wu = self._p[prefix + "w_up"]
        wd = self._p[prefix + "w_down"]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", e_in, wg)) \
            * jnp.einsum("ecd,edf->ecf", e_in, wu)
        return jnp.einsum("ecf,efd->ecd", h, wd)

    def stacked(self, names):
        return {n: self._p[n] for n in names}

    def sub(self, params):
        return DenseView(params)


def _mesh1():
    return make_test_mesh(shape=(1, 1, 1), axes=AX)


def _setup(scheme="zero3", *, quant=None, dtype="float32", arch="qwen2-0.5b",
           seed=0, **over):
    mesh = _mesh1()
    arch_cfg = get_arch(arch).reduced(n_layers=2, d_model=128, vocab=256)
    model = build_model(arch_cfg)
    cfg = scheme_config(scheme, mesh, quant_block=32, compute_dtype=dtype,
                        **over)
    if quant is not None:
        cfg = dataclasses.replace(cfg, quantize_weights=quant,
                                  quantize_grads=quant)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=10, warmup_steps=0))
    state = eng.init_state(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, arch_cfg.vocab, (2, 33)), jnp.int32)}
    return mesh, arch_cfg, model, cfg, eng, state, batch


def _dense_params(eng, state):
    out = {}
    for n, spec in eng.specs.items():
        flat = state["master"][n]
        if spec.stack:
            out[n] = flat[:, : spec.logical_size].reshape(
                (spec.stack,) + spec.shape)
        else:
            out[n] = flat[: spec.logical_size].reshape(spec.shape)
    return out


def _engine_grads(eng, model, mesh, state, batch):
    loss_fn = model.loss_fn()
    specs = eng.state_in_specs()["primaries"]

    def local(primaries, b):
        def loss(p):
            v = ParamView(eng.fns, p, overlap=eng.cfg.overlap)
            l, t = loss_fn(v, b)
            return l / t

        return jax.value_and_grad(loss)(primaries)

    sm = shard_map(local, mesh=mesh,
                       in_specs=(specs, {"tokens": P()}),
                       out_specs=(P(), specs), check_vma=False)
    return jax.jit(sm)(state["primaries"], batch)


def test_zero3_grads_match_dense_autodiff():
    mesh, arch, model, cfg, eng, state, batch = _setup("zero3")
    loss_e, grads = _engine_grads(eng, model, mesh, state, batch)

    dense = _dense_params(eng, state)

    def dense_loss(p):
        l, t = model.lm.loss(DenseView(p), batch)
        return l / t

    loss_d, grads_d = jax.value_and_grad(dense_loss)(dense)
    np.testing.assert_allclose(float(loss_e), float(loss_d), rtol=1e-5)
    for n, spec in eng.specs.items():
        ge = np.asarray(grads[n])
        gd = np.asarray(grads_d[n])
        if spec.stack:
            ge = ge[:, : spec.logical_size].reshape(gd.shape)
            pad = np.asarray(grads[n])[:, spec.logical_size:]
        else:
            ge, pad = ge[: spec.logical_size].reshape(gd.shape), \
                np.asarray(grads[n])[spec.logical_size:]
        np.testing.assert_allclose(ge, gd, rtol=2e-4, atol=1e-5,
                                   err_msg=n)
        if pad.size:
            assert np.abs(pad).max() == 0, f"padding grad leaked: {n}"


def test_topo_unquantized_equals_zero3():
    _, _, model3, _, eng3, st3, batch = _setup("zero3")
    mesh, _, modelt, _, engt, stt, _ = _setup("zero_topo", quant=False)
    l3, _ = _engine_grads(eng3, model3, _mesh1(), st3, batch)
    lt, _ = _engine_grads(engt, modelt, mesh, stt, batch)
    np.testing.assert_allclose(float(l3), float(lt), rtol=1e-6)


def test_quantized_loss_within_tolerance():
    """Paper Figs 9/10: quantized topo loss tracks exact loss (~1%)."""
    _, _, model, _, eng, st, batch = _setup("zero3")
    meshq, _, modelq, _, engq, stq, _ = _setup("zero_topo", quant=True)
    l_exact, _ = _engine_grads(eng, model, _mesh1(), st, batch)
    l_quant, _ = _engine_grads(engq, modelq, meshq, stq, batch)
    assert abs(float(l_exact) - float(l_quant)) / float(l_exact) < 0.02


@pytest.mark.parametrize("scheme", ["zero1", "zero2", "zero3", "zeropp",
                                    "zero_topo"])
def test_all_schemes_train(scheme):
    mesh, arch, model, cfg, eng, state, batch = _setup(scheme, dtype="float32")
    step = eng.make_train_step(model.loss_fn(), {"tokens": P()})
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{scheme} failed to learn: {losses}"


def test_quantized_training_tracks_exact():
    """Short convergence run: quantized zero_topo loss curve stays within a
    few percent of exact zero3 on identical data (Figs 9/10 analogue)."""
    _, _, m3, _, e3, s3, batch = _setup("zero3")
    _, _, mt, _, et, st, _ = _setup("zero_topo", quant=True)
    step3 = e3.make_train_step(m3.loss_fn(), {"tokens": P()})
    stept = et.make_train_step(mt.loss_fn(), {"tokens": P()})
    for i in range(10):
        s3, me = step3(s3, batch)
        st, mq = stept(st, batch)
        rel = abs(float(me["loss"]) - float(mq["loss"])) \
            / max(float(me["loss"]), 1e-9)
        assert rel < 0.05, (i, float(me["loss"]), float(mq["loss"]))


@pytest.mark.parametrize("scheme", ["zero3", "zeropp", "zero_topo"])
def test_overlap_bitwise_identical_losses(scheme):
    """The double-buffered gather prefetch (ZeroConfig.overlap, DESIGN.md §3)
    is a schedule change only: loss AND gradients must be bitwise identical
    to the serial schedule."""
    _, _, m0, _, e0, s0, batch = _setup(scheme, overlap=False)
    _, _, m1, _, e1, s1, _ = _setup(scheme, overlap=True)
    l0, g0 = _engine_grads(e0, m0, _mesh1(), s0, batch)
    l1, g1 = _engine_grads(e1, m1, _mesh1(), s1, batch)
    assert float(l0) == float(l1), (float(l0), float(l1))
    for n in e0.specs:
        np.testing.assert_array_equal(np.asarray(g0[n]), np.asarray(g1[n]),
                                      err_msg=n)


def test_overlap_bitwise_identical_pallas_interpret():
    """Same guarantee with the quantization kernels on the Pallas
    (interpret-mode) implementation path."""
    _, _, m0, _, e0, s0, batch = _setup("zero_topo", quant=True,
                                        impl="pallas_interpret",
                                        overlap=False)
    _, _, m1, _, e1, s1, _ = _setup("zero_topo", quant=True,
                                    impl="pallas_interpret", overlap=True)
    l0, _ = _engine_grads(e0, m0, _mesh1(), s0, batch)
    l1, _ = _engine_grads(e1, m1, _mesh1(), s1, batch)
    assert float(l0) == float(l1), (float(l0), float(l1))


def test_impl_bitwise_jnp_vs_pallas_interpret():
    """Kernel-impl dispatch (DESIGN.md §5): with the fused dequant-matmul
    and fused INT4 dequant-reduce in the hot path, impl="jnp" and
    impl="pallas_interpret" must stay bitwise identical through
    zero_matmul/zero_gather_q — loss AND every per-leaf gradient.
    (The 8-device version of this check is the kernel_impl_equivalence
    scenario in tests/_scenarios.py.)"""
    _, _, mj, _, ej, sj, batch = _setup("zero_topo", quant=True, impl="jnp")
    _, _, mp, _, ep, sp, _ = _setup("zero_topo", quant=True,
                                    impl="pallas_interpret")
    lj, gj = _engine_grads(ej, mj, _mesh1(), sj, batch)
    lp, gp = _engine_grads(ep, mp, _mesh1(), sp, batch)
    assert float(lj) == float(lp), (float(lj), float(lp))
    for n in ej.specs:
        np.testing.assert_array_equal(np.asarray(gj[n]), np.asarray(gp[n]),
                                      err_msg=n)


def test_impl_bitwise_with_overlap():
    """The prefetched (mm_pre) fused path keeps the same impl-equivalence
    guarantee: overlap + pallas_interpret == overlap + jnp, bitwise."""
    _, _, mj, _, ej, sj, batch = _setup("zero_topo", quant=True, impl="jnp",
                                        overlap=True)
    _, _, mp, _, ep, sp, _ = _setup("zero_topo", quant=True,
                                    impl="pallas_interpret", overlap=True)
    lj, gj = _engine_grads(ej, mj, _mesh1(), sj, batch)
    lp, gp = _engine_grads(ep, mp, _mesh1(), sp, batch)
    assert float(lj) == float(lp), (float(lj), float(lp))
    for n in ej.specs:
        np.testing.assert_array_equal(np.asarray(gj[n]), np.asarray(gp[n]),
                                      err_msg=n)


def test_overlap_train_step_bitwise():
    """Full train step (fwd + bwd + grad RS + AdamW + update gather):
    overlap on/off produce identical losses and identical master weights."""
    _, _, m0, _, e0, s0, batch = _setup("zero_topo", overlap=False)
    _, _, m1, _, e1, s1, _ = _setup("zero_topo", overlap=True)
    step0 = e0.make_train_step(m0.loss_fn(), {"tokens": P()})
    step1 = e1.make_train_step(m1.loss_fn(), {"tokens": P()})
    for _ in range(3):
        s0, r0 = step0(s0, batch)
        s1, r1 = step1(s1, batch)
        assert float(r0["loss"]) == float(r1["loss"])
    for n in e0.specs:
        np.testing.assert_array_equal(np.asarray(s0["master"][n]),
                                      np.asarray(s1["master"][n]), err_msg=n)


def test_microbatch_accumulation_matches_single():
    mesh, arch, model, cfg, eng, state, batch = _setup("zero3")
    hp2 = TrainHparams(lr=1e-3, total_steps=10, warmup_steps=0, n_microbatch=2)
    eng2 = ZeroEngine(model.leaf_specs(), cfg, mesh, hp2)
    step1 = eng.make_train_step(model.loss_fn(), {"tokens": P()})
    step2 = eng2.make_train_step(model.loss_fn(), {"tokens": P()})
    import copy
    s1, m1 = step1(jax.tree.map(jnp.copy, state), batch)
    s2, m2 = step2(jax.tree.map(jnp.copy, state), batch)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-4)
    for n in eng.specs:
        np.testing.assert_allclose(np.asarray(s1["master"][n]),
                                   np.asarray(s2["master"][n]),
                                   rtol=1e-4, atol=1e-6, err_msg=n)
