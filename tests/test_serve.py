"""Serving correctness: teacher-forced decode must reproduce prefill logits —
the KV-cache path (dense / ring / MLA-absorbed / SSM state) equals the
full-sequence path."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.engine import TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.config import ShapeConfig
from repro.models.registry import build_model, get_arch
from repro.serve.engine import ServeEngine

AX = ("data", "node", "gcd")

# one representative per cache type
CASES = ["deepseek-7b",        # dense full-attn KV
         "gemma3-1b",          # ring SWA + global mix
         "minicpm3-4b",        # MLA latent (absorbed decode)
         "falcon-mamba-7b",    # SSM state
         "jamba-v0.1-52b",     # hybrid
         "whisper-medium"]     # enc-dec with cross-attention


def _setup(name, *, dtype="float32"):
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype=dtype)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    return mesh, arch, model, eng, state


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_prefill(name):
    """prefill(tokens[:n]) then teacher-forced decode of tokens[n:] must give
    the same final logits as prefill(tokens) (same positions, same cache)."""
    mesh, arch, model, eng, state = _setup(name)
    b, n_prompt, n_extra = 2, 24, 4
    total = n_prompt + n_extra
    rng = np.random.default_rng(0)
    toks = rng.integers(0, arch.vocab, (b, total), dtype=np.int32)

    def mkbatch(t):
        out = {"tokens": jnp.asarray(t)}
        if arch.n_patches:
            out["patches"] = jnp.asarray(
                rng.standard_normal((b, arch.n_patches, arch.d_model)) * 0.0,
                jnp.float32)
        if arch.enc_layers:
            out["frames"] = jnp.asarray(
                np.ones((b, arch.n_frames, arch.d_model)) * 0.01, jnp.float32)
        return out

    shape = ShapeConfig("t", total, b, "decode")
    se = ServeEngine(model, eng, mesh, shape)
    prefill = se.make_prefill()
    decode = se.make_decode()

    # full prefill reference
    logits_full, _ = prefill(state["primaries"], mkbatch(toks))

    # prompt prefill + teacher-forced decode — but caches must be sized to
    # `total`: prefill with the prompt padded? No: prefill(prompt) gives a
    # cache of length n_prompt; decode then appends. Cache shapes differ, so
    # rebuild a serve engine sized to the prompt.
    se_p = ServeEngine(model, eng, mesh,
                       ShapeConfig("p", n_prompt, b, "decode"))
    logits, caches = se_p.make_prefill()(state["primaries"],
                                         mkbatch(toks[:, :n_prompt]))
    # grow dense caches to `total` by zero-padding the seq dim
    caches = _grow(caches, model, arch, n_prompt, total, b)
    for i in range(n_extra):
        logits, caches = decode(state["primaries"], caches,
                                {"token": jnp.asarray(toks[:, n_prompt + i])})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def _grow(caches, model, arch, old, new, b):
    """Zero-pad seq-dim of full-attention/MLA caches from `old` to `new`."""
    from repro.models.transformer import kind_meta
    out = {}
    for kind, entry in caches.items():
        if kind == "pos":
            out[kind] = entry
            continue
        m = kind_meta(kind, arch)
        grown = {}
        for k, v in entry.items():
            if m.mixer == "attn" and not m.window and k in ("k", "v"):
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, new - old)
                grown[k] = jnp.pad(v, pad)
            elif m.mixer == "mla" and k == "lat":
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, new - old)
                grown[k] = jnp.pad(v, pad)
            else:
                grown[k] = v
        out[kind] = grown
    return out


def test_generate_deterministic():
    mesh, arch, model, eng, state = _setup("qwen2-0.5b")
    b, s = 2, 16
    se = ServeEngine(model, eng, mesh, ShapeConfig("t", s + 8, b, "decode"))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (b, s)),
                                   jnp.int32)}
    t1 = np.asarray(se.generate(state, batch, 8))
    t2 = np.asarray(se.generate(state, batch, 8))
    np.testing.assert_array_equal(t1, t2)
