"""launch.distributed config resolution — pure env/flag logic, no cluster.
The live rendezvous paths are covered by tests/test_multiprocess.py."""
import pytest

from repro.launch.distributed import DistConfig, detect


def test_explicit_flags_win(monkeypatch):
    monkeypatch.setenv("SLURM_PROCID", "3")
    monkeypatch.setenv("SLURM_NTASKS", "4")
    d = detect("host:1234", 2, 1)
    assert (d.coordinator, d.num_processes, d.process_id, d.source) == \
        ("host:1234", 2, 1, "flags")


def test_partial_flags_refused():
    with pytest.raises(ValueError, match="together"):
        detect("host:1234", None, None)


def test_slurm_autodetect(monkeypatch):
    monkeypatch.setenv("SLURM_PROCID", "3")
    monkeypatch.setenv("SLURM_NTASKS", "4")
    monkeypatch.setenv("SLURM_NODELIST", "frontier[00123-00170]")
    d = detect()
    assert d.source == "slurm" and d.process_id == 3
    assert d.coordinator == "frontier00123:12621"
    # explicit coordinator override
    monkeypatch.setenv("REPRO_COORDINATOR", "login1:9000")
    assert detect().coordinator == "login1:9000"


def test_ompi_needs_coordinator(monkeypatch):
    monkeypatch.setenv("OMPI_COMM_WORLD_RANK", "1")
    monkeypatch.setenv("OMPI_COMM_WORLD_SIZE", "2")
    assert detect().source == "single"    # no rank-0 address -> fall through
    monkeypatch.setenv("REPRO_COORDINATOR", "c:9")
    d = detect()
    assert d.source == "ompi" and d.num_processes == 2 and d.process_id == 1


def test_env_vars_and_single_default(monkeypatch):
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    monkeypatch.setenv("REPRO_COORDINATOR", "c:9")
    d = detect()
    assert d.source == "env" and d.process_id == 1 and d.is_distributed
    for k in ("REPRO_NUM_PROCESSES", "REPRO_PROCESS_ID", "REPRO_COORDINATOR"):
        monkeypatch.delenv(k)
    d = detect()
    assert d.source == "single" and not d.is_distributed


def test_invalid_configs_refused():
    with pytest.raises(AssertionError):
        DistConfig(None, 2, 0)            # distributed without coordinator
    with pytest.raises(AssertionError):
        DistConfig("c:9", 2, 2)           # rank out of range


def test_cli_args_roundtrip():
    import argparse
    from repro.launch.distributed import add_cli_args, from_args
    ap = argparse.ArgumentParser()
    add_cli_args(ap)
    args = ap.parse_args(["--coordinator", "h:1", "--num-processes", "2",
                          "--process-id", "1"])
    d = from_args(args)
    assert d == DistConfig("h:1", 2, 1, "flags")
    assert not from_args(ap.parse_args([])).is_distributed
