"""Topology-aware partition planner (repro.topo): search-space validity over
randomized topologies, cost-model consistency with the independently-written
comm_volume formulas, planner-vs-preset dominance, JSON round-trip, and the
--scheme auto path on a live (degree-1) mesh."""
import json
import math
import random

import pytest

from repro.topo.cost import (PHASES, Workload, memory_bytes, phase_axes,
                             phase_volumes, step_cost, tflops_per_device)
from repro.topo.model import (Link, Topology, frontier, gpu_pod,
                              load_topology, scaled, tpu_pod)
from repro.topo.planner import (enumerate_candidates, model_workload, plan,
                                plan_for_mesh, preset_on_topology)

WL = Workload(psi=20e9, n_layers=44)


def random_topology(rng: random.Random) -> Topology:
    k = rng.randint(1, 4)
    tiers = ["l0", "intra", "inter"]
    links = []
    bw = rng.uniform(100e9, 400e9)
    for i in range(k):
        links.append(Link(f"ax{i}", rng.choice([1, 2, 3, 4]), bw,
                          rng.uniform(1e-6, 20e-6),
                          tiers[min(i, 2)] if rng.random() < 0.8
                          else rng.choice(tiers)))
        bw /= rng.uniform(1.5, 16.0)   # strictly decreasing: fastest first
    return Topology(f"rand{rng.random():.6f}", tuple(links),
                    flops_per_device=rng.uniform(50e12, 400e12),
                    hbm_bytes=rng.choice([16e9, 64e9, 1e15]))


# ---------------------------------------------------------------------------
# property-style: randomized topologies (seeded RNG, no hypothesis dep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(25))
def test_every_candidate_valid_on_random_topology(seed):
    rng = random.Random(seed)
    topo = random_topology(rng)
    flat = set(topo.axis_names)
    cands = enumerate_candidates(topo)
    assert cands, topo
    for cfg in cands:
        cfg.validate_dependency_rule()              # AMSP rule
        a = cfg.axes
        assert set(a.weight + a.extra_grad + a.replica) == flat
        if a.secondary is not None:
            assert set(a.secondary) <= flat
            assert cfg.quantize_weights              # INT8 copy needs quant
        # cost model produces finite, non-negative numbers for every one
        c = step_cost(cfg, topo, WL)
        for ph in PHASES:
            assert math.isfinite(c.comm_s[ph]) and c.comm_s[ph] >= 0, (cfg, ph)
        assert c.compute_s > 0 and c.memory_total > 0


@pytest.mark.parametrize("seed", range(10))
def test_plan_ranking_sorted_and_dominates_presets(seed):
    rng = random.Random(1000 + seed)
    topo = random_topology(rng)
    plans = plan(topo, WL, memory_budget=float("inf"))
    times = [p.step_s for p in plans]
    assert times == sorted(times)
    for scheme in ("zero3", "zeropp", "zero_topo"):
        cfg = preset_on_topology(scheme, topo)
        c = step_cost(cfg, topo, WL)
        assert plans[0].step_s <= c.step_s(WL.hidden_fraction) + 1e-12, \
            (scheme, topo)


def test_memory_budget_excludes_oversized_plans():
    topo = frontier(48)
    plans = plan(topo, WL, memory_budget=10e9)     # 10 GB: tight for 20B
    fitting = [p for p in plans if p.cost.fits]
    assert fitting, "zero3-like plans (~1 GB/device) must fit 10 GB"
    # every fitting plan ranks before every non-fitting plan
    first_unfit = next((i for i, p in enumerate(plans) if not p.cost.fits),
                       len(plans))
    assert all(p.cost.fits for p in plans[:first_unfit])
    assert not any(p.cost.fits for p in plans[first_unfit:])
    assert plans[0].cost.memory_total <= 10e9


# ---------------------------------------------------------------------------
# acceptance: Frontier + 20B — planner never slower than any preset
# ---------------------------------------------------------------------------

def test_fused_kernel_knob_prices_dequant_roundtrip():
    """Workload.fused_kernels (DESIGN.md §5): the unfused pipeline pays the
    dequant HBM round-trips (kernel_s > 0, slower step); the fused default
    pays nothing, so every pre-existing cost number is unchanged."""
    import dataclasses
    topo = frontier(48)
    wl = model_workload("gpt_neox_20b")
    assert wl.fused_kernels
    for scheme in ("zero3", "zeropp", "zero_topo"):
        cfg = preset_on_topology(scheme, topo)
        fused = step_cost(cfg, topo, wl)
        unfused = step_cost(cfg, topo,
                            dataclasses.replace(wl, fused_kernels=False))
        assert fused.kernel_s == 0.0
        # comm volumes never depend on the kernel impl (fusion changes
        # compute, not communication)
        assert fused.volumes == unfused.volumes
        if cfg.quantize_weights or cfg.quantize_grads:
            assert unfused.kernel_s > 0.0, scheme
            assert unfused.step_s(wl.hidden_fraction) \
                > fused.step_s(wl.hidden_fraction), scheme
        else:
            assert unfused.kernel_s == 0.0, scheme
        if cfg.quantize_grads:
            # the unfused dW path writes the dense f32 grad and re-reads
            # it to quantize (matmul_quant epilogue removes it): at least
            # 8 B/param/microbatch of HBM traffic beyond the a2a side
            assert unfused.kernel_s * topo.hbm_bw \
                >= wl.n_microbatch * 8.0 * wl.psi, scheme


def test_planner_beats_every_preset_on_frontier_20b():
    topo = frontier(48)
    wl = model_workload("gpt_neox_20b")            # underscore form accepted
    assert 19e9 < wl.psi < 22e9 and wl.n_layers == 44
    best = plan(topo, wl)[0]
    for scheme in ("zero3", "zeropp", "zero_topo"):
        cfg = preset_on_topology(scheme, topo)
        c = step_cost(cfg, topo, wl)
        assert best.step_s <= c.step_s(wl.hidden_fraction) + 1e-12, scheme
    # and the presets themselves keep the paper's ordering
    t = {s: tflops_per_device(preset_on_topology(s, topo), topo, wl)
         for s in ("zero3", "zeropp", "zero_topo")}
    assert t["zero_topo"] > t["zeropp"] > t["zero3"], t


def test_scaling_model_trend_from_shared_cost_model():
    """Post-refactor scaling_model reproduces the paper's TFLOPS trend."""
    from benchmarks.scaling_model import step_time, tflops_per_gpu
    for gcds in (64, 384):
        row = {s: tflops_per_gpu(s, 20e9, gcds // 8)
               for s in ("zero3", "zeropp", "zero_topo")}
        assert row["zero_topo"] > row["zeropp"] > row["zero3"], row
    # topo's comm is constant in scale; zero3's grows
    comm = {n: step_time("zero_topo", 20e9, n)[1] for n in (8, 48)}
    assert abs(comm[48] - comm[8]) / comm[8] < 0.2, comm
    z3 = {n: step_time("zero3", 20e9, n)[1] for n in (8, 48)}
    assert z3[48] > z3[8], z3


# ---------------------------------------------------------------------------
# cost model vs benchmarks/comm_volume.py (independent formulas)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["zero3", "zeropp", "zero_topo"])
def test_cost_volumes_match_comm_volume_analytics(scheme):
    from benchmarks.comm_volume import analytic_volumes
    from repro.core.partition import preset
    psi, n_nodes = 20e9, 48
    sizes = {"data": n_nodes, "node": 4, "gcd": 2}
    cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                 l0_axes=("gcd",), axis_sizes=sizes)
    mine = phase_volumes(cfg, psi)
    theirs = analytic_volumes(scheme, psi, n_nodes)
    for k in ("fwd_allgather", "bwd_allgather", "cross_replica",
              "update_gather", "total"):
        assert mine[k] == pytest.approx(theirs[k], rel=1e-9), (k, mine, theirs)
    # the two-stage grad RS telescopes to comm_volume's single-stage figure
    assert mine["grad_rs_w"] + mine["grad_rs_e"] == \
        pytest.approx(theirs["grad_rs"], rel=1e-9), (mine, theirs)


def test_phase_axes_match_collective_inventory():
    """The cost model prices the collectives engine/linear actually emit."""
    topo = frontier(4)
    cfg = preset_on_topology("zero_topo", topo)
    ax = phase_axes(cfg)
    assert ax["fwd_allgather"] == cfg.axes.weight          # linear._gather_full
    assert ax["bwd_allgather"] == cfg.axes.secondary       # gather_secondary
    assert ax["grad_rs_w"] == cfg.axes.weight  # linear._grad_to_primary_shard
    assert ax["grad_rs_e"] == cfg.axes.extra_grad          # engine to_os
    assert ax["cross_replica"] == cfg.axes.replica         # cross_replica_grad
    assert ax["update_gather"] == cfg.axes.extra_grad + cfg.axes.replica
    z3 = preset_on_topology("zero3", topo)
    assert phase_axes(z3)["bwd_allgather"] == z3.axes.weight  # no secondary


def test_memory_matches_partition_tables():
    from repro.core.partition import (grad_buffer_bytes, grad_memory_bytes,
                                      optimizer_memory_bytes,
                                      weight_memory_bytes)
    topo = frontier(48)
    psi = 20e9
    for scheme in ("zero3", "zeropp", "zero_topo"):
        cfg = preset_on_topology(scheme, topo)
        m = memory_bytes(cfg, psi)
        assert m["weights"] == weight_memory_bytes(cfg, int(psi))
        # grads are charged at the buffer the engine actually allocates
        # (primary layout on the seed path), not the paper's Table VI
        # grad-shard figure — that one is kept as grads_table
        assert m["grads"] == grad_buffer_bytes(cfg, int(psi), streaming=False)
        assert m["grads"] == 4 * int(psi) // cfg.w_degree
        assert m["grads_table"] == grad_memory_bytes(cfg, int(psi))
        assert m["optimizer"] == optimizer_memory_bytes(cfg, int(psi))
        assert m["total"] == m["weights"] + m["grads"] + m["optimizer"]
        # streaming charges grads at os-shard layout: never more, and
        # strictly less whenever os_degree > w_degree
        ms = memory_bytes(cfg, psi, streaming=True)
        assert ms["grads"] == grad_buffer_bytes(cfg, int(psi), streaming=True)
        assert ms["grads"] == 4 * int(psi) // cfg.os_degree
        assert ms["grads"] <= m["grads"]
        if cfg.os_degree > cfg.w_degree:
            assert ms["grads"] < m["grads"]


def test_streaming_workload_pricing():
    """Workload.stream_grads (DESIGN.md §8): the grad phases move into the
    overlappable per-microbatch pool (exposed_s shrinks to the update
    gather), their volume scales with n_microbatch, and the memory-budget
    search admits schemes the seed regime rejects."""
    import dataclasses
    topo = frontier(48)
    wl = Workload(psi=20e9, n_layers=44, n_microbatch=4)
    wls = dataclasses.replace(wl, stream_grads=True)
    cfg = preset_on_topology("zero_topo", topo)
    seed = step_cost(cfg, topo, wl)
    strm = step_cost(cfg, topo, wls)
    # seed: the whole post-backward section is exposed
    assert seed.exposed_s == pytest.approx(
        seed.comm_s["grad_rs_e"] + seed.comm_s["cross_replica"]
        + seed.comm_s["update_gather"])
    # streaming: only the update gather stays exposed
    assert strm.exposed_s == pytest.approx(strm.comm_s["update_gather"])
    # per-microbatch cadence: stage-2 + cross-replica seconds scale ~n_mb
    # (plus the per-layer latency term)
    assert strm.comm_s["grad_rs_e"] >= wl.n_microbatch \
        * seed.comm_s["grad_rs_e"]
    assert strm.comm_s["cross_replica"] >= wl.n_microbatch \
        * seed.comm_s["cross_replica"]
    # per-microbatch phases and volumes are regime-independent
    for ph in ("fwd_allgather", "bwd_allgather", "grad_rs_w"):
        assert strm.comm_s[ph] == seed.comm_s[ph]
    assert strm.volumes == seed.volumes
    # memory: grads at os layout; a budget between the two admits schemes
    # only under streaming
    assert strm.memory["grads"] < seed.memory["grads"]
    budget = (seed.memory["total"] + strm.memory["total"]) / 2
    assert not step_cost(cfg, topo, wl, memory_budget=budget).fits
    assert step_cost(cfg, topo, wls, memory_budget=budget).fits
    # the planner under that budget picks a fitting plan in the streaming
    # regime (and tags the chosen config with stream_grads)
    plans = plan(topo, wls, memory_budget=budget)
    assert plans[0].cost.fits and plans[0].cfg.stream_grads


# ---------------------------------------------------------------------------
# topology model
# ---------------------------------------------------------------------------

def test_topology_json_roundtrip(tmp_path):
    topo = frontier(16)
    p = tmp_path / "frontier16.json"
    topo.save(p)
    again = Topology.load(p)
    assert again == topo
    assert load_topology(str(p)) == topo           # path form
    assert load_topology("frontier").name == "frontier"  # preset form
    with pytest.raises(ValueError, match="unknown topology"):
        load_topology("no-such-cluster")
    # hand-written JSON with defaulted fields parses too
    q = tmp_path / "custom.json"
    q.write_text(json.dumps(dict(name="mycluster", links=[
        dict(name="nvl", size=4, bandwidth=3e11, latency=2e-6, tier="intra"),
        dict(name="ib", size=8, bandwidth=2.5e10, latency=1e-5, tier="inter"),
    ])))
    custom = load_topology(str(q))
    assert custom.axis_names == ("nvl", "ib") and custom.n_devices == 32


def test_topology_orders_fastest_first_and_tiers():
    t = Topology("x", (
        Link("slow", 4, 1e9, 1e-5, "inter"),
        Link("fast", 2, 1e11, 1e-6, "l0"),
        Link("mid", 8, 1e10, 2e-6, "intra"),
    ))
    assert t.axis_names == ("fast", "mid", "slow")
    assert t.tiers() == dict(l0=("fast",), intra=("fast", "mid"),
                             inter=("slow",))
    assert t.bandwidth(("fast", "mid")) == 1e10        # bottleneck
    assert t.latency(("fast", "mid")) == 2e-6          # slowest hop
    assert t.group_size(("mid", "slow")) == 32
    for preset_topo in (frontier(), gpu_pod(), tpu_pod()):
        bws = [l.bandwidth for l in preset_topo.links]
        assert bws == sorted(bws, reverse=True)
    assert scaled(frontier(48), "data", 8).link("data").size == 8


def test_from_mesh_matches_zero_tiers(mesh1):
    from repro.launch.mesh import zero_tiers
    topo = Topology.from_mesh(mesh1)
    tiers = zero_tiers(mesh1)
    # same tier membership (ordering conventions differ: zero_tiers keeps
    # mesh order, the topology lists l0 first — preset() normalizes both)
    assert {k: set(v) for k, v in topo.tiers().items()} == \
        {k: set(v) for k, v in tiers.items()}
    assert dict(topo.axis_sizes) == dict(mesh1.shape)
    # preset built on the derived topology == preset built on the mesh
    from repro.launch.mesh import scheme_config
    a = preset_on_topology("zero_topo", topo)
    b = scheme_config("zero_topo", mesh1)
    assert a.axes == b.axes and dict(a.axis_sizes) == dict(b.axis_sizes)


# ---------------------------------------------------------------------------
# process-spanning meshes: Topology.from_mesh must land the process-boundary
# axis in the inter tier and price it at the inter link. Real multi-process
# coverage runs in tests/test_multiprocess.py (topology_tiers scenario);
# here a stub mesh with fake per-device process indices exercises the same
# code in-process, including layouts a 2-process CPU run can't produce.
# ---------------------------------------------------------------------------

class _FakeDevice:
    def __init__(self, process_index):
        self.process_index = process_index


class _FakeMesh:
    """Duck-typed mesh: axis_names / shape / devices are all from_mesh,
    zero_tiers and process_axes consume."""

    def __init__(self, shape: dict, n_processes: int):
        import numpy as np
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        n = math.prod(shape.values())
        assert n % n_processes == 0
        per = n // n_processes
        devs = [_FakeDevice(i // per) for i in range(n)]
        self.devices = np.array(devs, dtype=object).reshape(
            tuple(shape.values()))


def test_from_mesh_process_boundary_lands_inter():
    from repro.launch.mesh import process_axes
    from repro.topo.model import DEFAULT_TIER_BANDWIDTH

    # 2 processes x 4 devices: the leading inter axis spans processes
    mesh = _FakeMesh(dict(data=2, node=2, gcd=2), n_processes=2)
    assert process_axes(mesh) == ("data",)
    topo = Topology.from_mesh(mesh)
    link = topo.link("data")
    assert link.tier == "inter"
    assert link.bandwidth == DEFAULT_TIER_BANDWIDTH["inter"]
    assert topo.bandwidth(("data",)) == DEFAULT_TIER_BANDWIDTH["inter"]
    assert topo.tiers()["inter"] == ("data",)
    assert "procs@data" in topo.name

    # 4 processes x 2 devices: the boundary still sits between data groups
    mesh4 = _FakeMesh(dict(data=4, node=2, gcd=1), n_processes=4)
    assert process_axes(mesh4) == ("data",)
    assert Topology.from_mesh(mesh4).link("data").tier == "inter"

    # planner sanity on the process-spanning topology: candidates exist,
    # the top plan is valid and the step cost prices inter traffic > 0
    wl = Workload(psi=2e6, n_layers=2)
    plans = plan(topo, wl)
    assert plans and plans[0].step_s > 0
    plans[0].cfg.validate_dependency_rule()


def test_from_mesh_rejects_intra_process_boundary():
    # 4 processes x 2 devices on (2, 2, 2): the boundary cuts the "node"
    # axis — intra-tier collectives would cross the network
    mesh = _FakeMesh(dict(data=2, node=2, gcd=2), n_processes=4)
    from repro.launch.mesh import process_axes, zero_tiers
    assert "node" in process_axes(mesh)
    with pytest.raises(ValueError, match="process boundary"):
        zero_tiers(mesh)
    with pytest.raises(ValueError, match="process boundary"):
        Topology.from_mesh(mesh)


def test_process_axes_single_process():
    from repro.launch.mesh import process_axes
    mesh = _FakeMesh(dict(data=2, node=2, gcd=2), n_processes=1)
    assert process_axes(mesh) == ()


# ---------------------------------------------------------------------------
# --scheme auto end-to-end on a live (degree-1) mesh; 8-device semantics run
# in tests/_scenarios.py::auto_scheme
# ---------------------------------------------------------------------------

def test_scheme_auto_builds_engine(mesh1):
    import jax
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch.mesh import scheme_config
    from repro.models.registry import build_model, get_arch

    cfg = scheme_config("auto", mesh1, quant_block=64, psi=1e6, n_layers=2,
                        compute_dtype="float32")
    cfg.validate_dependency_rule()
    assert cfg.name == "auto"
    assert cfg.quant_block == 64 and cfg.compute_dtype == "float32"
    arch = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=64, vocab=128)
    model = build_model(arch)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh1,
                     TrainHparams(total_steps=2, warmup_steps=0))
    state = eng.init_state(jax.random.key(0))
    step = eng.make_train_step(model.loss_fn(), {"tokens": P()})
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 128, (2, 17)), jnp.int32)}
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["tokens"]) == 2 * 16     # next-token pairs per sequence


def test_planner_cli_main(tmp_path, capsys):
    from repro.topo import planner
    assert planner.main(["--topology", "frontier", "--model", "gpt_neox_20b",
                         "--top", "3"]) == 0
    out = capsys.readouterr().out
    assert "planner choice" in out and "zero_topo" in out
    # --save-topology writes loadable JSON
    p = tmp_path / "t.json"
    planner.main(["--topology", "gpu_pod", "--save-topology", str(p)])
    assert load_topology(str(p)).name == "gpu_pod"
    with pytest.raises(SystemExit, match="unknown model"):
        planner.main(["--model", "definitely-not-a-model"])


def test_plan_table_quick_runs(tmp_path, monkeypatch):
    # route the emitted BENCH_plan.json to tmp (it lands in cwd otherwise)
    monkeypatch.setenv("REPRO_BENCH_DIR", str(tmp_path))
    from benchmarks.plan_table import run
    lines = []
    assert run(print_fn=lines.append, quick=True) is True
    text = "\n".join(lines)
    assert "auto (planner)" in text and "Table IV" in text
    rec = json.loads((tmp_path / "BENCH_plan.json").read_text())
    assert rec["choice"]["label"] and rec["workload"]["psi"] == 20e9
