"""Multi-PROCESS test harness: real ``jax.distributed`` clusters on CPU.

Two halves in one file:

* ``run_cluster(scenario, n_proc, ...)`` — imported by
  test_multiprocess.py. Spawns ``n_proc`` pytest-free worker processes
  (``python tests/_mp.py <scenario> <rank> <n_proc> <n_devices> <port>``)
  that rendezvous via ``jax.distributed.initialize`` on a fresh local port
  and split ``n_devices`` fake CPU devices between them (2 x 4 = the same
  8-device topo mesh the in-process scenarios use). ``n_proc=1`` runs the
  identical scenario single-process — the reference side of every parity
  assertion. All workers are killed on the first failure or on deadline, so
  a hung rendezvous costs minutes, not the CI job timeout.

* worker ``main()`` — runs one scenario and prints ``MP_RESULT <json>``
  (rank 0) + ``MP_OK <scenario> <rank>`` (every rank). Scenarios assert
  internally; the JSON carries whatever the pytest side diffs across
  process layouts (loss reprs, state hashes, collective census).
"""
import hashlib
import json
import os
import socket
import subprocess
import sys
import time

HERE = os.path.dirname(__file__)

AX = ("data", "node", "gcd")


# ---------------------------------------------------------------------------
# harness side (runs inside pytest; must not import jax)
# ---------------------------------------------------------------------------

def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def run_cluster(scenario: str, n_proc: int = 2, n_devices: int = 8,
                extra: dict | None = None, timeout: float = 900.0) -> dict:
    """Run `scenario` on an n_proc cluster; return rank 0's MP_RESULT json.

    Asserts every rank exits 0 and prints MP_OK. ``extra`` is forwarded to
    the workers as json (kernel impl, shared tmp dirs, ...).
    """
    assert n_devices % n_proc == 0, (n_devices, n_proc)
    port = _free_port()
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)       # workers force their own device count
    argv_tail = [str(n_proc), str(n_devices), str(port),
                 json.dumps(extra or {})]
    # line-buffered pipes read by the OS; workers are small-output, so
    # letting them run to completion before read() cannot fill the pipe
    # (<64KB per rank) — but a crashed rank must kill the cluster NOW, not
    # at the deadline: a dead worker leaves the others blocked in a
    # collective, so poll every second and tear down on first failure
    procs = [subprocess.Popen(
        [sys.executable, os.path.join(HERE, "_mp.py"), scenario, str(rank)]
        + argv_tail,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
        for rank in range(n_proc)]
    deadline = time.monotonic() + timeout
    hung = failed_early = False
    while any(p.poll() is None for p in procs):
        if any(p.poll() not in (None, 0) for p in procs):
            failed_early = True
            break
        if time.monotonic() > deadline:
            hung = True
            break
        time.sleep(1.0)
    if hung or failed_early:
        for p in procs:
            if p.poll() is None:
                p.kill()
    outs = [p.communicate()[0] or "" for p in procs]
    if hung:
        raise AssertionError(
            f"cluster {scenario} ({n_proc} procs) hung past {timeout}s:\n"
            + "\n".join(f"-- rank {r} --\n{o[-2000:]}"
                        for r, o in enumerate(outs)))
    # report the genuinely-crashed rank first (peers of a dead worker were
    # SIGKILLed by the teardown above and carry no useful traceback)
    ranked = sorted(range(n_proc), key=lambda r: procs[r].returncode <= 0)
    for rank in ranked:
        assert procs[rank].returncode == 0, \
            (f"rank {rank}/{n_proc} of {scenario} failed "
             f"(exit {procs[rank].returncode}):\n{outs[rank][-4000:]}")
    for rank, out in enumerate(outs):
        assert f"MP_OK {scenario} {rank}" in out, out[-4000:]
    for line in outs[0].splitlines():
        if line.startswith("MP_RESULT "):
            return json.loads(line[len("MP_RESULT "):])
    raise AssertionError(f"rank 0 of {scenario} printed no MP_RESULT:\n"
                         f"{outs[0][-4000:]}")


# ---------------------------------------------------------------------------
# worker side (its own process; full jax stack)
# ---------------------------------------------------------------------------

def _worker_setup(rank: int, n_proc: int, n_devices: int, port: int):
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.launch.distributed import DistConfig, initialize
    dcfg = DistConfig(f"127.0.0.1:{port}", n_proc, rank, "flags") \
        if n_proc > 1 else DistConfig()
    initialize(dcfg, local_devices=n_devices // n_proc)
    import jax
    jax.config.update("jax_default_matmul_precision", "float32")
    if os.environ.get("REPRO_KERNEL_IMPL"):
        from repro.kernels import ops as _kops
        _kops.set_default_impl(os.environ["REPRO_KERNEL_IMPL"])
    return dcfg


def _mesh():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(shape=(2, 2, 2), axes=AX)


def _replicated_np(x, mesh):
    """Full global value of a sharded array, on every process (all-gather
    via resharding — pure data movement, bitwise-safe)."""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = jax.jit(lambda a: a,
                  out_shardings=NamedSharding(mesh, P()))(x)
    return np.asarray(rep.addressable_data(0))


def _sha(a) -> str:
    import numpy as np
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _build(impl: str | None, stream: bool = False):
    import numpy as np
    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.launch.mesh import scheme_config
    from repro.models.registry import build_model, get_arch

    mesh = _mesh()
    arch = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=128, vocab=256)
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32", impl=impl,
                        stream_grads=stream)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=8, warmup_steps=0))
    batch_np = {"tokens": np.random.default_rng(0).integers(
        0, arch.vocab, (8, 33)).astype(np.int32)}
    return mesh, model, eng, batch_np


def _sharded_batch(mesh, batch_np):
    from jax.sharding import PartitionSpec as P
    from repro.data.pipeline import shard_batch
    return shard_batch(batch_np, mesh, {"tokens": P(AX)})


def train_step_parity(extra: dict):
    """Two train steps of the full quantized zero_topo hot path. The JSON
    printed here must be IDENTICAL between a 2-process x 4-device cluster
    and the single-process 8-device run: losses/grad-norms bitwise (repr),
    every per-leaf master update bitwise (sha256), and the compiled step's
    collective census (counts + wire bytes). ``extra["stream"]`` runs the
    streaming grad path (DESIGN.md §8): the per-layer grad reduce chain
    inside the backward crosses the process boundary on the E/R axes, so
    this is the cross-process proof of the streaming tap."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch import hlo

    mesh, model, eng, batch_np = _build(extra.get("impl"),
                                        bool(extra.get("stream")))
    state = eng.init_state(jax.random.key(0))
    step = eng.make_train_step(model.loss_fn(), {"tokens": P(AX)})
    batch = _sharded_batch(mesh, batch_np)

    lowered = step.lower(state, batch)
    census = hlo.analyze(lowered.compile().as_text()).summary()

    losses, gnorms = [], []
    for _ in range(2):
        state, m = step(state, batch)
        h = eng.metrics_to_host(m)
        losses.append(repr(h["loss"]))
        gnorms.append(repr(h["grad_norm"]))
    masters = {n: _sha(_replicated_np(state["master"][n], mesh))
               for n in sorted(eng.specs)}
    prims = {n: _sha(_replicated_np(state["primaries"][n], mesh))
             for n in sorted(eng.specs)}
    return dict(losses=losses, gnorms=gnorms, masters=masters, prims=prims,
                census=dict(collective_counts=census["collective_counts"],
                            wire_bytes=census["wire_bytes"]))


def checkpoint_roundtrip(extra: dict):
    """Per-process checkpoint save -> restore is lossless on a live
    multi-process cluster, and training continues bitwise-identically from
    the restored state."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.train import checkpoint

    mesh, model, eng, batch_np = _build(extra.get("impl"))
    state = eng.init_state(jax.random.key(0))
    step = eng.make_train_step(model.loss_fn(), {"tokens": P(AX)})
    batch = _sharded_batch(mesh, batch_np)
    state, _ = step(state, batch)

    from repro.core.engine import host_scalar
    ckpt_dir = extra["ckpt_dir"]
    checkpoint.save(state, ckpt_dir, int(host_scalar(state["step"])),
                    scheme=eng.scheme_fingerprint())
    with open(os.path.join(ckpt_dir, "step_00000001", "meta.json")) as f:
        meta = json.load(f)
    if jax.process_count() > 1:
        assert meta["format"] == "per_process", meta["format"]
        assert meta["mesh"]["process_count"] == jax.process_count()
    restored = checkpoint.restore(ckpt_dir, 1, eng.state_shardings(),
                                  expect_scheme=eng.scheme_fingerprint())
    for k, v in checkpoint._flatten(state).items():
        a = _replicated_np(v, mesh)
        b = _replicated_np(checkpoint._flatten(restored)[k], mesh)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=k)
    # training continues bitwise-identically from the restored state
    s_a, m_a = step(jax.tree.map(jax.numpy.copy, state), batch)
    s_b, m_b = step(restored, batch)
    ha, hb = eng.metrics_to_host(m_a), eng.metrics_to_host(m_b)
    assert repr(ha["loss"]) == repr(hb["loss"]), (ha, hb)
    return dict(loss=repr(ha["loss"]),
                format=meta["format"], mesh=meta["mesh"])


def checkpoint_wrong_layout(extra: dict):
    """Restoring a checkpoint written by a different process/device layout
    raises MeshMismatch naming both layouts (not an opaque reshape)."""
    from repro.train import checkpoint

    mesh, model, eng, _ = _build(extra.get("impl"))
    try:
        checkpoint.restore(extra["ckpt_dir"], 1, eng.state_shardings(),
                           expect_scheme=eng.scheme_fingerprint())
    except checkpoint.MeshMismatch as e:
        msg = str(e)
        assert "checkpoint:" in msg and "restoring" in msg, msg
        return dict(raised=True, message=msg[:200])
    raise AssertionError("restore across process layouts did not raise "
                         "MeshMismatch")


# -- elastic kill-and-resume (DESIGN.md §11) --------------------------------
#
# The elastic scenarios use mesh axes ("data", "repl", "gcd"): the SAME
# (2, 2, 2) global mesh supports 1x8, 2x4 and 4x2 process layouts. Same
# global mesh + same scheme across layouts = bitwise training continuation
# at float32 (the PR-4 parity result); what changes across layouts is the
# per-process shard FILES — exactly what restore(reshard=True) reassembles.
#
# The scheme is the zero_topo preset with an explicit tier split
# (w=gcd, e=repl, r=data) rather than zero_tiers' default (r=data+repl):
# every reduction collective then has exactly TWO runtime participants
# (the grad reduce is two hierarchical 2-way stages with program-fixed
# association, the cross-replica sync a 2-way psum). A 2-way float sum is
# association-free, so the result cannot depend on how XLA's runtime
# splits a group between in-process and cross-process transports — with
# the default r=(data, repl), the 4-participant replica psum reassociates
# differently on 1x8 vs 2x4 and breaks bitwise resume.

ELASTIC_AX = ("data", "repl", "gcd")
ELASTIC_STEPS = 4        # reference trains 0..3; save interrupts after 2


def _elastic_build():
    from repro.core.engine import TrainHparams, ZeroEngine
    from repro.core.partition import preset
    from repro.launch.mesh import make_test_mesh
    from repro.models.registry import build_model, get_arch

    mesh = make_test_mesh(shape=(2, 2, 2), axes=ELASTIC_AX)
    arch = get_arch("qwen2-0.5b").reduced(n_layers=2, d_model=128, vocab=256)
    model = build_model(arch)
    cfg = preset("zero_topo", intra_axes=("repl", "gcd"),
                 inter_axes=("data",), l0_axes=("gcd",),
                 axis_sizes=dict(mesh.shape), quant_block=64,
                 compute_dtype="float32")
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=8, warmup_steps=0))
    return mesh, model, eng, arch


def _elastic_batch(mesh, arch, step_i: int):
    """Per-step deterministic batch, seeded by the step index so the
    interrupted and uninterrupted runs see the identical data stream."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.data.pipeline import shard_batch
    batch_np = {"tokens": np.random.default_rng(100 + step_i).integers(
        0, arch.vocab, (8, 33)).astype(np.int32)}
    return shard_batch(batch_np, mesh, {"tokens": P(ELASTIC_AX)})


def _elastic_run(mesh, model, eng, arch, state, steps):
    from jax.sharding import PartitionSpec as P
    step = eng.make_train_step(model.loss_fn(), {"tokens": P(ELASTIC_AX)})
    losses = []
    for i in steps:
        state, m = step(state, _elastic_batch(mesh, arch, i))
        losses.append(repr(eng.metrics_to_host(m)["loss"]))
    return state, losses


def _elastic_hashes(eng, state, mesh):
    return {f"{cat}/{n}": _sha(_replicated_np(state[cat][n], mesh))
            for cat in ("primaries", "master", "opt_m", "opt_v")
            for n in sorted(eng.specs)}


def elastic_reference(extra: dict):
    """The uninterrupted run: ELASTIC_STEPS steps straight through. Its
    per-step losses and final per-leaf hashes are the ground truth every
    kill-and-resume leg must reproduce bitwise."""
    import jax
    mesh, model, eng, arch = _elastic_build()
    state = eng.init_state(jax.random.key(0))
    state, losses = _elastic_run(mesh, model, eng, arch, state,
                                 range(ELASTIC_STEPS))
    return dict(losses=losses, hashes=_elastic_hashes(eng, state, mesh))


def elastic_save(extra: dict):
    """First half of the kill: train 2 of ELASTIC_STEPS steps on a 2x4
    cluster, write a per-process checkpoint, exit (the 'kill')."""
    import jax
    from repro.core.engine import host_scalar
    from repro.train import checkpoint

    mesh, model, eng, arch = _elastic_build()
    state = eng.init_state(jax.random.key(0))
    state, losses = _elastic_run(mesh, model, eng, arch, state, range(2))
    checkpoint.save(state, extra["ckpt_dir"], int(host_scalar(state["step"])),
                    scheme=eng.scheme_fingerprint())
    return dict(losses=losses)


def elastic_resume(extra: dict):
    """Second half: a DIFFERENT process layout (1x8 or 4x2) restores the
    2x4 checkpoint with reshard=True and runs the remaining steps. The
    pytest side asserts save.losses + resume.losses == reference.losses
    (bitwise) and the final hashes match the uninterrupted run's."""
    import jax
    from repro.core.engine import host_scalar
    from repro.train import checkpoint

    mesh, model, eng, arch = _elastic_build()
    meta_mesh = json.loads(open(os.path.join(
        extra["ckpt_dir"], "step_00000002", "meta.json")).read()).get("mesh")
    assert meta_mesh["process_count"] != jax.process_count(), \
        "resume layout must differ from the writing layout"
    state = checkpoint.restore(extra["ckpt_dir"], 2, eng.state_shardings(),
                               expect_scheme=eng.scheme_fingerprint(),
                               reshard=True)
    assert int(host_scalar(state["step"])) == 2
    state, losses = _elastic_run(mesh, model, eng, arch, state,
                                 range(2, ELASTIC_STEPS))
    return dict(losses=losses, hashes=_elastic_hashes(eng, state, mesh),
                saved_procs=meta_mesh["process_count"])


def elastic_strict(extra: dict):
    """reshard=False on a cross-layout restore must still raise
    MeshMismatch — strictness is demoted by an explicit opt-in, not gone."""
    from repro.train import checkpoint
    mesh, model, eng, arch = _elastic_build()
    try:
        checkpoint.restore(extra["ckpt_dir"], 2, eng.state_shardings(),
                           expect_scheme=eng.scheme_fingerprint(),
                           reshard=False)
    except checkpoint.MeshMismatch as e:
        assert "reshard=True" in str(e), e
        return dict(raised=True)
    raise AssertionError("strict cross-layout restore did not raise")


def topology_tiers(extra: dict):
    """Topology.from_mesh on a process-spanning mesh: the process-boundary
    axis lands in the inter tier and is priced at the inter link; the
    planner runs on the resulting topology; and a mesh whose process
    boundary would cut an intra axis is rejected by zero_tiers."""
    import jax
    from repro.launch.mesh import make_test_mesh, process_axes, zero_tiers
    from repro.topo import Topology, plan_for_mesh
    from repro.topo.model import DEFAULT_TIER_BANDWIDTH

    mesh = _mesh()
    spanning = process_axes(mesh)
    if jax.process_count() > 1:
        assert spanning == ("data",), spanning
    else:
        assert spanning == (), spanning
    tiers = zero_tiers(mesh)
    assert all(a in tiers["inter"] for a in spanning), (spanning, tiers)

    topo = Topology.from_mesh(mesh)
    link = topo.link("data")
    assert link.tier == "inter", link
    assert link.bandwidth == DEFAULT_TIER_BANDWIDTH["inter"], link
    assert topo.bandwidth(("data",)) == DEFAULT_TIER_BANDWIDTH["inter"]

    plans = plan_for_mesh(mesh, psi=2e6, n_layers=2)
    assert plans and plans[0].step_s > 0
    plans[0].cfg.validate_dependency_rule()

    if jax.process_count() > 1:
        # a mesh whose *leading* axis is an intra axis puts the process
        # boundary inside the node: zero_tiers must refuse it
        bad = make_test_mesh(shape=(2, 2, 2), axes=("node", "gcd", "data"))
        try:
            zero_tiers(bad)
        except ValueError as e:
            assert "process boundary" in str(e), e
        else:
            raise AssertionError("zero_tiers accepted a process boundary "
                                 "across intra axes")
    return dict(spanning=list(spanning), tier=link.tier,
                bandwidth=link.bandwidth, topo_name=topo.name)


def heartbeat_straggler(extra: dict):
    """Trace-mode rank heartbeat + stall detection on a live cluster
    (launch.distributed.Heartbeat over obs.heartbeat). One deliberately
    delayed rank stops stamping at step 2 while the healthy ranks advance;
    rank 0's straggler report must NAME it — 'behind' under a generous
    stall window, 'stalled' once its stamp goes older than the window —
    and an expected-but-never-started rank reads 'dead'. Coordination is
    file-based (poll the stamps, then a done-marker) so no collective can
    mask the very failure mode the detector exists for."""
    import jax
    from repro.launch.distributed import heartbeat
    from repro.obs import heartbeat as hb

    hb_dir = extra["hb_dir"]
    delay_rank = int(extra.get("delay_rank", 1))
    h = heartbeat(hb_dir)
    assert h.rank == jax.process_index()
    assert h.n_ranks == jax.process_count()

    for step in range(3):
        h.stamp(step)
    done = os.path.join(hb_dir, "done")
    if h.rank == delay_rank and h.n_ranks > 1:
        # the straggler: no more stamps; wait for rank 0's verdict
        deadline = time.monotonic() + 120
        while not os.path.exists(done):
            assert time.monotonic() < deadline, "no verdict from rank 0"
            time.sleep(0.1)
        return None

    # healthy ranks: wait until every rank's step-2 stamp is visible
    deadline = time.monotonic() + 120
    while True:
        stamps = hb.read_stamps(hb_dir)
        if len(stamps) == h.n_ranks and \
                all(s["step"] >= 2 for s in stamps.values()):
            break
        assert time.monotonic() < deadline, stamps
        time.sleep(0.1)
    time.sleep(1.2)          # age the straggler's final stamp
    h.stamp(5)               # healthy ranks advance past it

    if h.rank != 0:
        while not os.path.exists(done):
            time.sleep(0.1)
        return None

    behind = h.report(stall_s=30.0)
    stalled = h.report(stall_s=0.6)
    dead = hb.straggler_report(hb_dir, h.n_ranks + 1, stall_s=30.0)
    text = h.format_report(stall_s=30.0)
    with open(done, "w") as f:
        f.write("ok")

    if h.n_ranks > 1:
        assert not behind["ok"] and delay_rank in behind["stragglers"], behind
        assert behind["ranks"][delay_rank]["status"] == "behind", behind
        assert behind["ranks"][0]["status"] == "ok", behind
        assert behind["max_step"] == 5, behind
        assert stalled["ranks"][delay_rank]["status"] == "stalled", stalled
        assert f"rank {delay_rank}" in text, text
    assert dead["ranks"][h.n_ranks]["status"] == "dead", dead
    return dict(
        behind={str(r): v["status"] for r, v in behind["ranks"].items()},
        stalled={str(r): v["status"] for r, v in stalled["ranks"].items()},
        dead={str(r): v["status"] for r, v in dead["ranks"].items()},
        max_step=behind["max_step"], report=text)


SCENARIOS = dict(train_step_parity=train_step_parity,
                 heartbeat_straggler=heartbeat_straggler,
                 checkpoint_roundtrip=checkpoint_roundtrip,
                 checkpoint_wrong_layout=checkpoint_wrong_layout,
                 elastic_reference=elastic_reference,
                 elastic_save=elastic_save,
                 elastic_resume=elastic_resume,
                 elastic_strict=elastic_strict,
                 topology_tiers=topology_tiers)


def main():
    scenario, rank, n_proc, n_devices, port, extra = (
        sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
        int(sys.argv[5]), json.loads(sys.argv[6]))
    _worker_setup(rank, n_proc, n_devices, port)
    result = SCENARIOS[scenario](extra)
    if rank == 0 and result is not None:
        print("MP_RESULT " + json.dumps(result), flush=True)
    print(f"MP_OK {scenario} {rank}", flush=True)


if __name__ == "__main__":
    main()
