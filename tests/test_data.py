"""Data pipeline: determinism, learnable structure, pack/load round-trip."""
import numpy as np

from repro.data.pipeline import (BatchSpec, PackedDataset, SyntheticTokens,
                                 pack_documents)


def test_synthetic_deterministic():
    spec = BatchSpec(global_batch=4, seq_len=32, vocab=997)
    a = SyntheticTokens(spec, seed=3).batch(7)
    b = SyntheticTokens(spec, seed=3).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(spec, seed=4).batch(7)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_learnable_structure():
    spec = BatchSpec(global_batch=2, seq_len=64, vocab=101)
    t = SyntheticTokens(spec).batch(0)["tokens"]
    idx = np.arange(1, 65)
    m = (idx % 4) != 0
    succ = (t[:, :-1] * 31 + 7) % 101
    np.testing.assert_array_equal(t[:, 1:][:, m], succ[:, m])
    assert (t >= 0).all() and (t < 101).all()


def test_synthetic_modalities():
    spec = BatchSpec(2, 16, 50, n_patches=4, n_frames=8, d_model=32)
    b = SyntheticTokens(spec).batch(0)
    assert b["patches"].shape == (2, 4, 32)
    assert b["frames"].shape == (2, 8, 32)


def test_pack_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 1000, size=rng.integers(5, 200)) for _ in range(50)]
    path = tmp_path / "corpus.bin"
    n_rows = pack_documents(docs, path, row_len=64, eod_token=0)
    ds = PackedDataset(path)
    assert ds.n_rows == n_rows and ds.row_len == 64
    total = sum(len(d) + 1 for d in docs)
    assert n_rows == total // 64
    # contents preserved in order
    flat = np.concatenate([np.concatenate([d, [0]]) for d in docs])
    np.testing.assert_array_equal(ds.data.reshape(-1),
                                  flat[: n_rows * 64].astype(np.uint32))
    # deterministic batches, right shape
    b1 = ds.batch(3, 4, seed=1)
    b2 = ds.batch(3, 4, seed=1)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 64) and b1.dtype == np.int32
