"""Shared fixtures. NOTE: XLA_FLAGS is deliberately NOT set here — in-process
tests use degree-1 meshes pinned to the first device, so they pass under any
host device count (locally that is 1 device; CI exports
XLA_FLAGS=--xla_force_host_platform_device_count=8 for the whole run, per
.github/workflows/ci.yml). Distributed semantics are exercised by subprocess
scenarios (test_distributed.py, test_overlap.py, test_collectives.py) that
always force their own 8-device view regardless of the parent env, and by
real multi-PROCESS jax.distributed clusters (test_multiprocess.py via
tests/_mp.py) whose workers likewise pin their own local device count."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")

# CI interpret leg: REPRO_KERNEL_IMPL=pallas_interpret reruns the suite with
# the Pallas kernel bodies interpreted on CPU. ZeroConfig.impl defaults to
# None (= inherit this process default), so every config built by the tests
# picks it up unless a test pins impl explicitly.
if os.environ.get("REPRO_KERNEL_IMPL"):
    from repro.kernels import ops as _kops
    _kops.set_default_impl(os.environ["REPRO_KERNEL_IMPL"])


@pytest.fixture(scope="session")
def mesh1():
    """Degree-1 three-tier mesh: all sharding degrees 1, full code path."""
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(shape=(1, 1, 1), axes=("data", "node", "gcd"))


def tiny_cfg(mesh, scheme="zero_topo", quant_block=64, **over):
    from repro.launch.mesh import scheme_config
    return scheme_config(scheme, mesh, quant_block=quant_block, **over)
