"""Paged KV pool: page-table admission/eviction invariants, SLO rejection,
and preemption under oversubscription (the deterministic step-count census
the serve benchmark gates; full request-storm run in benchmarks/serve_load)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.config import ShapeConfig
from repro.models.registry import build_model, get_arch
from repro.serve.paged import PagedKV, seq_entry_keys
from repro.serve.scheduler import ContinuousBatcher, Request, ServeSLO

AX = ("data", "node", "gcd")


def _setup(name="qwen2-0.5b"):
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    return mesh, arch, model, eng, state


def _paged(model, n_slots=3, max_len=16, page=4, n_pages=0):
    return PagedKV(model, ShapeConfig("p", max_len, n_slots, "decode"),
                   page_size=page, n_pages=n_pages)


def test_page_accounting():
    """alloc / alloc_prefix / release keep table, owner, and free list
    consistent, fail cleanly on exhaustion, and never leak pages."""
    _, _, model, _, _ = _setup()
    pk = _paged(model, n_slots=3, max_len=16, page=4, n_pages=5)
    assert pk.blocks_per_slot == 4
    assert pk.pages_needed(1) == 1 and pk.pages_needed(4) == 1 \
        and pk.pages_needed(5) == 2
    assert pk.free_pages() == 5

    assert pk.alloc_prefix(0, 8)          # two pages for slot 0
    assert (pk.table[0, :2] >= 0).all() and (pk.table[0, 2:] < 0).all()
    assert pk.free_pages() == 3
    assert pk.alloc(0, 1)                 # idempotent: already allocated
    assert pk.free_pages() == 3
    assert all(pk.owner[pk.table[0, b]] == 0 for b in range(2))

    # slot 1 wants 4 pages but only 3 are free: refuse without side effects
    assert not pk.alloc_prefix(1, 16)
    assert (pk.table[1] < 0).all() and pk.free_pages() == 3

    assert pk.alloc_prefix(1, 12)         # exactly the remaining 3
    assert pk.free_pages() == 0
    assert not pk.alloc(2, 0)             # exhausted

    # unallocated / inactive entries redirect to the sink page
    dt = np.asarray(pk.device_table())
    assert (dt[2] == pk.n_pages).all() and (dt[0, 2:] == pk.n_pages).all()
    assert (dt[0, :2] < pk.n_pages).all()

    pk.release(0)
    assert pk.free_pages() == 2 and (pk.table[0] < 0).all()
    assert (pk.owner >= 0).sum() == 3     # slot 1 still holds its pages
    pk.release(1)
    assert pk.free_pages() == 5 and (pk.owner < 0).all()


def test_pageable_entries():
    """Sequence-indexed entries page; O(1)-per-slot entries stay dense."""
    _, _, model, _, _ = _setup("falcon-mamba-7b")
    shape = ShapeConfig("p", 16, 2, "decode")
    # mamba caches are all O(1) per slot: nothing to page
    assert not seq_entry_keys(model, shape)
    _, _, model, _, _ = _setup("qwen2-0.5b")
    keys = seq_entry_keys(model, shape)
    assert keys and all(k in ("k", "v", "lat") for _, k in keys)


def test_slo_rejection():
    """Queue-wait bound: with one slot and long decodes, late requests are
    deterministically rejected, and every request ends exactly once."""
    mesh, arch, model, eng, state = _setup()
    rng = np.random.default_rng(2)
    slo = ServeSLO(max_queue_steps=3)
    cb = ContinuousBatcher(model, eng, mesh, n_slots=1, max_len=32,
                           prompt_len=8, slo=slo)
    reqs = [Request(rid=i, prompt=rng.integers(0, arch.vocab, 8)
                    .astype(np.int32), max_new=8) for i in range(6)]
    cb.run(state["primaries"], reqs)
    c = cb.counters
    assert all(r.done for r in reqs)
    assert c["rejected"] > 0
    assert c["rejected"] + c["retired"] == len(reqs)
    assert c["admitted"] == c["retired"] + c["preempted"]
    assert all(r.out == [] for r in reqs if r.rejected)
    assert not cb.queue


def test_preemption_oversubscription():
    """n_pages < slots * blocks_per_slot: lazy growth runs the free list dry
    mid-decode, the youngest slot is evicted (pages released, output reset,
    requeued at the front) and later finishes; the pool never leaks."""
    mesh, arch, model, eng, state = _setup()
    rng = np.random.default_rng(3)
    cb = ContinuousBatcher(model, eng, mesh, n_slots=3, max_len=16,
                           prompt_len=4, page_size=4,
                           # 3 slots admit on 1 page each; each then needs a
                           # 2nd page mid-decode -> 4 pages can't hold 3x2
                           n_pages=4,
                           slo=ServeSLO(max_queue_steps=50))
    reqs = [Request(rid=i, prompt=rng.integers(0, arch.vocab, 4)
                    .astype(np.int32), max_new=8) for i in range(4)]
    cb.run(state["primaries"], reqs)
    c = cb.counters
    assert all(r.done for r in reqs)
    assert c["preempted"] > 0
    assert c["rejected"] + c["retired"] == len(reqs)
    assert c["admitted"] == c["retired"] + c["preempted"]
    # drained: every page back on the free list, no owners, sink table
    assert cb.paged.free_pages() == cb.paged.n_pages
    assert (cb.paged.owner < 0).all() and (cb.paged.table < 0).all()
    retired = [r for r in reqs if not r.rejected]
    assert all(1 <= len(r.out) <= r.max_new for r in retired)


def test_paged_matches_unpaged_batcher():
    """Fully-provisioned paged pool == oversubscribed pool that never
    actually preempts: the page layout cannot change the tokens."""
    mesh, arch, model, eng, state = _setup()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, arch.vocab, 8).astype(np.int32)
               for _ in range(3)]

    def run(n_pages):
        cb = ContinuousBatcher(model, eng, mesh, n_slots=2, max_len=24,
                               prompt_len=8, page_size=4, n_pages=n_pages)
        reqs = [Request(rid=i, prompt=p, max_new=5)
                for i, p in enumerate(prompts)]
        cb.run(state["primaries"], reqs)
        assert cb.counters["preempted"] == 0
        return [list(r.out) for r in reqs]

    assert run(0) == run(12)   # 0 = fully provisioned; 12 = exactly enough
