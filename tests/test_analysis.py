"""Comm-contract verifier (repro.analysis): unit tests + mutation tests.

Single-process units: the Layer-1 jaxpr walker on toy traced programs (each
schedule rule broken deliberately, asserting the exact rule id), Layer-2
replica-group parsing / tier classification / policy on synthetic HLO with a
fake mesh, and the Layer-3 AST linter rules with waivers and tracked
exemptions. The real-engine clean-grid and compiled-HLO mutation scenarios
run on 8 fake devices in a subprocess (tests/_analysis_scenarios.py).
"""
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest

HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(HERE, "..", "src"))

from repro.analysis import dataflow, lint  # noqa: E402
from repro.analysis import contracts  # noqa: E402
from repro.analysis import tags  # noqa: E402
from repro.core.partition import preset  # noqa: E402


# ---------------------------------------------------------------------------
# Layer 1: jaxpr dataflow rules on toy programs
# ---------------------------------------------------------------------------

def _issue(x):
    return tags.tag(x, role="issue", machine="gather")


def _wait(x):
    return tags.tag(x, role="wait", machine="gather")


def _toy_report(mutation):
    """A 2-slot rotation schedule over a scan, with one deliberate break."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def step(ws, x):
        def body(carry, w):
            acc, buf = carry
            nxt = _issue(w)                    # prefetch next layer
            if mutation == "drop_wait":
                acc = acc + x.sum()            # buf overwritten, never waited
            else:
                y = _wait(buf)
                acc = acc + (y * x).sum()
                if mutation == "double_wait":
                    acc = acc + _wait(buf).sum()
                if mutation == "wait_no_issue":
                    acc = acc + _wait(w * 2.0).sum()
            return (acc, nxt), None

        buf0 = _issue(ws[0])
        (acc, _), _ = lax.scan(body, (jnp.float32(0.0), buf0), ws)
        return acc

    with tags.tagging():
        jx = jax.make_jaxpr(step)(jnp.ones((3, 4)), jnp.ones(4))
    return dataflow.analyze_jaxpr(jx, label="toy")


def test_toy_clean():
    rep = _toy_report("clean")
    assert rep.ok, rep.render()
    assert rep.census["tags/gather/issue"] == 2    # body + prologue
    assert rep.census["tags/gather/wait"] == 1


@pytest.mark.parametrize("mutation,rule", [
    ("drop_wait", "buffer-overwrite-before-wait"),
    ("double_wait", "gather-double-wait"),
    ("wait_no_issue", "gather-wait-without-issue"),
])
def test_toy_mutations(mutation, rule):
    rep = _toy_report(mutation)
    assert rule in rep.rules(), (mutation, rep.render())


def test_dead_issue():
    import jax
    import jax.numpy as jnp

    def f(x):
        _ = _issue(x)                          # bytes dropped on the floor
        return x * 2.0

    with tags.tagging():
        jx = jax.make_jaxpr(f)(jnp.ones(4))
    rep = dataflow.analyze_jaxpr(jx)
    assert rep.rules() == {"gather-dead-issue"}, rep.render()


@pytest.mark.parametrize("mutation,rule", [
    ("clean", None),
    ("from_carry", "sink-not-from-xs"),
    ("twice", "sink-multiplicity"),
])
def test_sink_rules(mutation, rule):
    import jax
    import jax.numpy as jnp
    from jax import lax

    def step(ws, x):
        def body(c, w):
            if mutation == "from_carry":
                s = tags.tag(c, role="sink", machine="stream", name="leaf")
            else:
                s = tags.tag(w, role="sink", machine="stream", name="leaf")
            c = c + (s * 1.0).sum()
            if mutation == "twice":
                s2 = tags.tag(w, role="sink", machine="stream", name="leaf")
                c = c + s2.sum()
            return c, None

        c, _ = lax.scan(body, x.sum(), ws)
        return c

    with tags.tagging():
        jx = jax.make_jaxpr(step)(jnp.ones((3, 4)), jnp.ones(4))
    rep = dataflow.analyze_jaxpr(jx)
    if rule is None:
        assert rep.ok, rep.render()
    else:
        assert rule in rep.rules(), rep.render()


def test_tags_disabled_are_identity():
    """Outside the tagging() context the tag is a no-op: the traced program
    contains no contract_tag primitives (the hot path stays byte-identical
    when the verifier is not looking)."""
    import jax
    import jax.numpy as jnp

    jx = jax.make_jaxpr(lambda x: _wait(_issue(x)).sum())(jnp.ones(4))
    prims = {e.primitive.name for e in jx.jaxpr.eqns}
    assert dataflow.TAG_PRIMITIVE not in prims


# ---------------------------------------------------------------------------
# Layer 2: replica-group parsing, tier classification, policy
# ---------------------------------------------------------------------------

AXES = ("data", "node", "gcd")


def _fake_mesh():
    """Duck-typed mesh: classify() only touches axis_names/shape/devices."""
    return SimpleNamespace(axis_names=AXES,
                           shape={"data": 2, "node": 2, "gcd": 2},
                           devices=np.zeros((2, 2, 2)))


def _cfg(**over):
    return preset("zero_topo", intra_axes=("node", "gcd"),
                  inter_axes=("data",), l0_axes=("gcd",),
                  axis_sizes={"data": 2, "node": 2, "gcd": 2},
                  quant_block=64, **over)


def test_group_members_explicit():
    assert contracts.group_members(
        "x = f32[8] all-gather(y), replica_groups={{0,1},{2,3}}") == [0, 1]


def test_group_members_iota():
    # arange(8).reshape(2,2,2).transpose(1,2,0) -> first row [0, 4]
    line = "x = f32[8] all-gather(y), replica_groups=[4,2]<=[2,2,2]T(1,2,0)"
    assert contracts.group_members(line) == [0, 4]
    line = "x = f32[8] all-gather(y), replica_groups=[4,2]<=[8]"
    assert contracts.group_members(line) == [0, 1]


def test_spanned_axes_and_tiers():
    dims = (2, 2, 2)
    assert contracts.spanned_axes([0, 1], dims, AXES) == ("gcd",)
    assert contracts.spanned_axes([0, 2], dims, AXES) == ("node",)
    assert contracts.spanned_axes([0, 4], dims, AXES) == ("data",)
    assert contracts.spanned_axes([0, 1, 2, 3], dims, AXES) == ("node", "gcd")


def _hlo(body: str) -> str:
    return textwrap.dedent(f"""\
    HloModule toy

    ENTRY %main (p0: f32[131072]) -> f32[131072] {{
    {body}
    }}
    """)


def test_dtype_tier_violation_and_quantized_pass():
    mesh, cfg = _fake_mesh(), _cfg()
    # big fp all-reduce spanning all axes: inter tier, no allowlist class
    # (zero_topo quantizes grads, so grads-unquantized does not apply)
    bad = _hlo("  %ar = f32[131072]{0} all-reduce(%p0), "
               "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add")
    rep = contracts.check_hlo(bad, cfg, mesh, n_microbatch=0)
    assert "dtype-tier" in rep.rules(), rep.render()
    # the same payload on the s8 wire passes
    good = _hlo("  %ag = s8[131072]{0} all-gather(%q), "
                "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}")
    rep = contracts.check_hlo(good, cfg, mesh, n_microbatch=0)
    assert rep.ok, rep.render()
    assert rep.census["collectives/all-gather/inter/int"] == 1


def test_fp_allowlist_classes():
    mesh = _fake_mesh()
    # cross-replica sync: fp32 all-reduce over the replica axes only
    crs = _hlo("  %ar = f32[131072]{0} all-reduce(%p0), "
               "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add")
    rep = contracts.check_hlo(crs, _cfg(), mesh, n_microbatch=0)
    assert rep.ok, rep.render()
    # update all-gather over E+R: fp allowed only while the config leaves it
    # unquantized
    upd = _hlo("  %ag = f32[131072]{0} all-gather(%p0), "
               "replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={0}")
    assert contracts.check_hlo(upd, _cfg(), mesh, n_microbatch=0).ok
    rep = contracts.check_hlo(upd, _cfg(quantize_update_gather=True), mesh,
                              n_microbatch=0)
    assert "dtype-tier" in rep.rules(), rep.render()
    # scale sibling: small fp rides with a big int payload over the same group
    pair = _hlo("  %ag = s8[131072]{0} all-gather(%q), "
                "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
                "  %sc = f32[8192]{0} all-gather(%s), "
                "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}")
    rep = contracts.check_hlo(pair, _cfg(), mesh, n_microbatch=0)
    assert rep.ok, rep.render()


def test_determinism_census():
    mesh, cfg = _fake_mesh(), _cfg()
    # a small fp all-reduce beyond the replica axes is only legitimate as a
    # token psum; with a budget of zero, one is a raw lax.psum that must be
    # rewritten through det_psum
    psum = _hlo("  %ar = f32[1]{0} all-reduce(%p0), "
                "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add")
    rep = contracts.check_hlo(psum, cfg, mesh, n_microbatch=0)
    assert "determinism" in rep.rules(), rep.render()
    assert rep.census["collectives/small_fp_allreduce"] == 1
    # under budget is fine: XLA may fold/hoist the per-microbatch psums
    assert contracts.check_hlo(psum, cfg, mesh, n_microbatch=1).ok
    # small fp all-reduces spanning only the replica axes are the per-leaf
    # cross-replica syncs — excluded from the census even at budget zero
    crs = _hlo("  %ar = f32[1]{0} all-reduce(%p0), "
               "replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add")
    rep = contracts.check_hlo(crs, cfg, mesh, n_microbatch=0)
    assert rep.ok, rep.render()
    assert rep.census["collectives/small_fp_allreduce"] == 0


def test_mixed_tuple_classifies_int():
    c = contracts._dtype_census("(s8[65536], f32[1024])")
    assert c["int_bytes"] > c["fp_bytes"]


# ---------------------------------------------------------------------------
# Layer 3: lint rules, waivers, tracked exemptions
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, src, rel="somewhere/mod.py"):
    f = tmp_path / "mod.py"
    f.write_text(textwrap.dedent(src))
    from repro.analysis.report import Report
    rep = Report()
    lint.lint_file(f, rel, rep)
    return rep


def test_lint_raw_psum_and_waiver(tmp_path):
    rep = _lint_src(tmp_path, """\
        from jax import lax
        def f(x):
            return lax.psum(x, ("data",))
    """)
    assert rep.rules() == {"raw-psum"}
    rep = _lint_src(tmp_path, """\
        from jax import lax
        def f(x):
            # contract: allow[raw-psum] -- integer counts, order-exact
            return lax.psum(x, ("data",))
    """)
    assert rep.ok, rep.render()
    # marker anywhere in the contiguous comment block above counts
    rep = _lint_src(tmp_path, """\
        from jax import lax
        def f(x):
            # contract: allow[raw-psum] -- a long justification that
            # continues on a second comment line
            return lax.psum(x, ("data",))
    """)
    assert rep.ok, rep.render()


def test_lint_allowed_locations(tmp_path):
    src = """\
        from jax import lax
        def f(x):
            return lax.psum(x, ("data",))
    """
    assert _lint_src(tmp_path, src, rel="core/collectives.py").ok
    assert not _lint_src(tmp_path, src, rel="core/engine.py").ok


def test_lint_pallas_and_dequant(tmp_path):
    rep = _lint_src(tmp_path, """\
        import jax.experimental.pallas as pl
        from ..kernels import ops
        def f(x, q, s):
            y = pl.pallas_call(None)(x)
            a = ops.dequantize_int8(q, s)     # sanctioned dispatch
            b = dequantize_int8(q, s)         # raw quant math
            return y, a, b
    """)
    assert rep.rules() == {"pallas-call", "dequant-math"}, rep.render()
    assert _lint_src(tmp_path, """\
        import jax.experimental.pallas as pl
        def k(x):
            return pl.pallas_call(None)(x)
    """, rel="kernels/custom.py").ok


def test_lint_ops_dispatch_and_exemptions(tmp_path, monkeypatch):
    rep = _lint_src(tmp_path, """\
        from ..kernels.quant_blockwise import quantize_int8_pallas
    """)
    assert rep.rules() == {"ops-dispatch"}
    # the attention/scan promotion emptied the tracked-exemption table:
    # models/layers.py may no longer import the kernel module directly
    assert lint.OPS_DISPATCH_EXEMPT == {}
    rep = _lint_src(tmp_path, """\
        from ..kernels.flash_attention import flash_attention_pallas
    """, rel="models/layers.py")
    assert "ops-dispatch" in rep.rules(), rep.render()
    rep = _lint_src(tmp_path, """\
        from ..kernels.selective_scan import selective_scan_pallas
    """, rel="models/ssm.py")
    assert "ops-dispatch" in rep.rules(), rep.render()
    # the machinery stays: an exemption that matches no import is stale
    monkeypatch.setitem(lint.OPS_DISPATCH_EXEMPT, "models/ssm.py",
                        ("selective_scan",))
    rep = _lint_src(tmp_path, "x = 1\n", rel="models/ssm.py")
    assert rep.rules() == {"stale-exemption"}, rep.render()
    rep = _lint_src(tmp_path, """\
        from ..kernels.selective_scan import selective_scan_pallas
    """, rel="models/ssm.py")
    assert rep.ok, rep.render()


def test_lint_version_api(tmp_path):
    rep = _lint_src(tmp_path, """\
        import jax
        from jax import lax
        from jax.experimental.shard_map import shard_map
        from jax.core import Primitive
        from jax.sharding import AxisType
        def f():
            m = jax.make_mesh((2,), ("a",))
            return jax.shard_map, lax.pvary
    """)
    assert rep.rules() == {"version-api"}
    assert len(rep.findings) == 6, rep.render()
    assert _lint_src(tmp_path, "import jax\nm = jax.make_mesh((2,), ('a',))\n",
                     rel="compat.py").ok


def test_lint_repo_is_clean():
    """The shipped package has zero unwaived violations (acceptance gate)."""
    rep = lint.lint_paths()
    assert rep.ok, rep.render()


# ---------------------------------------------------------------------------
# 8-device scenarios (subprocess): real engine clean grid, compiled mutations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["verifier_clean", "verifier_mutations"])
def test_scenario(name):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "_analysis_scenarios.py"), name],
        capture_output=True, text=True, timeout=900, env=env)
    tail = (r.stdout + r.stderr)[-4000:]
    assert r.returncode == 0, f"scenario {name} failed:\n{tail}"
    assert f"SCENARIO_OK {name}" in r.stdout, tail
