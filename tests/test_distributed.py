"""Distributed-semantics tests: each case runs tests/_scenarios.py in a
subprocess with 8 fake CPU devices (XLA_FLAGS must be set before jax import,
and the main pytest process keeps the real single-device view)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SCENARIOS = ["collectives", "reshard_roundtrip",
             "schemes_equivalent", "auto_scheme",
             "kernel_impl_equivalence", "attn_scan_impl_equivalence",
             "stream_grads_equivalence",
             "dp_vs_single", "serve_sharded",
             "hlo_census_real", "multipod_mesh", "resident_and_sp",
             "serve_resident_quant_equivalence",
             "obs_trace_equivalence"]


@pytest.mark.parametrize("name", SCENARIOS)
def test_scenario(name):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "_scenarios.py"), name],
        capture_output=True, text=True, timeout=900, env=env)
    tail = (r.stdout + r.stderr)[-4000:]
    assert r.returncode == 0, f"scenario {name} failed:\n{tail}"
    assert f"SCENARIO_OK {name}" in r.stdout, tail
