"""docs/CLI.md is generated from the entry-point parsers
(``repro.launch.cli_reference``); any flag change must ship with a
regenerated file or this test fails."""
from pathlib import Path

from repro.launch import cli_reference

DOC = Path(__file__).resolve().parents[1] / "docs" / "CLI.md"


def test_cli_reference_up_to_date():
    assert DOC.exists(), \
        "docs/CLI.md missing — PYTHONPATH=src python -m " \
        "repro.launch.cli_reference --write"
    assert DOC.read_text() == cli_reference.generate(), \
        "docs/CLI.md is stale (a build_parser() changed): regenerate with " \
        "PYTHONPATH=src python -m repro.launch.cli_reference --write"


def test_reference_covers_every_tool_and_the_elastic_flags():
    text = cli_reference.generate()
    for mod in cli_reference.TOOLS:
        assert f"## `python -m {mod}`" in text, mod
    # the flags this PR's docs lean on must actually be documented
    for flag in ("`--resume`", "`--strict-restore`", "`--replan-from`",
                 "`--ckpt-dir`", "`--grid`", "`--out-topology`"):
        assert flag in text, flag


def test_parsers_import_side_effect_free(monkeypatch):
    """Rendering must not mutate the process (the generator and this test
    import every tool module): XLA_FLAGS stays whatever it was."""
    import os
    before = os.environ.get("XLA_FLAGS")
    cli_reference.generate()
    assert os.environ.get("XLA_FLAGS") == before
