"""Beyond-paper serving paths on a degree-1 mesh: the INT8 wire residency
(and its dense fallback) and sequence-parallel prefill must reproduce the
ZeRO-serving results BITWISE (full 8-device checks live in
test_distributed.py / _scenarios.py::serve_resident_quant_equivalence)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.engine import TrainHparams, ZeroEngine
from repro.core.partition import resident_memory_bytes
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.config import ShapeConfig
from repro.models.registry import build_model, get_arch
from repro.serve.engine import ServeEngine
from repro.serve.resident import (WIRE, ResidentServeEngine, build_resident)

AX = ("data", "node", "gcd")


def _setup(name, quantized=True):
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    if not quantized:
        # dense-fallback residency: every leaf is materialized through the
        # training gather and kept replicated in compute dtype
        cfg = dataclasses.replace(
            cfg, quantize_weights=False, quantize_grads=False,
            axes=dataclasses.replace(cfg.axes, secondary=None))
        cfg.validate_dependency_rule()
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    return mesh, arch, model, eng, state


@pytest.mark.parametrize("quantized", [True, False],
                         ids=["int8-wire", "dense-fallback"])
@pytest.mark.parametrize("name", ["qwen2-0.5b", "mixtral-8x7b",
                                  "minicpm3-4b", "falcon-mamba-7b"])
def test_resident_matches_zero_serving(name, quantized):
    """Prefill + teacher-forced decode logits are BITWISE identical: the
    residency stores the training gather's own output (wire or dense), and
    the matmul epilogues are shared code. Exception: the mamba DECODE —
    the resident weights are still bitwise (asserted via prefill) but the
    SSM decode step's fp32 op order shifts with XLA's fusion of the
    differently-materialized weight producers, so it lands within fp32
    noise (~1e-6) instead of exactly."""
    mesh, arch, model, eng, state = _setup(name, quantized)
    rng = np.random.default_rng(0)
    b = 2
    batch = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (b, 16)),
                                   jnp.int32)}
    shape = ShapeConfig("t", 16, b, "decode")
    se = ServeEngine(model, eng, mesh, shape)
    layout, resident = build_resident(eng, state, mesh)
    assert any(layout.mode(n) == WIRE for n in eng.specs) == quantized
    rse = ResidentServeEngine(model, eng, mesh, shape,
                              res_axes=layout.res_axes)

    l0, c0 = se.make_prefill()(state["primaries"], batch)
    l1, c1 = rse.make_prefill()(resident, batch)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    forced = rng.integers(0, arch.vocab, (3, b)).astype(np.int32)
    d0 = se.make_decode()
    d1 = rse.make_decode()
    mamba = name == "falcon-mamba-7b"
    for t in forced:
        l0, c0 = d0(state["primaries"], c0, {"token": jnp.asarray(t)})
        l1, c1 = d1(resident, c1, {"token": jnp.asarray(t)})
        if mamba:
            np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                       rtol=2e-6, atol=2e-6)
        else:
            np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_resident_memory_budget():
    """The wire residency's byte count matches the partition formula
    psi/|R| + 4*psi/(block*|R|) and the stored arrays match the report."""
    mesh, arch, model, eng, state = _setup("qwen2-0.5b")
    layout, resident = build_resident(eng, state, mesh)
    rep = layout.memory_report()
    psi = sum(s.logical_size * (s.stack or 1)
              for n, s in eng.specs.items() if layout.mode(n) == WIRE)
    assert rep["formula_bytes"] == resident_memory_bytes(
        eng.cfg, psi, res_degree=layout.res_degree)
    assert rep["wire_bytes"] == rep["formula_bytes"]
    stored = 0
    for name in eng.specs:
        if layout.mode(name) == WIRE:
            e = resident[name]
            stored += e["q"].size * e["q"].dtype.itemsize
            stored += e["s"].size * e["s"].dtype.itemsize
    assert stored == rep["wire_bytes"] * layout.res_degree
    # INT8 + fp32 block scales: ~psi*(1+4/block) bytes, well under bf16
    assert rep["wire_bytes"] <= psi * (1 + 4 / 64) + 4096


def test_sp_prefill_single_device_noop():
    """seq_parallel on a degree-1 mesh must be a no-op (falls back)."""
    mesh, arch, model, eng, state = _setup("deepseek-7b")
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (2, 32)),
                                   jnp.int32)}
    shape = ShapeConfig("t", 32, 2, "prefill")
    se = ServeEngine(model, eng, mesh, shape)
    l0, c0 = se.make_prefill(seq_parallel=False)(state["primaries"], batch)
    l1, c1 = se.make_prefill(seq_parallel=True)(state["primaries"], batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-6)


def test_sp_eligibility():
    assert build_model(get_arch("minicpm3-4b")).lm.sp_eligible()
    assert build_model(get_arch("gemma3-1b")).lm.sp_eligible()
    assert not build_model(get_arch("falcon-mamba-7b")).lm.sp_eligible()
    assert not build_model(get_arch("jamba-v0.1-52b")).lm.sp_eligible()
