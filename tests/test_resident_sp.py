"""Beyond-paper serving paths on a degree-1 mesh: resident tensor-parallel
weights and sequence-parallel prefill must reproduce the ZeRO-serving
results exactly (full 8-device checks live in test_distributed.py)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.engine import TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.config import ShapeConfig
from repro.models.registry import build_model, get_arch
from repro.serve.engine import ServeEngine
from repro.serve.resident import ResidentServeEngine, build_resident

AX = ("data", "node", "gcd")


def _setup(name):
    import dataclasses
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    # compare exact-vs-exact: the ZeRO path would otherwise differ by its
    # INT8 weight-gather quantization, not by the resident layout
    cfg = dataclasses.replace(cfg, quantize_weights=False,
                              quantize_grads=False)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    return mesh, arch, model, eng, state


@pytest.mark.parametrize("name", ["qwen2-0.5b", "mixtral-8x7b",
                                  "minicpm3-4b", "falcon-mamba-7b"])
def test_resident_matches_zero_serving(name):
    """Prefill + teacher-forced decode logits agree (token-level argmax can
    flip on near-ties at random init, so compare the distributions)."""
    mesh, arch, model, eng, state = _setup(name)
    rng = np.random.default_rng(0)
    b = 2
    batch = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (b, 16)),
                                   jnp.int32)}
    shape = ShapeConfig("t", 16, b, "decode")
    se = ServeEngine(model, eng, mesh, shape)
    layout, resident = build_resident(eng, state, mesh, ("node", "gcd"),
                                      dtype=jnp.float32)
    rse = ResidentServeEngine(model, eng, mesh, shape)

    l0, c0 = se.make_prefill()(state["primaries"], batch)
    l1, c1 = rse.make_prefill()(resident, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)
    forced = rng.integers(0, arch.vocab, (3, b)).astype(np.int32)
    d0 = se.make_decode()
    d1 = rse.make_decode()
    for t in forced:
        l0, c0 = d0(state["primaries"], c0, {"token": jnp.asarray(t)})
        l1, c1 = d1(resident, c1, {"token": jnp.asarray(t)})
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=1e-4, atol=1e-4)


def test_resident_memory_budget():
    """Resident layout must hold 2*psi/TP bytes of matmul weights/device."""
    mesh, arch, model, eng, state = _setup("qwen2-0.5b")
    layout, resident = build_resident(eng, state, mesh, ("node", "gcd"))
    total = sum(np.prod(v.shape) * v.dtype.itemsize
                for v in jax.tree.leaves(resident))
    # degree-1 mesh: resident ~= full bf16 model + replicated fp32 smalls
    assert total < 2.6 * eng.param_count()


def test_sp_prefill_single_device_noop():
    """seq_parallel on a degree-1 mesh must be a no-op (falls back)."""
    mesh, arch, model, eng, state = _setup("deepseek-7b")
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (2, 32)),
                                   jnp.int32)}
    shape = ShapeConfig("t", 32, 2, "prefill")
    se = ServeEngine(model, eng, mesh, shape)
    l0, c0 = se.make_prefill(seq_parallel=False)(state["primaries"], batch)
    l1, c1 = se.make_prefill(seq_parallel=True)(state["primaries"], batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-6)


def test_sp_eligibility():
    assert build_model(get_arch("minicpm3-4b")).lm.sp_eligible()
    assert build_model(get_arch("gemma3-1b")).lm.sp_eligible()
    assert not build_model(get_arch("falcon-mamba-7b")).lm.sp_eligible()
    assert not build_model(get_arch("jamba-v0.1-52b")).lm.sp_eligible()
