"""Cross-process execution tests: the inter tier as a REAL process boundary.

Each test launches tests/_mp.py worker clusters — 2 processes x 4 fake CPU
devices forming the same (2, 2, 2) topo mesh as the in-process scenarios —
and diffs their MP_RESULT json against the single-process 8-device run of
the identical scenario. This is the CI `multiprocess` leg (and part of
tier-1): the engine's collectives, data sharding, metric aggregation and
checkpointing all cross a jax.distributed boundary here, not a fake one.
"""
import pytest

from _mp import run_cluster


@pytest.mark.parametrize("kernel_impl,stream", [
    ("jnp", False), ("pallas_interpret", False),
    ("jnp", True), ("pallas_interpret", True)])
def test_train_step_parity(kernel_impl, stream):
    """A 2-process x 4-device train step reproduces the single-process
    8-device step BITWISE: losses, grad norms, every per-leaf master and
    primary update, and the compiled collective census (counts + wire
    bytes). The partitioned program is identical — only the transport under
    the inter-tier collectives changes — so any drift here is a real
    cross-process bug, not noise. ``stream`` repeats the proof for the
    streaming grad path (DESIGN.md §8), whose per-layer reduce chain runs
    its stage-2/cross-replica collectives across the process boundary
    inside the backward scan."""
    extra = {"impl": kernel_impl, "stream": stream}
    mp = run_cluster("train_step_parity", n_proc=2, extra=extra)
    sp = run_cluster("train_step_parity", n_proc=1, extra=extra)
    assert mp["losses"] == sp["losses"], (mp["losses"], sp["losses"])
    assert mp["gnorms"] == sp["gnorms"], (mp["gnorms"], sp["gnorms"])
    for name in sp["masters"]:
        assert mp["masters"][name] == sp["masters"][name], name
        assert mp["prims"][name] == sp["prims"][name], name
    assert mp["census"] == sp["census"], (mp["census"], sp["census"])


def test_checkpoint_roundtrip_multiprocess(tmp_path):
    """Per-process checkpoint save/restore on a live 2-process cluster:
    lossless, meta records the per_process format + mesh layout, and
    training continues bitwise-identically from the restored state."""
    out = run_cluster("checkpoint_roundtrip", n_proc=2,
                      extra={"ckpt_dir": str(tmp_path)})
    assert out["format"] == "per_process"
    assert out["mesh"]["process_count"] == 2
    assert out["mesh"]["local_devices"] == 4


def test_checkpoint_process_count_guard(tmp_path):
    """A checkpoint written by a 2-process cluster refuses to restore
    single-process (and vice versa) with MeshMismatch, not an opaque
    reshape error."""
    run_cluster("checkpoint_roundtrip", n_proc=2,
                extra={"ckpt_dir": str(tmp_path)})
    out = run_cluster("checkpoint_wrong_layout", n_proc=1,
                      extra={"ckpt_dir": str(tmp_path)})
    assert out["raised"] is True


@pytest.fixture(scope="module")
def elastic_ckpt(tmp_path_factory):
    """Shared first half of the elastic legs: the uninterrupted 2x4
    reference run, and a 2x4 run killed after step 2 leaving a per-process
    checkpoint behind."""
    d = tmp_path_factory.mktemp("elastic")
    save = run_cluster("elastic_save", n_proc=2, extra={"ckpt_dir": str(d)})
    ref = run_cluster("elastic_reference", n_proc=2)
    return str(d), save, ref


@pytest.mark.parametrize("n_proc", [1, 4])
def test_elastic_kill_and_resume(elastic_ckpt, n_proc):
    """Kill-at-step-k / resume-on-a-different-mesh continues BITWISE: a 2x4
    cluster trains 2 steps and dies leaving a per-process checkpoint; a 1x8
    (and a 4x2) cluster reshards it through the partition formulas
    (restore(reshard=True), DESIGN.md §11) and trains the remaining steps.
    Concatenated losses and every final per-leaf sha256 must equal the
    uninterrupted same-seed 2x4 run exactly — float32 is the bitwise
    cross-layout regime (DESIGN.md §6)."""
    d, save, ref = elastic_ckpt
    out = run_cluster("elastic_resume", n_proc=n_proc,
                      extra={"ckpt_dir": d})
    assert out["saved_procs"] == 2
    assert save["losses"] + out["losses"] == ref["losses"], \
        (save["losses"], out["losses"], ref["losses"])
    assert out["hashes"] == ref["hashes"]


def test_elastic_strict_mode_still_raises(elastic_ckpt):
    """reshard=False keeps the pre-elastic contract: a cross-layout restore
    raises MeshMismatch (now naming the reshard=True escape hatch)."""
    d, _, _ = elastic_ckpt
    out = run_cluster("elastic_strict", n_proc=1, extra={"ckpt_dir": d})
    assert out["raised"] is True


def test_topology_from_process_spanning_mesh():
    """Topology.from_mesh on a real 2-process mesh pins the process-boundary
    axis to the inter tier and prices it at the inter link; zero_tiers
    rejects meshes whose process boundary cuts an intra axis; the planner
    runs on the resulting topology."""
    out = run_cluster("topology_tiers", n_proc=2)
    assert out["spanning"] == ["data"]
    assert out["tier"] == "inter"


def test_heartbeat_straggler(tmp_path):
    """Rank heartbeats on a live 2-process cluster: the deliberately
    delayed rank (stops stamping at step 2 while rank 0 advances to 5) is
    NAMED by the straggler report — 'behind' under a generous stall window,
    'stalled' once its stamp ages past the window — and an expected rank
    that never stamped reads 'dead'. This is the trace-mode answer to 'one
    rank hangs the cluster and nothing says which'."""
    out = run_cluster("heartbeat_straggler", n_proc=2,
                      extra={"hb_dir": str(tmp_path), "delay_rank": 1})
    assert out["behind"] == {"0": "ok", "1": "behind"}, out
    assert out["stalled"]["1"] == "stalled", out
    assert out["dead"]["2"] == "dead", out
    assert out["max_step"] == 5
    assert "rank 1: behind" in out["report"], out["report"]
