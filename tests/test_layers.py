"""Model-layer correctness: flash attention vs naive softmax reference,
RoPE properties, chunked CE vs direct, Mamba chunked scan vs sequential,
MoE dispatch invariants."""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")  # optional [test] extra; degrade to skip, not collection error
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def naive_attention(q, k, v, causal=True, window=0):
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    dv = v.shape[-1]
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 1), (8, 2)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_matches_naive(h, hkv, causal, window):
    b, s, d = 2, 64, 16
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, d))
    out = L.flash_attention(q, k, v, causal=causal, window=window,
                            q_chunk=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_mla_value_dim():
    b, s, h, dq, dv = 1, 32, 4, 24, 16
    q = jax.random.normal(jax.random.key(0), (b, s, h, dq))
    k = jax.random.normal(jax.random.key(1), (b, s, h, dq))
    v = jax.random.normal(jax.random.key(2), (b, s, h, dv))
    out = L.flash_attention(q, k, v, q_chunk=8, kv_chunk=8)
    assert out.shape == (b, s, h, dv)
    ref = naive_attention(q, k, v)[..., :dv]
    # recompute naive with proper scale over dq
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dq)
    mask = jnp.tril(jnp.ones((s, s), bool))
    p = jax.nn.softmax(jnp.where(mask, s_, -1e30), axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_matches_full_attention():
    """flash_decode at position p == last row of full causal attention."""
    b, s, h, hkv, d = 2, 40, 8, 2, 16
    q = jax.random.normal(jax.random.key(0), (b, 1, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, d))
    pos = 29                    # only first 30 cache rows valid
    out = L.flash_decode(q[:, 0], k, v, jnp.asarray(pos))
    ref = naive_attention(q, k[:, :pos + 1], v[:, :pos + 1],
                          causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ring_decode_matches_window_attention():
    b, h, hkv, d, w = 1, 4, 1, 8, 16
    total = 37
    k = jax.random.normal(jax.random.key(1), (b, total, hkv, d))
    v = jax.random.normal(jax.random.key(2), (b, total, hkv, d))
    q = jax.random.normal(jax.random.key(0), (b, 1, h, d))
    pos = total - 1
    ring_k = jnp.zeros((b, w, hkv, d))
    ring_v = jnp.zeros((b, w, hkv, d))
    for p in range(total):
        ring_k = ring_k.at[:, p % w].set(k[:, p])
        ring_v = ring_v.at[:, p % w].set(v[:, p])
    out = L.ring_decode(q[:, 0], ring_k, ring_v, jnp.asarray(pos), w)
    ref = naive_attention(q, k[:, pos - w + 1: pos + 1],
                          v[:, pos - w + 1: pos + 1], causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm_and_relative_positions():
    d = 32
    x = jax.random.normal(jax.random.key(0), (1, 8, 2, d))
    cos, sin = L.rope_freqs(jnp.arange(8), d, 10_000.0)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, d))
    def dot_at(pq, pk):
        cq, sq_ = L.rope_freqs(jnp.asarray([pq]), d, 10_000.0)
        ck, sk = L.rope_freqs(jnp.asarray([pk]), d, 10_000.0)
        qq = L.apply_rope(q, cq, sq_)
        kk = L.apply_rope(k, ck, sk)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(3, 1) - dot_at(5, 1)) > 1e-4  # but not absolute


@pytest.mark.parametrize("chunk", [8, 64])
def test_chunked_ce_matches_direct(chunk):
    b, s, d, vocab = 2, 64, 16, 97
    x = jax.random.normal(jax.random.key(0), (b, s, d))
    w = jax.random.normal(jax.random.key(1), (vocab, d)) * 0.1
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, vocab)
    mask = (jax.random.uniform(jax.random.key(3), (b, s)) > 0.2)\
        .astype(jnp.float32)
    loss, n = L.chunked_cross_entropy(x, w, labels, mask, chunk=chunk)
    logits = x @ w.T
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = jnp.sum((lse - gold) * mask)
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)
    assert float(n) == float(mask.sum())
    # gradients agree too
    g1 = jax.grad(lambda xx: L.chunked_cross_entropy(
        xx, w, labels, mask, chunk=chunk)[0])(x)
    g2 = jax.grad(lambda xx: jnp.sum(
        (jax.nn.logsumexp(xx @ w.T, -1)
         - jnp.take_along_axis(xx @ w.T, labels[..., None], -1)[..., 0])
        * mask))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)


def test_mamba_chunked_scan_matches_sequential():
    """Chunked associative scan == naive per-step recurrence."""
    from repro.models.ssm import _inner_scan
    b, q, din, n = 2, 16, 8, 4
    da = jax.random.uniform(jax.random.key(0), (b, q, din, n),
                            minval=0.5, maxval=0.99)
    dbx = jax.random.normal(jax.random.key(1), (b, q, din, n)) * 0.1
    h0 = jax.random.normal(jax.random.key(2), (b, din, n))
    h_all, h_last = _inner_scan(da, dbx, h0)
    h = h0
    for t in range(q):
        h = da[:, t] * h + dbx[:, t]
        np.testing.assert_allclose(np.asarray(h_all[:, t]), np.asarray(h),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6), st.integers(1, 2))
def test_prop_moe_dispatch_invariants(seed, n_exp, top_k):
    from repro.models.moe import _dispatch_combine
    t, cap = 32, 8
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.key(seed), (t, n_exp)), axis=-1)
    disp, comb, aux = _dispatch_combine(gates, top_k, cap)
    d = np.asarray(disp, np.float32)
    c = np.asarray(comb)
    # every token goes to <= top_k slots; capacity respected exactly
    assert d.sum() <= t * top_k + 1e-5
    assert (d.sum(axis=(0,)) <= cap + 1e-5).all()   # per (expert, slot) <= 1
    assert (d.sum(axis=0) <= 1 + 1e-5).all()
    # combine weights are a convex-ish combination (sum <= 1 per token)
    assert (c.sum(axis=(1, 2)) <= 1 + 1e-5).all()
    assert 0.5 < float(aux) < n_exp + 1e-5           # E*sum(f*p) ~ 1 near balance
