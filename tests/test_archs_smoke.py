"""Per-architecture smoke tests (deliverable (f)): a REDUCED variant of each
assigned architecture runs one train step + one prefill/decode round on CPU,
asserting output shapes and finiteness. The FULL configs are exercised only
via the dry-run."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.config import SHAPES, ShapeConfig, shape_supported
from repro.models.registry import build_model, get_arch, list_archs
from repro.serve.engine import ServeEngine

AX = ("data", "node", "gcd")
ASSIGNED = [a for a in list_archs() if not a.startswith("gpt-neox")]


def _mesh():
    return make_test_mesh(shape=(1, 1, 1), axes=AX)


def _batch(arch, b, s_total, seed=0):
    rng = np.random.default_rng(seed)
    st = s_total - arch.n_patches if arch.n_patches else s_total
    out = {"tokens": jnp.asarray(rng.integers(0, arch.vocab, (b, st + 1)),
                                 jnp.int32)}
    if arch.n_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal((b, arch.n_patches, arch.d_model)) * 0.02,
            jnp.bfloat16)
    if arch.enc_layers:
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, arch.n_frames, arch.d_model)) * 0.02,
            jnp.bfloat16)
    return out


@pytest.mark.parametrize("name", list_archs())
def test_reduced_config_constraints(name):
    arch = get_arch(name).reduced()
    assert arch.n_layers <= 4 and arch.d_model <= 512
    assert not arch.moe.n_experts or arch.moe.n_experts <= 4
    # reduced keeps every block kind of the full pattern
    assert set(arch.pattern) == set(get_arch(name).pattern)


@pytest.mark.parametrize("name", list_archs())
def test_train_step_smoke(name):
    mesh = _mesh()
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(total_steps=5, warmup_steps=0))
    state = eng.init_state(jax.random.key(0))
    batch = _batch(arch, 2, 32)
    bspecs = {k: P() for k in batch}
    step = eng.make_train_step(model.loss_fn(), bspecs)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for n, spec in eng.specs.items():
        p = new_state["primaries"][n]
        assert p.shape == state["primaries"][n].shape  # wait: donated
        assert np.isfinite(np.asarray(p, np.float32)).all(), n


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_smoke(name):
    mesh = _mesh()
    arch = get_arch(name).reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    b, s = 2, 32
    shape = ShapeConfig("t", s, b, "decode")
    se = ServeEngine(model, eng, mesh, shape)
    batch = _batch(arch, b, s)
    batch["tokens"] = batch["tokens"][:, :-1]
    toks = se.generate(state, batch, 3)
    assert toks.shape == (b, 3)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < arch.vocab).all()


def test_all_assigned_shapes_covered():
    """Every assigned arch supports every shape except documented
    long-context skips."""
    from repro.models.config import LONG_CONTEXT_OK
    count = 0
    for name in ASSIGNED:
        arch = get_arch(name)
        for sname, sh in SHAPES.items():
            if shape_supported(arch, sh):
                count += 1
            else:
                assert sname == "long_500k" and name not in LONG_CONTEXT_OK
    assert count == 10 * 4 - 6       # 34 runnable combos + 6 documented skips
    assert len(ASSIGNED) == 10
