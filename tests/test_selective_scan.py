"""Selective scan under the kernels/ops dispatch: the jnp oracle and the
interpret-mode Pallas kernel agree BITWISE through forward and backward
(DESIGN.md §5), and — because blocking along B/D/S never reorders the
per-element recurrence — ANY bb/bd/bs kernel blocking reproduces the oracle
exactly, not just to tolerance.

hypothesis is an optional [test] extra: the property tests degrade to a
skip when it is missing (same guard as tests/test_kernels.py).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.selective_scan import selective_scan_pallas


def _inputs(b, s, d, n, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(seed), 6)
    dt = jax.random.uniform(ks[0], (b, s, d), minval=0.01, maxval=0.2)
    x = jax.random.normal(ks[1], (b, s, d))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    h0 = jax.random.normal(ks[5], (b, d, n)) * 0.1
    return (dt.astype(dtype), x.astype(dtype), bm.astype(dtype),
            cm.astype(dtype), a, h0)


def _grads(args, impl):
    """Fresh jit per impl (dispatch is baked in at trace time)."""
    def loss(dt, x, bm, cm, a, h0):
        y, hl = ops.selective_scan(dt, x, bm, cm, a, h0, impl=impl)
        return jnp.sum(y * y) + jnp.sum(hl * hl)
    return jax.jit(jax.value_and_grad(loss, argnums=tuple(range(6))))(*args)


@pytest.mark.parametrize("b,s,d,n", [(1, 32, 8, 4), (2, 64, 16, 4),
                                     (2, 33, 16, 8)])
def test_ops_scan_jnp_vs_interpret_bitwise(b, s, d, n):
    args = _inputs(b, s, d, n)
    lj, gj = _grads(args, "jnp")
    li, gi = _grads(args, "pallas_interpret")
    assert np.asarray(lj).tobytes() == np.asarray(li).tobytes()
    for a, bb in zip(jax.tree.leaves(gj), jax.tree.leaves(gi)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


@pytest.mark.parametrize("bb,bd,bs", [(2, 64, 32), (1, 64, 16), (2, 32, 8),
                                      (1, 16, 32), (2, 8, 64)])
def test_kernel_blocking_invariance_exact(bb, bd, bs):
    """Non-default bb/bd/bs blockings are EXACTLY equal to the oracle: the
    recurrence is sequential in time and elementwise in B/D, so tiling can
    never reorder the arithmetic."""
    args = _inputs(2, 64, 64, 16, seed=1)
    yr, hr = jax.jit(lambda a: ref.selective_scan_ref(*a))(args)
    yk, hk = jax.jit(lambda a: selective_scan_pallas(
        *a, bb=bb, bd=bd, bs=bs, interpret=True))(args)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yk))
    np.testing.assert_array_equal(np.asarray(hr), np.asarray(hk))


@pytest.mark.parametrize("bs", [8, 16, 64])
def test_ref_time_blocking_invariance_exact(bs):
    args = _inputs(1, 64, 16, 4, seed=2)
    y0, h0 = jax.jit(lambda a: ref.selective_scan_ref(*a, bs=256))(args)
    y1, h1 = jax.jit(lambda a: ref.selective_scan_ref(*a, bs=bs))(args)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes_bitwise(dtype):
    """bf16 inputs: both impls cast per-step inside the loop body, so the
    pair stays bitwise (state/output are f32 in both)."""
    args = _inputs(1, 32, 8, 4, seed=3, dtype=dtype)
    yr, hr = jax.jit(lambda a: ref.selective_scan_ref(*a))(args)
    yk, hk = jax.jit(lambda a: selective_scan_pallas(
        *a, bb=1, bd=8, bs=32, interpret=True))(args)
    assert yr.dtype == yk.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yk))
    np.testing.assert_array_equal(np.asarray(hr), np.asarray(hk))


def test_dispatch_counters_record_scan():
    ops.reset_dispatch_counters()
    args = _inputs(1, 16, 8, 4, seed=4)
    for impl in ("jnp", "pallas_interpret"):
        jax.jit(lambda a, _i=impl: ops.selective_scan(*a, impl=_i))(args)
    counts = ops.dispatch_counters()
    assert counts.get("selective_scan/jnp", 0) >= 1, counts
    assert counts.get("selective_scan/pallas_interpret", 0) >= 1, counts


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from([(1, 8), (2, 16), (4, 8)]))
    def test_prop_nondefault_blocking_bitwise(seed, bd_bs):
        """Random inputs, non-default blockings: still exactly the oracle."""
        bd, bs = bd_bs
        args = _inputs(2, 32, 16, 4, seed=seed % 1000)
        yr, hr = jax.jit(lambda a: ref.selective_scan_ref(*a))(args)
        yk, hk = jax.jit(lambda a: selective_scan_pallas(
            *a, bb=1, bd=bd, bs=bs, interpret=True))(args)
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yk))
        np.testing.assert_array_equal(np.asarray(hr), np.asarray(hk))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_prop_state_bounded(seed):
        """With a < 0 and bounded inputs, the state stays bounded."""
        dt, x, bm, cm, a, h0 = _inputs(1, 64, 8, 4, seed=seed % 1000)
        y, hl = ops.selective_scan(dt, x, bm, cm, a, h0,
                                   impl="pallas_interpret")
        assert np.isfinite(np.asarray(y)).all()
        da_max = float(jnp.max(jnp.exp(dt[..., None] * a)))
        assert da_max <= 1.0 + 1e-6
        bound = float(jnp.max(jnp.abs(h0))) + 64 * float(
            jnp.max(jnp.abs((dt * x)[..., None] * bm[:, :, None, :])))
        assert float(jnp.max(jnp.abs(hl))) <= bound + 1e-4
else:
    def test_prop_hypothesis_missing():
        pytest.skip("hypothesis not installed (optional [test] extra); "
                    "property tests skipped")
