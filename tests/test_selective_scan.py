"""Pallas selective-scan kernel vs the jnp associative-scan oracle:
shape sweeps + property tests (decay bounds)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")  # optional [test] extra; degrade to skip, not collection error
from hypothesis import given, settings, strategies as st

from repro.kernels.selective_scan import selective_scan_pallas
from repro.models.ssm import _inner_scan


def _ref(dt, x, bm, cm, a, h0):
    da = jnp.exp(dt[..., None] * a)
    dbx = (dt * x)[..., None] * bm[:, :, None, :]
    h_all, h_last = _inner_scan(da, dbx, h0)
    return jnp.einsum("bsdn,bsn->bsd", h_all, cm), h_last


def _inputs(b, s, d, n, seed=0):
    ks = jax.random.split(jax.random.key(seed), 6)
    dt = jax.random.uniform(ks[0], (b, s, d), minval=0.01, maxval=0.2)
    x = jax.random.normal(ks[1], (b, s, d))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    h0 = jax.random.normal(ks[5], (b, d, n)) * 0.1
    return dt, x, bm, cm, a, h0


@pytest.mark.parametrize("b,s,d,n,bd,bs", [
    (1, 32, 8, 4, 8, 8), (2, 64, 16, 4, 8, 16), (2, 128, 16, 16, 16, 32),
    (1, 64, 32, 8, 32, 64),
])
def test_matches_reference(b, s, d, n, bd, bs):
    args = _inputs(b, s, d, n)
    y, hl = selective_scan_pallas(*args, bd=bd, bs=bs, interpret=True)
    y_ref, h_ref = _ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    dt, x, bm, cm, a, h0 = _inputs(1, 32, 8, 4, seed=1)
    y, hl = selective_scan_pallas(dt.astype(dtype), x.astype(dtype),
                                  bm.astype(dtype), cm.astype(dtype),
                                  a, h0, bd=8, bs=8, interpret=True)
    y_ref, _ = _ref(dt.astype(dtype).astype(jnp.float32),
                    x.astype(dtype).astype(jnp.float32),
                    bm.astype(dtype).astype(jnp.float32),
                    cm.astype(dtype).astype(jnp.float32), a, h0)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_prop_state_bounded(seed):
    """With a < 0 and bounded inputs, the state stays bounded (stability)."""
    dt, x, bm, cm, a, h0 = _inputs(1, 64, 8, 4, seed=seed % 1000)
    y, hl = selective_scan_pallas(dt, x, bm, cm, a, h0, bd=8, bs=16,
                                  interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    # |h| <= |h0| * prod(decay) + sum |dbx| and decay < 1
    da_max = float(jnp.max(jnp.exp(dt[..., None] * a)))
    assert da_max <= 1.0 + 1e-6
    bound = float(jnp.max(jnp.abs(h0))) + 64 * float(
        jnp.max(jnp.abs((dt * x)[..., None] * bm[:, :, None, :])))
    assert float(jnp.max(jnp.abs(hl))) <= bound + 1e-4
