"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
shape/dtype sweeps + hypothesis property tests (deliverable (c)).

hypothesis is an optional [test] extra: only the property tests at the
bottom require it (they skip when it is missing); the deterministic kernel
tests always run."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.quant_blockwise import (dequantize_int8_pallas,
                                           quantize_int8_pallas)
from repro.kernels.quant_int4 import (dequantize_int4_pallas,
                                      quantize_int4_pallas)
from repro.kernels.dequant_matmul import dequant_matmul_pallas

SHAPES = [(8, 128), (8, 256), (16, 128), (32, 512), (64, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.key(seed), shape, jnp.float32) * 3.0
    return x.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_int8_pallas_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    q_p, s_p = quantize_int8_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_int8_ref(x)
    # interpret-mode fma ordering can flip round-to-nearest ties by 1 LSB
    # for half dtypes; f32 must match exactly
    diff = np.abs(np.asarray(q_p, np.int32) - np.asarray(q_r, np.int32))
    assert diff.max() <= (0 if dtype == jnp.float32 else 1)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)
    d_p = dequantize_int8_pallas(q_p, s_p, jnp.float32, interpret=True)
    d_r = ref.dequantize_int8_ref(q_r, s_r, jnp.float32)
    tol = 0.0 if dtype == jnp.float32 else float(np.asarray(s_r).max())
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r), rtol=1e-6,
                               atol=tol + 1e-7)


@pytest.mark.parametrize("shape", [(8, 256), (16, 512), (32, 1024)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_int4_pallas_matches_ref(shape, dtype):
    x = _rand(shape, dtype, seed=1)
    q_p, s_p = quantize_int4_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_int4_ref(x)
    lo_p, hi_p = np.asarray(q_p, np.int32) & 0xF, np.asarray(q_p, np.int32) >> 4
    lo_r, hi_r = np.asarray(q_r, np.int32) & 0xF, np.asarray(q_r, np.int32) >> 4
    tol = 0 if dtype == jnp.float32 else 1
    assert np.abs(lo_p - lo_r).max() <= tol
    assert np.abs(hi_p - hi_r).max() <= tol
    d_p = dequantize_int4_pallas(q_p, s_p, jnp.float32, interpret=True)
    d_r = ref.dequantize_int4_ref(q_r, s_r, jnp.float32)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r),
                               atol=float(np.asarray(s_r).max()) * (tol + 1e-6))


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 128, 256),
                                 (128, 256, 384)])
def test_dequant_matmul_pallas(mkn):
    m, k, n = mkn
    x = _rand((m, k), jnp.float32, 2)
    w = _rand((k, n), jnp.float32, 3)
    # block-quantize w along K in bk=128 blocks, per column
    wb = np.asarray(w).reshape(k // 128, 128, n)
    absmax = np.abs(wb).max(axis=1)
    scales = np.where(absmax == 0, 1.0, absmax / 127.0).astype(np.float32)
    q = np.clip(np.round(wb / scales[:, None, :]), -127, 127).astype(np.int8)
    q = q.reshape(k, n)
    out = dequant_matmul_pallas(x, jnp.asarray(q), jnp.asarray(scales),
                                interpret=True)
    expect = ref.dequant_matmul_ref(x, jnp.asarray(q), jnp.asarray(scales))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=5e-4)


@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("knb", [(128, 384, 64), (256, 256, 128),
                                 (128, 512, 512)])
def test_dequant_matmul_flat_matches_ref_and_unfused(knb, transpose):
    """Flat-shard scale layout: the fused kernel (interpret) == the blocked
    ref == the unfused dequant->matmul, both orientations."""
    k, n, block = knb
    m = 66   # deliberately not a sublane multiple: exercises the M padding
    w = _rand((k * n,), jnp.float32, 4)
    q, s = ops.quantize_int8(w, block)
    x = _rand((m, n if transpose else k), jnp.float32, 5)
    y_j = ops.dequant_matmul(x, q, s, (k, n), block, transpose=transpose,
                             dtype=jnp.float32, impl="jnp")
    y_p = ops.dequant_matmul(x, q, s, (k, n), block, transpose=transpose,
                             dtype=jnp.float32, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(y_j), np.asarray(y_p))
    wd = ops.dequantize_int8(q, s, block, jnp.float32).reshape(k, n)
    y_u = x @ (wd.T if transpose else wd)
    # approximate: the unfused dot's reduction order depends on XLA's CPU
    # partitioning (it shifts under --xla_force_host_platform_device_count)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_u),
                               rtol=1e-4, atol=5e-4)


def test_dequant_matmul_flat_bf16_out():
    k, n, block = 128, 256, 64
    q, s = ops.quantize_int8(_rand((k * n,), jnp.float32, 6), block)
    x = _rand((8, k), jnp.float32, 7)
    y = ops.dequant_matmul(x, q, s, (k, n), block, dtype=jnp.bfloat16,
                           impl="pallas_interpret")
    assert y.dtype == jnp.bfloat16 and y.shape == (8, n)
    y32 = ops.dequant_matmul(x, q, s, (k, n), block, dtype=jnp.float32,
                             impl="jnp")
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(y32.astype(jnp.bfloat16)))


def test_matmul_fusable_gate():
    assert ops.matmul_fusable((128, 384), 64)
    assert not ops.matmul_fusable((128, 100), 64)   # N not block-aligned
    assert not ops.matmul_fusable((512,), 64)       # 1-D leaf


@pytest.mark.parametrize("d", [2, 8])
def test_int4_sum_kernel_matches_ref(d):
    """Fused unpack+dequant+reduce == per-chunk dequant + sum; jnp and
    interpret impls bitwise identical under jit (the engine always runs
    jitted, where XLA applies the same fma contraction to both)."""
    block = 256
    x = _rand((d * 8 * block,), jnp.float32, 8)
    q, s = ops.quantize_int4(x, block)
    r_j = jax.jit(lambda q, s: ops.dequantize_int4_sum(
        q, s, d, block, impl="jnp"))(q, s)
    r_p = jax.jit(lambda q, s: ops.dequantize_int4_sum(
        q, s, d, block, impl="pallas_interpret"))(q, s)
    np.testing.assert_array_equal(np.asarray(r_j), np.asarray(r_p))
    unfused = ops.dequantize_int4(q, s, block).reshape(d, -1).sum(axis=0)
    np.testing.assert_allclose(np.asarray(r_j), np.asarray(unfused),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("nb", [1, 3, 12, 20])
def test_kernels_cover_unaligned_block_counts(nb):
    """Block counts that are not a multiple of the 8-row tile must still be
    fully written (the row tile degrades via gcd instead of the grid
    truncating and leaving trailing rows as uninitialized garbage)."""
    block, d = 128, 2
    x = _rand((nb * block,), jnp.float32, 10)
    for quant, dequant in ((ops.quantize_int8, ops.dequantize_int8),
                           (ops.quantize_int4, ops.dequantize_int4)):
        q, s = quant(x, block, impl="pallas_interpret")
        qr, sr = quant(x, block, impl="jnp")
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        out = dequant(q, s, block, jnp.float32, impl="pallas_interpret")
        outr = dequant(q, s, block, jnp.float32, impl="jnp")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(outr))
    xs = _rand((d * nb * block,), jnp.float32, 11)
    q, s = ops.quantize_int4(xs, block)
    r_p = jax.jit(lambda q, s: ops.dequantize_int4_sum(
        q, s, d, block, impl="pallas_interpret"))(q, s)
    r_j = jax.jit(lambda q, s: ops.dequantize_int4_sum(
        q, s, d, block, impl="jnp"))(q, s)
    assert np.isfinite(np.asarray(r_p)).all()
    np.testing.assert_array_equal(np.asarray(r_p), np.asarray(r_j))
    q8, s8 = ops.quantize_int8(xs, block)
    r8_p = jax.jit(lambda q, s: ops.dequantize_int8_sum(
        q, s, d, block, impl="pallas_interpret"))(q8, s8)
    r8_j = jax.jit(lambda q, s: ops.dequantize_int8_sum(
        q, s, d, block, impl="jnp"))(q8, s8)
    assert np.isfinite(np.asarray(r8_p)).all()
    np.testing.assert_array_equal(np.asarray(r8_p), np.asarray(r8_j))


@pytest.mark.parametrize("d", [2, 8])
def test_int8_sum_kernel_matches_ref(d):
    block = 128
    x = _rand((d * 16 * block,), jnp.float32, 9)
    q, s = ops.quantize_int8(x, block)
    r_j = jax.jit(lambda q, s: ops.dequantize_int8_sum(
        q, s, d, block, impl="jnp"))(q, s)
    r_p = jax.jit(lambda q, s: ops.dequantize_int8_sum(
        q, s, d, block, impl="pallas_interpret"))(q, s)
    np.testing.assert_array_equal(np.asarray(r_j), np.asarray(r_p))
    unfused = ops.dequantize_int8(q, s, block).reshape(d, -1).sum(axis=0)
    np.testing.assert_allclose(np.asarray(r_j), np.asarray(unfused),
                               rtol=1e-6, atol=1e-5)


# ---------------------------------------------------------------------------
# ops-level (flat API, padding plumbing)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("n,block", [(1024, 128), (4096, 512), (512, 512)])
def test_ops_int8_roundtrip_error_bound(impl, n, block):
    x = jax.random.normal(jax.random.key(5), (n,)) * 2.0
    q, s = ops.quantize_int8(x, block, impl=impl)
    d = ops.dequantize_int8(q, s, block, jnp.float32, impl=impl)
    blocks = np.asarray(x).reshape(-1, block)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-7
    err = np.abs(np.asarray(d).reshape(-1, block) - blocks)
    assert (err <= bound + 1e-6).all()


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_ops_int4_roundtrip_error_bound(impl):
    n, block = 2048, 256
    x = jax.random.normal(jax.random.key(6), (n,))
    q, s = ops.quantize_int4(x, block, impl=impl)
    assert q.shape == (n // 2,) and q.dtype == jnp.uint8
    d = ops.dequantize_int4(q, s, block, jnp.float32, impl=impl)
    blocks = np.asarray(x).reshape(-1, block)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 7.0 * 0.5 + 1e-7
    err = np.abs(np.asarray(d).reshape(-1, block) - blocks)
    assert (err <= bound + 1e-6).all()


# ---------------------------------------------------------------------------
# matmul_quant: the fused dW -> wire-format epilogue (DESIGN.md §5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("m,k,n,block", [(33, 16, 512, 64), (64, 128, 384, 64),
                                         (10, 8, 256, 128)])
def test_matmul_quant_jnp_vs_interpret_bitwise(bits, m, k, n, block):
    """Wire bytes AND scales are bitwise identical across the pair — the
    downstream a2a ships these verbatim, so close-enough is not enough."""
    x = _rand((m, k), jnp.float32, 8)
    g = _rand((m, n), jnp.float32, 9)
    q_j, s_j = ops.matmul_quant(x, g, block, bits=bits, impl="jnp")
    q_p, s_p = ops.matmul_quant(x, g, block, bits=bits,
                                impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(q_j), np.asarray(q_p))
    np.testing.assert_array_equal(np.asarray(s_j), np.asarray(s_p))


@pytest.mark.parametrize("bits", [8, 4])
def test_matmul_quant_matches_unfused_pair(bits):
    """Dequantizing the fused output recovers x.T @ g to quantization error,
    and the wire layout equals quantize(C.reshape(-1)) up to rounding."""
    m, k, n, block = 32, 16, 512, 64
    x = _rand((m, k), jnp.float32, 10)
    g = _rand((m, n), jnp.float32, 11)
    q, s = ops.matmul_quant(x, g, block, bits=bits, impl="jnp")
    dense = np.asarray(x).T @ np.asarray(g)
    deq = ops.dequantize_int4 if bits == 4 else ops.dequantize_int8
    got = np.asarray(deq(q, s, block, jnp.float32)).reshape(k, n)
    qmax = 7.0 if bits == 4 else 127.0
    bound = np.abs(dense.reshape(-1, block)).max(axis=1, keepdims=True) \
        / qmax * 0.5 + 1e-6
    err = np.abs((got - dense).reshape(-1, block))
    assert (err <= bound + 1e-5).all()


def test_matmul_quant_pad_to_exact_zero_blocks():
    """pad_to appends exact wire zeros (q=0 / 0x88, scale=1) — the same
    bytes quantize-of-zero-padding ships on the unfused path."""
    m, k, n, block = 16, 8, 256, 64
    x = _rand((m, k), jnp.float32, 12)
    g = _rand((m, n), jnp.float32, 13)
    logical = k * n
    pad_to = logical + 4 * block
    for bits, fill in ((8, 0), (4, 0x88)):
        q, s = ops.matmul_quant(x, g, block, bits=bits, pad_to=pad_to,
                                impl="pallas_interpret")
        q0, s0 = ops.matmul_quant(x, g, block, bits=bits, impl="jnp")
        wire = logical // 2 if bits == 4 else logical
        assert q.shape == (pad_to // 2 if bits == 4 else pad_to,)
        np.testing.assert_array_equal(np.asarray(q)[:wire], np.asarray(q0))
        assert (np.asarray(q)[wire:] == fill).all()
        np.testing.assert_array_equal(np.asarray(s)[:logical // block],
                                      np.asarray(s0))
        assert (np.asarray(s)[logical // block:] == 1.0).all()


def test_dw_fusable_routes_unaligned_to_unfused(monkeypatch):
    """Regression: a leaf whose columns don't tile into quant blocks (e.g.
    falcon-mamba's w_xproj (512, 48) with block 64) must keep the dense
    matmul + quantize pair — matmul_quant would produce a broken wire
    layout for it. Any fused call for such a spec is an error."""
    from repro.core import linear
    from repro.core.partition import LeafSpec
    from repro.launch.mesh import make_test_mesh, scheme_config

    mesh = make_test_mesh(shape=(1, 1, 1), axes=("data", "node", "gcd"))
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    aligned = LeafSpec("w_in", (256, 1024))
    unaligned = LeafSpec("w_xproj", (512, 48))
    assert not linear._dw_fusable(unaligned, cfg)
    # the gate result for the aligned spec depends only on the RS config;
    # on a 1-device weight axis there is no quantized a2a to fuse into
    if cfg.quantize_grads and cfg.size(cfg.axes.weight) > 1:
        assert linear._dw_fusable(aligned, cfg)

    def _boom(*a, **kw):
        raise AssertionError("matmul_quant called for a non-fusable leaf")
    monkeypatch.setattr(ops, "matmul_quant", _boom)
    x2 = _rand((12, 512), jnp.float32, 14)
    g2 = _rand((12, 48), jnp.float32, 15)
    out = linear._mm_dw_stage1(x2, g2, False, unaligned, cfg)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# hypothesis property tests (skip when the optional extra is missing)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1),
           st.sampled_from([64, 128, 512]))
    def test_prop_int8_scales_positive_and_bounded(nb, seed, block):
        x = jax.random.normal(jax.random.key(seed), (nb, block)) * 10
        q, s = ref.quantize_int8_ref(x)
        assert (np.asarray(s) > 0).all()
        assert (np.abs(np.asarray(q)) <= 127).all()
        # all-zero blocks dequantize to exact zeros
        z, sz = ref.quantize_int8_ref(jnp.zeros((2, block)))
        assert (np.asarray(ref.dequantize_int8_ref(z, sz)) == 0).all()

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_prop_int4_pack_bijection(seed):
        """pack(unpack(q)) == q for all valid nibble pairs."""
        rng = np.random.default_rng(seed)
        vals = rng.integers(-7, 8, size=(4, 256)).astype(np.float32)
        q, s = ref.quantize_int4_ref(jnp.asarray(vals))  # scale==1 blocks
        d = ref.dequantize_int4_ref(q, s)
        # since |vals| <= 7 and absmax<=7 -> scale = absmax/7 <= 1;
        # round-trip re-quantizing gives identical packed bytes
        q2, s2 = ref.quantize_int4_ref(d)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([128, 256]))
    def test_prop_quant_idempotent(seed, block):
        """Dequantized tensors are fixed points of quantize∘dequantize."""
        x = jax.random.normal(jax.random.key(seed), (4, block))
        q, s = ref.quantize_int8_ref(x)
        d = ref.dequantize_int8_ref(q, s)
        q2, s2 = ref.quantize_int8_ref(d)
        d2 = ref.dequantize_int8_ref(q2, s2)
        np.testing.assert_allclose(np.asarray(d), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)
else:
    def test_prop_hypothesis_missing():
        pytest.skip("hypothesis not installed (optional [test] extra); "
                    "property tests run on CI")
