"""Overlap-path equivalence: the double-buffered gather prefetch
(ZeroConfig.overlap, core/prefetch.py, DESIGN.md §3) must be a pure schedule
change.  scan_layers (stacked leaves, remat on and off, with_ys) and
loop_layers (heterogeneous pattern) are exercised directly and through the
engine; the 8-device train-step check runs the ``overlap_equivalence``
subprocess scenario for zero3 / zeropp / zero_topo."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.registry import build_model, get_arch

HERE = os.path.dirname(__file__)
AX = ("data", "node", "gcd")


def _mesh1():
    return make_test_mesh(shape=(1, 1, 1), axes=AX)


def _engine(arch="qwen2-0.5b", scheme="zero_topo", **over):
    mesh = _mesh1()
    cfg_arch = get_arch(arch).reduced(n_layers=3, d_model=128, vocab=256) \
        if arch == "qwen2-0.5b" else get_arch(arch).reduced()
    model = build_model(cfg_arch)
    cfg = scheme_config(scheme, mesh, quant_block=32,
                        compute_dtype="float32", **over)
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh,
                     TrainHparams(lr=1e-3, total_steps=10, warmup_steps=0))
    return mesh, model, eng, eng.init_state(jax.random.key(0))


def _batch(model, seed=0, shape=(2, 33)):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, model.arch.vocab, shape), jnp.int32)}


# ---------------------------------------------------------------------------
# scan_layers directly (remat on/off, with_ys, explicit overlap arg)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("remat", [True, False])
def test_scan_layers_overlap_matches(remat):
    mesh, model, eng, state = _engine()
    names = [n for n in eng.specs if n.startswith("attn.")]

    def fn(view, x):
        def body(v, c):
            y = jnp.tanh(v.mm("attn.wq", c))
            c2 = c + v.mm("attn.wo", y)
            return c2, jnp.sum(jnp.square(y))

        outs = {}
        for overlap in (False, True):
            outs[overlap] = view.scan_layers(body, x, names, remat=remat,
                                             with_ys=True, overlap=overlap)
        return outs

    apply = eng.make_apply(fn, (P(),), P())
    x = jax.random.normal(jax.random.key(3), (2, 5, 128), jnp.float32)
    out = apply(state["primaries"], x)
    np.testing.assert_array_equal(np.asarray(out[False][0]),
                                  np.asarray(out[True][0]))
    np.testing.assert_array_equal(np.asarray(out[False][1]),
                                  np.asarray(out[True][1]))


def test_loop_layers_overlap_matches():
    """Heterogeneous pattern through loop_layers, overlap on/off, incl. the
    per-layer ys."""
    mesh, model, eng, state = _engine()
    names = [n for n in eng.specs if n.startswith("attn.")]
    stack = eng.specs[names[0]].stack

    def fn(view, x):
        stacks = view.stacked(names)
        steps = [("attn", jax.tree.map(lambda a, i=i: a[i], stacks))
                 for i in range(stack)]

        def body(v, c, tag):
            y = jnp.tanh(v.mm("attn.wq", c))
            return c + v.mm("attn.wo", y), jnp.sum(jnp.square(y))

        outs = {}
        for overlap in (False, True):
            c, ys = view.loop_layers(body, x, steps, overlap=overlap)
            outs[overlap] = (c, jnp.stack(ys))
        return outs

    apply = eng.make_apply(fn, (P(),), P())
    x = jax.random.normal(jax.random.key(4), (2, 5, 128), jnp.float32)
    out = apply(state["primaries"], x)
    np.testing.assert_array_equal(np.asarray(out[False][0]),
                                  np.asarray(out[True][0]))
    np.testing.assert_array_equal(np.asarray(out[False][1]),
                                  np.asarray(out[True][1]))


# ---------------------------------------------------------------------------
# engine-level: prefill caches (with_ys epilogue concat) + hetero arch loss
# ---------------------------------------------------------------------------

def test_prefill_caches_identical():
    outs = {}
    for overlap in (False, True):
        mesh, model, eng, state = _engine(overlap=overlap)
        fn = model.prefill_fn((), dict(mesh.shape))
        apply = eng.make_apply(fn, ({"tokens": P()},), P())
        logits, caches = apply(state["primaries"], _batch(model))
        outs[overlap] = (logits, caches)
    np.testing.assert_array_equal(np.asarray(outs[False][0]),
                                  np.asarray(outs[True][0]))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        outs[False][1], outs[True][1])


def test_hetero_arch_loss_identical():
    """gemma3's 5:1 local:global pattern goes through loop_layers."""
    losses = {}
    for overlap in (False, True):
        mesh, model, eng, state = _engine(arch="gemma3-1b", overlap=overlap)
        ev = eng.make_eval_step(model.loss_fn(), {"tokens": P()})
        losses[overlap] = float(ev(state, _batch(model)))
    assert losses[False] == losses[True], losses


# ---------------------------------------------------------------------------
# 8-device train-step equivalence (zero3 / zeropp / zero_topo + hetero)
# ---------------------------------------------------------------------------

def test_scenario_overlap_equivalence_8dev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "_scenarios.py"),
         "overlap_equivalence"],
        capture_output=True, text=True, timeout=900, env=env)
    tail = (r.stdout + r.stderr)[-4000:]
    assert r.returncode == 0, f"overlap_equivalence failed:\n{tail}"
    assert "SCENARIO_OK overlap_equivalence" in r.stdout, tail
