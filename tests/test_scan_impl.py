"""Model-level integration of the Pallas selective-scan kernel: the mamba
mixer under set_scan_impl('pallas_interpret') reproduces the jnp path."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import ParamView, TrainHparams, ZeroEngine
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models import ssm
from repro.models.registry import build_model, get_arch


def test_mamba_model_pallas_scan_matches_jnp():
    mesh = make_test_mesh(shape=(1, 1, 1), axes=("data", "node", "gcd"))
    arch = get_arch("falcon-mamba-7b").reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, arch.vocab, (2, 33)), jnp.int32)}

    def loss(prims, b):
        v = ParamView(eng.fns, prims)
        l, t = model.lm.loss(v, b)
        return l / t

    f = jax.jit(shard_map(
        loss, mesh=mesh,
        in_specs=(eng.state_in_specs()["primaries"], {"tokens": P()}),
        out_specs=P(), check_vma=False))
    ssm.set_scan_impl("jnp")
    l0 = float(f(state["primaries"], batch))
    try:
        ssm.set_scan_impl("pallas_interpret")
        l1 = float(f(state["primaries"], batch))
    finally:
        ssm.set_scan_impl("jnp")
    assert abs(l0 - l1) < 1e-4, (l0, l1)
