"""Model-level integration of the selective-scan ops dispatch: a mamba
model's loss AND gradients are bitwise identical under the process-default
impl switch (jnp vs pallas_interpret), end to end through the ZeRO engine."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import ParamView, TrainHparams, ZeroEngine
from repro.kernels import ops
from repro.launch.mesh import make_test_mesh, scheme_config
from repro.models.registry import build_model, get_arch


def test_mamba_model_scan_impls_bitwise():
    mesh = make_test_mesh(shape=(1, 1, 1), axes=("data", "node", "gcd"))
    arch = get_arch("falcon-mamba-7b").reduced()
    model = build_model(arch)
    cfg = scheme_config("zero_topo", mesh, quant_block=64,
                        compute_dtype="float32")
    eng = ZeroEngine(model.leaf_specs(), cfg, mesh, TrainHparams())
    state = eng.init_state(jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, arch.vocab, (2, 33)), jnp.int32)}

    def loss(prims, b):
        v = ParamView(eng.fns, prims)
        l, t = model.lm.loss(v, b)
        return l / t

    results = {}
    try:
        for impl in ("jnp", "pallas_interpret"):
            ops.set_default_impl(impl)
            ops.reset_dispatch_counters()
            # fresh jit per impl: dispatch is baked in at trace time
            f = jax.jit(shard_map(
                jax.value_and_grad(loss), mesh=mesh,
                in_specs=(eng.state_in_specs()["primaries"], {"tokens": P()}),
                out_specs=(P(), eng.state_in_specs()["primaries"]),
                check_vma=False))
            l, g = f(state["primaries"], batch)
            assert ops.dispatch_counters().get(
                f"selective_scan/{impl}", 0) > 0, ops.dispatch_counters()
            results[impl] = (float(l), jax.tree.map(np.asarray, g))
    finally:
        ops.set_default_impl("jnp")

    l0, g0 = results["jnp"]
    l1, g1 = results["pallas_interpret"]
    assert l0 == l1, (l0, l1)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(a, b)
