"""Collectives invariants promised by partition.py's module docstring: the
canonical flat-slice hierarchy [W major, E, R minor] makes every stage's
shard a contiguous refinement of the previous stage's, the secondary
partition round-trips, and the a2a quantized reduce-scatter tracks the plain
one.  Degree-1 numerics run in-process; 8-device semantics run the
``collectives`` / ``collectives_split`` subprocess scenarios."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core import collectives as col
from repro.core.partition import padded_flat_size, preset
from repro.launch.mesh import make_test_mesh, scheme_config

HERE = os.path.dirname(__file__)
AX = ("data", "node", "gcd")
SIZES = {"data": 2, "node": 2, "gcd": 2}


def _topo_cfg(**over):
    return preset("zero_topo", intra_axes=("node", "gcd"),
                  inter_axes=("data",), l0_axes=("gcd",), axis_sizes=SIZES,
                  **over)


# ---------------------------------------------------------------------------
# The slice-hierarchy invariant (pure index math, no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["zero1", "zero2", "zero3", "zeropp",
                                    "zero_topo"])
def test_major_to_minor_contiguous_refinement(scheme):
    """Flat storage uses [W major, E, R minor]: for every device coordinate,
    the optimizer shard is a contiguous sub-slice of the gradient shard,
    which is a contiguous sub-slice of the primary shard — i.e. each stage
    refines the previous one without any re-layout collective."""
    cfg = preset(scheme, intra_axes=("node", "gcd"), inter_axes=("data",),
                 l0_axes=("gcd",), axis_sizes=SIZES)
    n = 1000
    padded = padded_flat_size(n, cfg)
    dw, dg, dos = cfg.w_degree, cfg.g_degree, cfg.os_degree
    assert padded % dos == 0 and dos % dg == 0 and dg % dw == 0
    lp, lg, lo = padded // dw, padded // dg, padded // dos
    # enumerate devices by their (w, e, r) group indices, major -> minor
    for w in range(dw):
        for e in range(dg // dw):
            for r in range(dos // dg):
                p0 = w * lp                       # primary slice start
                g0 = (w * (dg // dw) + e) * lg     # grad slice start
                o0 = ((w * (dg // dw) + e) * (dos // dg) + r) * lo
                # contiguous refinement: each slice sits inside its parent
                assert p0 <= g0 and g0 + lg <= p0 + lp
                assert g0 <= o0 and o0 + lo <= g0 + lg
                # and the offset is exactly the child-major linear index
                assert g0 - p0 == e * lg
                assert o0 - g0 == r * lo


def test_block_alignment_of_every_stage():
    """padded % (os_degree * block) == 0 keeps every stage's shard a whole
    number of quantization blocks (partition.padded_flat_size contract)."""
    cfg = _topo_cfg()
    for n in (1, 7, 1000, 4097, 65536):
        padded = padded_flat_size(n, cfg)
        b = cfg.block_for(n)
        assert (padded // cfg.w_degree) % b == 0
        assert (padded // cfg.g_degree) % b == 0
        assert (padded // cfg.os_degree) % b == 0


# ---------------------------------------------------------------------------
# Degree-1 numerics (full code path, collectives are group-size-1)
# ---------------------------------------------------------------------------

def _metric1(fn, x):
    from jax.sharding import PartitionSpec as P
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    sm = shard_map(lambda s: fn(s.reshape(-1)), mesh=mesh,
                   in_specs=P(AX), out_specs=P(AX), check_vma=False)
    return jax.jit(sm)(x)


def test_split_gather_matches_fused_degree1():
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)

    x = jax.random.normal(jax.random.key(0), (64 * 4,))

    def check(shard):
        full, qf, sf = col.quant_all_gather_int8(shard, AX, cfg)
        qf2, sf2 = col.gather_issue_int8(shard, AX, cfg)
        full2 = col.gather_wait_int8(qf2, sf2, cfg)
        return jnp.stack([
            jnp.max(jnp.abs(full.astype(jnp.float32)
                            - full2.astype(jnp.float32))),
            jnp.max(jnp.abs(qf - qf2).astype(jnp.float32)),
            jnp.max(jnp.abs(sf - sf2))])

    out = _metric1(check, x)
    assert np.asarray(out).max() == 0.0


def test_secondary_roundtrip_degree1():
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    x = jax.random.normal(jax.random.key(1), (64 * 4,))

    def check(shard):
        full, qf, sf = col.quant_all_gather_int8(shard, AX, cfg)
        sq, ss = col.secondary_slice(qf, sf, cfg.axes.secondary, cfg)
        rebuilt = col.gather_secondary(sq, ss, cfg.axes.secondary, cfg)
        return jnp.max(jnp.abs(rebuilt.astype(jnp.float32)
                               - full.astype(jnp.float32)))[None]

    assert float(np.asarray(_metric1(check, x)).max()) == 0.0


def test_rs_quant_vs_plain_degree1():
    """Group size 1: both reduce-scatters are the identity (cast aside)."""
    mesh = make_test_mesh(shape=(1, 1, 1), axes=AX)
    cfg = scheme_config("zero_topo", mesh, quant_block=64)
    x = jax.random.normal(jax.random.key(2), (64 * 4,))

    def check(shard):
        a = col.reduce_scatter_flat(shard, AX, cfg, quantized=False)
        b = col.reduce_scatter_flat(shard, AX, cfg, quantized=True)
        return jnp.max(jnp.abs(a - b))[None]

    assert float(np.asarray(_metric1(check, x)).max()) == 0.0


# ---------------------------------------------------------------------------
# 8-device semantics (subprocess, own XLA_FLAGS)
# ---------------------------------------------------------------------------

# (the broader `collectives` scenario already runs under test_distributed.py;
# only the split-primitive coverage is owned here)
@pytest.mark.parametrize("name", ["collectives_split"])
def test_scenario_8dev(name):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "_scenarios.py"), name],
        capture_output=True, text=True, timeout=900, env=env)
    tail = (r.stdout + r.stderr)[-4000:]
    assert r.returncode == 0, f"scenario {name} failed:\n{tail}"
    assert f"SCENARIO_OK {name}" in r.stdout, tail
